"""Composable compressed-query demo: filter / aggregate / phrase operators
answered directly on the grammars, driven from raw query text.

Builds a handful of tiny text corpora, tokenizes them (the same dictionary
the compression used), and runs the three query-operator kinds through the
sync batched server and the async deadline queue:

* ``filter_count``  — which files match ``cat AND the >= 2 OR mat``;
* ``agg_terms``     — per-file and corpus-total counts over a term set;
* ``phrase_count``  — exact phrase occurrences via the sequence-support
  plans (never by decompressing).

Query text parses through ``repro.query.frontend`` against the frozen
tokenizer — unknown words map to UNK and can never grow the vocab.  The
same operator against many corpora batches into ONE jitted program per
pack; distinct predicates/term sets split into separate groups (they are
part of the group key).

    PYTHONPATH=src python examples/query.py
"""

import time

from repro.core import compress_files, flatten
from repro.data.tokenizer import Tokenizer
from repro.query import phrase_from_text, predicate_from_text, terms_from_text
from repro.serving import AnalyticsServer, AsyncAnalyticsServer, Query

CORPORA = {
    "pets": ["the cat sat on the mat",
             "the dog chased the cat around the mat",
             "a bird sang"],
    "food": ["the cat ate the fish",
             "the dog ate the cat food then more food",
             "fish and chips on a mat"],
    "news": ["dog bites man",
             "man bites dog and the dog ran",
             "the cat reads the news on the mat"],
}


def main() -> None:
    tok = Tokenizer.build(t for texts in CORPORA.values() for t in texts)
    engine = AnalyticsServer(max_batch=4, method="auto")
    for name, texts in CORPORA.items():
        files = [tok.encode(t) for t in texts]
        g, nf = compress_files(files, tok.vocab_size)
        engine.register(name, flatten(g, tok.vocab_size, nf))
        print(f"registered corpus {name}: {nf} files, "
              f"vocab {tok.vocab_size}")

    pred_text = "cat AND the >= 2 OR mat"
    pred = predicate_from_text(tok, pred_text)
    terms = terms_from_text(tok, "cat dog fish")
    phrase = phrase_from_text(tok, "the cat")
    names = tuple(CORPORA)

    # ---- sync: each operator batches into one program over the pack -----
    t0 = time.monotonic()
    filt = engine.run([Query(n, "filter_count", predicate=pred)
                       for n in names])
    dt = time.monotonic() - t0
    print(f"\nfilter '{pred_text}' ({dt * 1e3:.1f} ms incl. compile):")
    for name, files_hit in zip(names, filt):
        print(f"  {name}: files {files_hit.tolist()}")

    aggs = engine.run([Query(n, "agg_terms", terms=terms, agg="sum")
                       for n in names])
    print("\nsum(count) over 'cat dog fish':")
    for name, (per_file, total) in zip(names, aggs):
        print(f"  {name}: per-file {per_file.tolist()} total {total:.0f}")

    counts = engine.run([Query(n, "phrase_count", terms=phrase)
                         for n in names])
    print("\nphrase 'the cat' occurrences (via sequence plans):")
    for name, c in zip(names, counts):
        print(f"  {name}: {float(c):.0f}")

    # ---- async: operators ride the deadline-aware flush policy ----------
    with AsyncAnalyticsServer(engine, idle_timeout=0.01,
                              poll_interval=0.002,
                              max_pending=64) as queue:
        now = time.monotonic()
        futures = {n: queue.submit(Query(n, "filter_count", predicate=pred),
                                   deadline=now + 0.05)
                   for n in names}
        # a different predicate -> its own group, flushed independently
        other = queue.submit(Query(
            "news", "filter_count",
            predicate=predicate_from_text(tok, "dog >= 2")))
        t0 = time.monotonic()
        async_filt = {n: f.result(timeout=60) for n, f in futures.items()}
        other_hit = other.result(timeout=60)
        dt = time.monotonic() - t0

    print(f"\nasync resolved {len(async_filt) + 1} filters "
          f"in {dt * 1e3:.1f} ms")
    for name, sync_hit in zip(names, filt):
        same = (async_filt[name] == sync_hit).all()
        print(f"  {name}: async result identical to sync: {bool(same)}")
    print(f"  news for 'dog >= 2': files {other_hit.tolist()}")

    st = engine.stats
    print(f"\nflushes by reason: {dict(st.flushes)}")
    print(f"engine calls: {st.batched_calls} batched + {st.single_calls} "
          f"single for {st.queries} sync + {st.submitted} async queries "
          f"(max queue depth {st.max_queue_depth})")


if __name__ == "__main__":
    main()
