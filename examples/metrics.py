"""Observability demo: metrics exposition + per-query span trees.

Registers a few Table-II-analogue corpora, serves a mixed burst through
both the sync server and the async queue, and then shows what the
observability layer captured (docs/observability.md):

* the span tree of one query's whole lifecycle — submit, queue wait,
  flush, pack build, compile/execute — read off ``query.trace``;
* the server's metrics registry rendered two ways: the JSON ``snapshot``
  (what BENCH uploads) and the Prometheus text exposition (what a
  scrape endpoint would serve);
* a slice of the process-global registry — kernel dispatch decisions and
  store memo traffic recorded by the library layers below the server.

    PYTHONPATH=src python examples/metrics.py
"""

import json
import time

from repro.core import compress_files, flatten
from repro.data.synthetic import TABLE2, make_table2_corpus
from repro.obs import global_registry
from repro.serving import AnalyticsServer, AsyncAnalyticsServer, Query


def _print_span(span, depth: int = 0) -> None:
    pad = "  " * depth
    attrs = ", ".join(f"{k}={v}" for k, v in span.attrs.items())
    print(f"{pad}{span.name:<12} {span.duration * 1e3:8.3f} ms"
          + (f"   [{attrs}]" if attrs else ""))
    for child in span.children:
        _print_span(child, depth + 1)


def main() -> None:
    engine = AnalyticsServer(max_batch=4, method="auto")
    for name in ("A", "B", "D"):
        files = make_table2_corpus(name)
        g, nf = compress_files(files, TABLE2[name].vocab)
        engine.register(name, flatten(g, TABLE2[name].vocab, nf))

    # ---- sync path: every run() query gets a root span --------------------
    queries = [Query(n, "word_count") for n in ("A", "B", "D")]
    engine.run(queries)                      # cold: pack build + compile
    queries = [Query(n, "word_count") for n in ("A", "B", "D")]
    engine.run(queries)                      # warm: cache hit + execute

    print("warm sync query span tree (shared run_group/chunk subtree is")
    print("the batching — three queries, one engine call):")
    _print_span(queries[0].trace)

    # ---- async path: spans grow queue_wait and flush stages ---------------
    with AsyncAnalyticsServer(engine, idle_timeout=0.01,
                              poll_interval=0.002) as queue:
        q = Query("A", "sequence_count", l=3)
        fut = queue.submit(q, deadline=time.monotonic() + 1.0)
        fut.result(timeout=60)
    print("\nasync query span tree (queue_wait + flush around the chunk):")
    _print_span(q.trace)

    # ---- exposition -------------------------------------------------------
    snap = engine.registry.snapshot()
    stage = snap["repro_server_stage_seconds"]["samples"]
    print("\nJSON snapshot, stage-latency excerpt:")
    for s in stage:
        print(f"  stage={s['labels']['stage']:<12} n={s['count']:<3} "
              f"p99={s['p99'] * 1e3:.3f} ms")

    print("\nPrometheus exposition (server registry, first 20 lines):")
    for line in engine.registry.render_prometheus().splitlines()[:20]:
        print(f"  {line}")

    print("\nprocess-global library metrics (dispatch / memo / plans):")
    gsnap = global_registry().snapshot()
    for name in sorted(gsnap):
        for s in gsnap[name]["samples"]:
            labels = ",".join(f"{k}={v}" for k, v in s["labels"].items())
            value = s.get("value", s.get("count"))
            print(f"  {name}{{{labels}}} = {value}")

    # the snapshot is JSON-safe end to end (what CI uploads as an artifact)
    json.dumps({"server": snap, "global": gsnap})
    print("\nsnapshot serializes cleanly; "
          f"trace log holds {len(engine.trace_log)} root spans")


if __name__ == "__main__":
    main()
