"""Async analytics serving demo: deadline-aware batching over compressed
corpora.

Registers a few Table-II-analogue corpora, starts the background flush
thread, and fires a burst of mixed queries — some with tight deadlines,
some best-effort — at the queue.  The flush policy packs them into batched
engine calls; the printed stats show how many device calls the traffic
actually cost and why each flush fired.  The tail of the demo submits a
query whose deadline is already hopeless — the queue sheds it with
`DeadlineExceeded` instead of wasting an engine slot on it.

    PYTHONPATH=src python examples/serve_async.py
"""

import time

from repro.core import compress_files, flatten
from repro.data.synthetic import make_table2_corpus, TABLE2
from repro.serving import (AnalyticsServer, AsyncAnalyticsServer,
                           DeadlineExceeded, Query)


def main() -> None:
    engine = AnalyticsServer(max_batch=4, method="auto")
    for name in ("A", "B", "D"):
        files = make_table2_corpus(name)
        g, nf = compress_files(files, TABLE2[name].vocab)
        engine.register(name, flatten(g, TABLE2[name].vocab, nf))
        print(f"registered corpus {name}: {nf} files, "
              f"{engine._corpora[name].num_rules} rules")

    # warm the compiled programs so the timed burst shows serving latency
    engine.run([Query(n, "word_count") for n in ("A", "B", "D")])

    with AsyncAnalyticsServer(engine, idle_timeout=0.01,
                              poll_interval=0.002) as queue:
        now = time.monotonic()
        futures = {
            # tight deadline: flushed as soon as one batch-latency remains
            "wc_A": queue.submit(Query("A", "word_count"),
                                 deadline=now + 0.05),
            "wc_B": queue.submit(Query("B", "word_count"),
                                 deadline=now + 0.05),
            # best effort: rides along with whatever flush happens first
            "sort_D": queue.submit(Query("D", "sort")),
            "seq_A": queue.submit(Query("A", "sequence_count", l=3)),
            "tv_B": queue.submit(Query("B", "term_vector")),
        }
        t0 = time.monotonic()
        results = {k: f.result(timeout=60) for k, f in futures.items()}
        dt = time.monotonic() - t0

        # a deadline that has already passed is shed at flush time: the
        # future raises instead of the engine computing a dead answer
        hopeless = queue.submit(Query("A", "word_count"),
                                deadline=time.monotonic() - 0.001)
        try:
            hopeless.result(timeout=60)
            print("\nexpired-deadline query unexpectedly returned")
        except DeadlineExceeded as e:
            print(f"\nexpired-deadline query shed: {e}")

    wc_a = results["wc_A"]
    order, counts = results["sort_D"]
    grams, gcounts = results["seq_A"]
    print(f"\nresolved {len(results)} queries in {dt * 1e3:.1f} ms")
    print(f"corpus A total words: {wc_a.sum():.0f}")
    print(f"corpus D top word: id={int(order[0])} x{counts[0]:.0f}")
    print(f"corpus A distinct 3-grams: {len(grams)}")
    print(f"corpus B term-vector shape: {results['tv_B'].shape}")

    st = engine.stats
    print(f"\nflushes by reason: {st.flushes} (shed={st.shed})")
    print(f"engine calls: {st.batched_calls} batched "
          f"+ {st.single_calls} single for {st.submitted} submissions "
          f"(max queue depth {st.max_queue_depth})")
    for kind in ("word_count", "sort", "term_vector", "sequence_count"):
        est = st.estimate_latency(kind)
        print(f"  batch-latency estimate {kind:<22} {est * 1e3:7.2f} ms "
              f"(EWMA; first executions are compile warmup)")


if __name__ == "__main__":
    main()
