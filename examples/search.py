"""Compressed BM25 retrieval demo: top-k document ranking directly on the
grammar, through both the sync batched server and the async deadline queue.

Builds a few synthetic corpora, registers them, and answers multi-term
queries with BM25 (and TF-IDF) top-k rankings — term frequencies, document
frequencies and document lengths all derived from the compressed
representation, never from decompressed text.  The same query against many
corpora batches into ONE jitted scoring program; distinct queries split
into separate groups (their terms/k are part of the group key).

    PYTHONPATH=src python examples/search.py
"""

import time

from repro.core import compress_files, flatten
from repro.data.synthetic import TABLE2, make_table2_corpus
from repro.serving import AnalyticsServer, AsyncAnalyticsServer, Query


def main() -> None:
    engine = AnalyticsServer(max_batch=4, method="auto")
    names = ("A", "B", "D")
    for name in names:
        files = make_table2_corpus(name)
        g, nf = compress_files(files, TABLE2[name].vocab)
        engine.register(name, flatten(g, TABLE2[name].vocab, nf))
        print(f"registered corpus {name}: {nf} files, "
              f"vocab {TABLE2[name].vocab}")

    query = (3, 17, 42)          # word ids; real deployments map text->ids
    k = 3

    # ---- sync: one batched call ranks every corpus against the query ----
    t0 = time.monotonic()
    results = engine.run([Query(n, "search_bm25", terms=query, k=k)
                          for n in names])
    dt = time.monotonic() - t0
    print(f"\nsync BM25 top-{k} for terms {query} "
          f"({dt * 1e3:.1f} ms incl. compile):")
    for name, (doc_ids, scores) in zip(names, results):
        ranked = ", ".join(f"file {d} ({s:.3f})"
                           for d, s in zip(doc_ids, scores))
        print(f"  {name}: {ranked}")

    # TF-IDF is its own query kind — and its own batch group
    tfidf = engine.run([Query("A", "search_tfidf", terms=query, k=k)])[0]
    print(f"  A (tfidf): docs {tfidf[0].tolist()}")

    # ---- async: search rides the deadline-aware flush policy ------------
    with AsyncAnalyticsServer(engine, idle_timeout=0.01,
                              poll_interval=0.002,
                              max_pending=64) as queue:
        now = time.monotonic()
        futures = {
            name: queue.submit(Query(name, "search_bm25", terms=query, k=k),
                               deadline=now + 0.05)
            for name in names
        }
        # a different query -> different group, flushed independently
        other = queue.submit(Query("B", "search_bm25", terms=(5, 9), k=2))
        t0 = time.monotonic()
        async_results = {n: f.result(timeout=60) for n, f in futures.items()}
        other_ids, _ = other.result(timeout=60)
        dt = time.monotonic() - t0

    print(f"\nasync resolved {len(async_results) + 1} searches "
          f"in {dt * 1e3:.1f} ms")
    for name in names:
        same = (async_results[name][0] == results[names.index(name)][0]).all()
        print(f"  {name}: async ranking identical to sync: {bool(same)}")
    print(f"  B for terms (5, 9): docs {other_ids.tolist()}")

    st = engine.stats
    print(f"\nflushes by reason: {dict(st.flushes)}")
    print(f"engine calls: {st.batched_calls} batched + {st.single_calls} "
          f"single for {st.queries} sync + {st.submitted} async queries "
          f"(max queue depth {st.max_queue_depth})")


if __name__ == "__main__":
    main()
