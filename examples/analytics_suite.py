"""Run all six analytics over the Table-II-analogue datasets, with the
traversal-strategy selector's decision per dataset (paper §VI-C).

    PYTHONPATH=src python examples/analytics_suite.py
"""

import time

import numpy as np

from repro.core import (inverted_index, ranked_inverted_index, select_direction,
                        sequence_count, sort_words, term_vector, word_count)
from repro.data import CompressedCorpus, synthetic


def main() -> None:
    for name in ("A", "B", "C", "D", "E"):
        spec = synthetic.TABLE2[name]
        files = synthetic.make_table2_corpus(name)
        cc = CompressedCorpus.build(files, vocab_size=spec.vocab)
        ga = cc.ga
        s = cc.stats()
        print(f"\n=== dataset {name}: {s['tokens']} tokens, "
              f"{s['files']} files, {s['rules']} rules, "
              f"ratio {s['compression_ratio']:.2f}x, depth {s['dag_depth']} "
              f"-> selector: {select_direction(ga)}")
        for app, fn in [
            ("word_count", lambda: np.asarray(word_count(ga))),
            ("sort", lambda: np.asarray(sort_words(ga)[1])),
            ("term_vector", lambda: np.asarray(term_vector(ga))),
            ("inverted_index", lambda: np.asarray(inverted_index(ga))),
            ("ranked_inverted_index",
             lambda: np.asarray(ranked_inverted_index(ga)[0])),
            ("sequence_count", lambda: sequence_count(ga, l=3)),
        ]:
            t0 = time.perf_counter()
            out = fn()
            dt = time.perf_counter() - t0
            extra = ""
            if app == "word_count":
                extra = f" (total {int(out.sum())})"
            if app == "sequence_count":
                extra = f" ({len(out[1])} distinct 3-grams)"
            print(f"  {app:24s} {dt*1e3:8.1f} ms{extra}")


if __name__ == "__main__":
    main()
