"""End-to-end driver: train an LM from a TADOC-compressed corpus.

The full production flow, scaled to this container:
  1. build a corpus, compress it with Sequitur (stored compressed);
  2. compute vocab statistics directly on the compressed grammar;
  3. stream training batches via random-access window expansion
     (the corpus is never decompressed as a whole);
  4. train with AdamW + checkpointing + straggler watchdog (restart-safe:
     rerun the same command after a crash and it resumes exactly);
  5. generate a sample.

    PYTHONPATH=src python examples/train_tadoc_lm.py --steps 60
    PYTHONPATH=src python examples/train_tadoc_lm.py --steps 300 --size 100m
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import sort_words, word_count
from repro.data import BatchPipeline, CompressedCorpus, synthetic
from repro.models import init_lm, reduced, unbox
from repro.serving import greedy_generate
from repro.training import AdamW, StragglerWatchdog, train


def build_model(size: str, vocab: int):
    base = get_config("qwen2-0.5b")
    if size == "100m":      # ~100M-param class (slow on 1 CPU core)
        cfg = reduced(base, num_layers=8, d_model=512, num_heads=8,
                      num_kv_heads=4, head_dim=64, d_ff=2048,
                      vocab_size=vocab, dtype="float32")
    elif size == "10m":
        cfg = reduced(base, num_layers=4, d_model=192, num_heads=6,
                      num_kv_heads=2, head_dim=32, d_ff=768,
                      vocab_size=vocab, dtype="float32")
    else:                    # "tiny" default: seconds per run
        cfg = reduced(base, num_layers=2, d_model=64, d_ff=256,
                      vocab_size=vocab, dtype="float32")
    return cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--size", default="tiny", choices=["tiny", "10m", "100m"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/tadoc_lm_ckpt")
    args = ap.parse_args()

    # 1-2: compressed corpus + compressed-domain stats
    files = synthetic.make_table2_corpus("E")
    vocab = synthetic.TABLE2["E"].vocab
    cc = CompressedCorpus.build(files, vocab_size=vocab)
    print("corpus:", cc.stats())
    counts = np.asarray(word_count(cc.ga))
    order, cnts = sort_words(cc.ga)
    print(f"vocab stats from compressed data: top word id "
          f"{int(order[0])} x{int(cnts[0])}, "
          f"{int((counts > 0).sum())} distinct words")

    # 3: batches by random access
    pipeline = BatchPipeline(cc, global_batch=args.batch, seq_len=args.seq,
                             seed=0, prefetch=2)

    # 4: train (restart-safe; rerun to resume)
    cfg = build_model(args.size, vocab + 1)
    params, _ = unbox(init_lm(jax.random.PRNGKey(0), cfg))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {cfg.name} {n_params/1e6:.1f}M params")
    wd = StragglerWatchdog(on_straggler=lambda s, dt, ema: print(
        f"[watchdog] step {s} took {dt:.2f}s (ema {ema:.2f}s)"))
    out = train(cfg, params, AdamW(lr=3e-3, warmup_steps=10,
                                   schedule="cosine",
                                   total_steps=args.steps),
                pipeline, steps=args.steps, ckpt_dir=args.ckpt_dir,
                ckpt_every=25, watchdog=wd)
    print(f"loss: {out['history'][0]:.3f} -> {out['history'][-1]:.3f}")

    # 5: generate
    prompt = jnp.asarray(pipeline.batch_at(0)[0][:2, :16])
    gen = greedy_generate(cfg, out["params"], prompt, steps=12)
    print("generated ids:", np.asarray(gen).tolist())
    pipeline.close()


if __name__ == "__main__":
    main()
