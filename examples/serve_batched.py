"""Batched serving demo: KV-cache decode over a batch of requests.

    PYTHONPATH=src python examples/serve_batched.py --batch 8 --steps 24
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_cache, init_lm, reduced, unbox
from repro.serving import make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), dtype="float32")
    params, _ = unbox(init_lm(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32))

    max_len = args.prompt_len + args.steps
    cache = init_cache(cfg, args.batch, max_len)
    step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    # prefill (token-by-token at demo scale), then timed decode
    tok = None
    for t in range(args.prompt_len):
        tok, cache, _ = step(params, cache, prompts[:, t:t + 1])
    jax.block_until_ready(tok)
    t0 = time.perf_counter()
    outs = []
    for _ in range(args.steps):
        outs.append(tok)
        tok, cache, _ = step(params, cache, tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    tput = args.batch * args.steps / dt
    print(f"{args.arch} (reduced): batch={args.batch} "
          f"decode {args.steps} steps in {dt*1e3:.0f} ms "
          f"-> {tput:.0f} tok/s")
    print("sampled ids (first request):",
          [int(o[0, 0]) for o in outs][:12])


if __name__ == "__main__":
    main()
