"""Quickstart: TADOC in 60 seconds.

Compress a tiny text corpus with Sequitur, then run all six analytics
DIRECTLY ON THE COMPRESSED DATA — no decompression anywhere.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (flatten, compress_files, word_count, sort_words,
                        term_vector, inverted_index, ranked_inverted_index,
                        sequence_count, select_direction)
from repro.data import Tokenizer

DOCS = [
    "the quick brown fox jumps over the lazy dog . "
    "the quick brown fox likes the lazy dog .",
    "a lazy dog sleeps all day . the quick brown fox jumps again "
    "and again and again .",
    "the dog and the fox are friends . the quick brown fox jumps "
    "over the lazy dog once more .",
]


def main() -> None:
    tok = Tokenizer()
    files = [tok.encode(d) for d in DOCS]
    V = tok.vocab_size

    g, nf = compress_files(files, V)
    ga = flatten(g, V, nf)
    print(f"corpus: {sum(map(len, files))} tokens, {nf} files, vocab {V}")
    print(f"grammar: {ga.num_rules} rules, {ga.body.shape[0]} symbols, "
          f"ratio {ga.compression_ratio():.2f}x, depth {ga.num_levels}")
    print(f"selector picks: {select_direction(ga)}\n")

    wc = np.asarray(word_count(ga))
    order, cnts = sort_words(ga)
    print("top words (sort + word_count):")
    for i in range(5):
        w = tok.id_to_word[int(order[i])]
        print(f"  {w!r}: {int(cnts[i])}")

    tv = np.asarray(term_vector(ga))
    ii = np.asarray(inverted_index(ga))
    fox = tok.word_to_id["fox"]
    print(f"\n'fox' per file (term_vector): {tv[:, fox].astype(int)}")
    print(f"'fox' in files (inverted_index): {np.where(ii[:, fox])[0]}")
    rank, rcnt = ranked_inverted_index(ga)
    print(f"'fox' files ranked by freq: {np.asarray(rank)[fox].tolist()}")

    grams, gcnt = sequence_count(ga, l=3)
    top = np.argsort(-gcnt)[:3]
    print("\ntop 3-grams (sequence_count, head/tail cross-rule support):")
    for i in top:
        words = " ".join(tok.id_to_word[int(w)] for w in grams[i])
        print(f"  {words!r}: {int(gcnt[i])}")

    # verify against direct computation
    direct = np.bincount(np.concatenate(files), minlength=V)
    assert np.allclose(wc, direct), "compressed != direct?!"
    print("\n[verified: compressed-domain results == direct counts]")


if __name__ == "__main__":
    main()
