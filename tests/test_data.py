"""Data plane: tokenizer, store roundtrip, random access, pipeline."""

import os
import tempfile

import numpy as np

from repro.core import word_count
from repro.data import (BatchPipeline, CompressedCorpus, Tokenizer,
                        synthetic)


def test_tokenizer_roundtrip(tmp_path):
    tok = Tokenizer()
    ids = tok.encode("the cat sat on the mat . the cat !")
    assert ids[0] == ids[4] == ids[7]       # "the"
    tok.save(str(tmp_path / "tok.json"))
    tok2 = Tokenizer.load(str(tmp_path / "tok.json"))
    assert tok2.decode(ids) == "the cat sat on the mat . the cat !"
    assert tok2.encode("unseen")[0] == 0     # frozen -> <unk>


def test_vocab_from_tadoc_counts():
    words = ["a", "b", "c"]
    counts = np.array([5, 50, 1])
    tok = Tokenizer.from_tadoc_counts(words, counts)
    assert tok.word_to_id["b"] < tok.word_to_id["a"] < tok.word_to_id["c"]


def test_store_roundtrip_and_window(tmp_path):
    files = synthetic.make_table2_corpus("D")
    cc = CompressedCorpus.build(files, vocab_size=400)
    p = str(tmp_path / "c.npz")
    cc.save(p)
    cc2 = CompressedCorpus.load(p)
    assert cc2.stats() == cc.stats()
    assert cc.stats()["compression_ratio"] > 1.2
    w = cc2.window(0, 37, 50)
    assert (w == files[0][37:87]).all()


def test_analytics_on_store():
    files = synthetic.make_table2_corpus("A")
    cc = CompressedCorpus.build(files, vocab_size=1200)
    wc = np.asarray(word_count(cc.ga))
    oracle = np.bincount(np.concatenate(files), minlength=1200)
    assert np.allclose(wc, oracle)


def test_pipeline_determinism_and_sharding():
    files = synthetic.make_table2_corpus("D")
    cc = CompressedCorpus.build(files, vocab_size=400)
    kw = dict(global_batch=8, seq_len=32, seed=7, prefetch=0)
    full = BatchPipeline(cc, **kw)
    s0 = BatchPipeline(cc, shard=0, num_shards=2, **kw)
    s1 = BatchPipeline(cc, shard=1, num_shards=2, **kw)
    xf, yf = full.batch_at(5)
    x0, _ = s0.batch_at(5)
    x1, _ = s1.batch_at(5)
    assert (np.concatenate([x0, x1]) == xf).all()
    assert (xf[:, 1:] == yf[:, :-1]).all()          # labels = next token
    # same (seed, step) -> identical batch, independent of history
    xf2, _ = BatchPipeline(cc, **kw).batch_at(5)
    assert (xf2 == xf).all()


def test_pipeline_iterator_prefetch():
    files = synthetic.make_table2_corpus("D")
    cc = CompressedCorpus.build(files, vocab_size=400)
    pl = BatchPipeline(cc, global_batch=4, seq_len=16, seed=1, prefetch=2)
    it = iter(pl)
    b0 = next(it)
    b1 = next(it)
    assert b0[0].shape == (4, 16) and b1[0].shape == (4, 16)
    x0, _ = pl.batch_at(0)
    assert (b0[0] == x0).all()
    pl.close()


def test_synthetic_table2_shapes():
    for name, spec in synthetic.TABLE2.items():
        files = synthetic.make_table2_corpus(name)
        assert len(files) == spec.n_files
        assert all(len(f) == spec.tokens_per_file for f in files)
