"""Data plane: tokenizer, store roundtrip, random access, pipeline."""

import dataclasses

import numpy as np
import pytest

from repro.core import word_count
from repro.data import (BatchPipeline, CompressedCorpus, Tokenizer,
                        synthetic)


def test_tokenizer_roundtrip(tmp_path):
    tok = Tokenizer()
    ids = tok.encode("the cat sat on the mat . the cat !")
    assert ids[0] == ids[4] == ids[7]       # "the"
    tok.save(str(tmp_path / "tok.json"))
    tok2 = Tokenizer.load(str(tmp_path / "tok.json"))
    assert tok2.decode(ids) == "the cat sat on the mat . the cat !"
    assert tok2.encode("unseen")[0] == 0     # frozen -> <unk>


def test_vocab_from_tadoc_counts():
    words = ["a", "b", "c"]
    counts = np.array([5, 50, 1])
    tok = Tokenizer.from_tadoc_counts(words, counts)
    assert tok.word_to_id["b"] < tok.word_to_id["a"] < tok.word_to_id["c"]


def test_store_roundtrip_and_window(tmp_path):
    files = synthetic.make_table2_corpus("D")
    cc = CompressedCorpus.build(files, vocab_size=400)
    p = str(tmp_path / "c.npz")
    cc.save(p)
    cc2 = CompressedCorpus.load(p)
    assert cc2.stats() == cc.stats()
    assert cc.stats()["compression_ratio"] > 1.2
    w = cc2.window(0, 37, 50)
    assert (w == files[0][37:87]).all()


def test_window_bounds_are_validated():
    """Regression: offset past the file end used to compute a negative
    length (np.empty crash) and a negative offset silently expanded the
    PREVIOUS file's tokens — both must raise, clearly."""
    files = synthetic.make_table2_corpus("A")     # multi-file corpus
    cc = CompressedCorpus.build(files, vocab_size=1200)
    flen = int(cc.file_lens[1])
    # interior reads still work, including the clamped tail ...
    assert (cc.window(1, flen - 5, 50) == files[1][flen - 5:]).all()
    # ... and the offset == file_len edge is an empty window, not an error
    assert cc.window(1, flen, 10).size == 0
    with pytest.raises(ValueError):
        cc.window(1, flen + 1, 10)          # past the end
    with pytest.raises(ValueError):
        cc.window(1, -3, 10)                # would read file 0's tokens
    with pytest.raises(ValueError):
        cc.window(1, 0, -1)                 # negative length
    with pytest.raises(IndexError):
        cc.window(len(cc.file_lens), 0, 1)  # no such file
    with pytest.raises(IndexError):
        cc.window(-1, 0, 1)


def test_global_window_bounds_are_validated():
    files = synthetic.make_table2_corpus("D")
    cc = CompressedCorpus.build(files, vocab_size=400)
    total = int(cc.ga.exp_len[0])
    # the full stream expands (splitters included), tail clamps, end edge
    # is empty
    assert cc.global_window(0, total).size == total
    assert cc.global_window(total - 3, 10).size == 3
    assert cc.global_window(total, 10).size == 0
    with pytest.raises(ValueError):
        cc.global_window(total + 1, 1)
    with pytest.raises(ValueError):
        cc.global_window(-1, 5)             # used to read from offset 0
    with pytest.raises(ValueError):
        cc.global_window(0, -2)


def test_store_roundtrip_preserves_every_array_field(tmp_path):
    """Regression: _ARRAY_FIELDS used to string-compare dataclass
    annotations (`f.type == "np.ndarray"`), so an annotation-style change
    silently dropped arrays from save/load.  Assert the field selection
    covers exactly the ndarray fields and that each one round-trips."""
    from repro.data.store import _ARRAY_FIELDS, _META_FIELDS
    files = synthetic.make_table2_corpus("D")
    cc = CompressedCorpus.build(files, vocab_size=400)
    array_fields = {f.name for f in dataclasses.fields(cc.ga)
                    if isinstance(getattr(cc.ga, f.name), np.ndarray)}
    assert set(_ARRAY_FIELDS) == array_fields
    assert set(_META_FIELDS) == {
        f.name for f in dataclasses.fields(cc.ga)} - array_fields
    p = str(tmp_path / "c.npz")
    cc.save(p)
    cc2 = CompressedCorpus.load(p)
    for name in _ARRAY_FIELDS:
        a, b = getattr(cc.ga, name), getattr(cc2.ga, name)
        assert a.dtype == b.dtype and a.shape == b.shape, name
        assert (a == b).all(), f"array field {name} did not survive"
    for name in _META_FIELDS:
        assert getattr(cc.ga, name) == getattr(cc2.ga, name), name
    assert (cc2.file_starts == cc.file_starts).all()
    assert (cc2.file_lens == cc.file_lens).all()


def test_analytics_on_store():
    files = synthetic.make_table2_corpus("A")
    cc = CompressedCorpus.build(files, vocab_size=1200)
    wc = np.asarray(word_count(cc.ga))
    oracle = np.bincount(np.concatenate(files), minlength=1200)
    assert np.allclose(wc, oracle)


def test_pipeline_determinism_and_sharding():
    files = synthetic.make_table2_corpus("D")
    cc = CompressedCorpus.build(files, vocab_size=400)
    kw = dict(global_batch=8, seq_len=32, seed=7, prefetch=0)
    full = BatchPipeline(cc, **kw)
    s0 = BatchPipeline(cc, shard=0, num_shards=2, **kw)
    s1 = BatchPipeline(cc, shard=1, num_shards=2, **kw)
    xf, yf = full.batch_at(5)
    x0, _ = s0.batch_at(5)
    x1, _ = s1.batch_at(5)
    assert (np.concatenate([x0, x1]) == xf).all()
    assert (xf[:, 1:] == yf[:, :-1]).all()          # labels = next token
    # same (seed, step) -> identical batch, independent of history
    xf2, _ = BatchPipeline(cc, **kw).batch_at(5)
    assert (xf2 == xf).all()


def test_pipeline_iterator_prefetch():
    files = synthetic.make_table2_corpus("D")
    cc = CompressedCorpus.build(files, vocab_size=400)
    pl = BatchPipeline(cc, global_batch=4, seq_len=16, seed=1, prefetch=2)
    it = iter(pl)
    b0 = next(it)
    b1 = next(it)
    assert b0[0].shape == (4, 16) and b1[0].shape == (4, 16)
    x0, _ = pl.batch_at(0)
    assert (b0[0] == x0).all()
    pl.close()


def test_synthetic_table2_shapes():
    for name, spec in synthetic.TABLE2.items():
        files = synthetic.make_table2_corpus(name)
        assert len(files) == spec.n_files
        assert all(len(f) == spec.tokens_per_file for f in files)
