"""DAG traversal engines: frontier == leveled == pallas-ELL == oracle."""

import numpy as np
import pytest

from repro.core import (compress_files, flatten, top_down_weights,
                        per_file_weights, bottom_up_tables, bottom_up_bounds,
                        traversal_rounds)
from conftest import make_repetitive_files


@pytest.fixture(params=[0, 1, 2])
def ga(request):
    rng = np.random.default_rng(request.param)
    vocab = int(rng.integers(8, 25))
    files = make_repetitive_files(rng, vocab, n_files=int(rng.integers(1, 5)))
    g, nf = compress_files(files, vocab)
    return flatten(g, vocab, nf), files, g


def _oracle_weights(ga):
    occ = np.zeros(ga.num_rules)
    occ[0] = 1
    for lv in range(ga.num_levels):
        for r in np.where(ga.level == lv)[0]:
            b = ga.rule_body(r)
            subs = b[b >= ga.num_terminals] - ga.num_terminals
            u, c = np.unique(subs, return_counts=True)
            for uu, cc in zip(u, c):
                occ[uu] += cc * occ[r]
    return occ


def test_engines_agree(ga):
    ga, files, g = ga
    oracle = _oracle_weights(ga)
    for method in ("frontier", "leveled", "frontier_ell", "leveled_ell",
                   "frontier_fused"):
        w = np.asarray(top_down_weights(ga, method))
        assert np.allclose(w, oracle), method


def test_per_file_engines_agree(ga):
    """The per-file ELL engines (vector-payload rounds) == segment_sum
    bases; frontier_fused runs its per-round ELL base per-file."""
    ga, _, _ = ga
    want = np.asarray(per_file_weights(ga, "frontier"))
    for method in ("leveled", "frontier_ell", "leveled_ell",
                   "frontier_fused"):
        got = np.asarray(per_file_weights(ga, method))
        np.testing.assert_allclose(got, want, rtol=1e-6, err_msg=method)


def test_rounds_equal_dag_depth(ga):
    ga, _, _ = ga
    assert traversal_rounds(ga) == ga.num_levels


def test_bottom_up_matches_top_down(ga):
    ga, files, g = ga
    full = g.expand()
    words = full[full < ga.vocab_size]
    oracle = np.bincount(words, minlength=ga.vocab_size)
    _, result = bottom_up_tables(ga)
    assert np.allclose(np.asarray(result), oracle)


def test_bounds_dominate_actual(ga):
    ga, _, _ = ga
    C, _ = bottom_up_tables(ga)
    actual = (np.asarray(C) > 0).sum(axis=1)
    bounds = np.asarray(bottom_up_bounds(ga))
    assert (bounds >= actual - 1e-6).all()


def test_per_file_weights_sum_to_global(ga):
    ga, _, _ = ga
    Wf = np.asarray(per_file_weights(ga))
    w = np.asarray(top_down_weights(ga))
    # per-file weights sum over files to the global weights (excluding root)
    assert np.allclose(Wf.sum(axis=1)[1:], w[1:])
