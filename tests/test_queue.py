"""Async deadline-aware queue: flush policy properties + sync equivalence.

The invariants fuzzed here (tests run without hypothesis via
_hypothesis_compat):

* no submitted query ever starves — every future resolves (with a result
  or :class:`DeadlineExceeded`), and a query that resolves with a result
  under a deadline did so no later than ``deadline + one poll interval``
  of simulated time;
* a query whose deadline has already passed at flush time is shed: its
  future raises ``DeadlineExceeded``, the engine is never asked for it,
  and ``stats.shed`` / ``FlushEvent.n_shed`` account for every shed
  exactly once;
* no flush ever packs more than ``max_batch`` distinct corpora, and every
  flush carries exactly one (kind, l) group;
* the async path is bit-identical to a one-shot synchronous
  ``AnalyticsServer.run`` of the same queries for every non-shed result,
  whatever the arrival order, deadlines, duplicates, shed mix, and flush
  interleaving.

Time is fully simulated (``clock=`` injection): the trace loop drives
:meth:`AsyncAnalyticsServer.poll` on a fixed tick grid, so runs reproduce
exactly from the conftest-logged seed.
"""

import threading

import numpy as np
import pytest

from repro.core import compress_files, flatten, word_count
from repro.serving import (AnalyticsServer, AsyncAnalyticsServer,
                           DeadlineExceeded, Query, QueueFull)
from _hypothesis_compat import given, settings, st
from _oracle import assert_result_equal
from conftest import make_repetitive_files

MAX_BATCH = 3
POLL_DT = 0.005


class SimClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _build_engine(n_corpora=6, max_batch=MAX_BATCH, seed=1234):
    rng = np.random.default_rng(seed)
    eng = AnalyticsServer(max_batch=max_batch)
    for i in range(n_corpora):
        vocab = int(rng.integers(8, 28))
        files = make_repetitive_files(rng, vocab,
                                      n_files=int(rng.integers(1, 4)))
        g, nf = compress_files(files, vocab)
        eng.register(f"c{i}", flatten(g, vocab, nf))
    return eng


_ENGINE = None


def _shared_engine():
    """One engine for the whole module: packs/compilations are reused, and
    @given-wrapped tests cannot take fixtures under the no-hypothesis
    fallback."""
    global _ENGINE
    if _ENGINE is None:
        _ENGINE = _build_engine()
    return _ENGINE


def _assert_same(got, want):
    assert_result_equal(got, want, "async-vs-sync")


# --------------------------------------------------------------- policy --
def test_submit_validates_before_queueing():
    eng = _shared_engine()
    aq = AsyncAnalyticsServer(eng, clock=SimClock())
    with pytest.raises(KeyError):
        aq.submit(Query("nope", "word_count"))
    with pytest.raises(ValueError):
        aq.submit(Query("c0", "nope"))
    assert aq.queue_depth == 0


def test_full_group_flushes_on_submit():
    eng = _shared_engine()
    clk = SimClock()
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0, clock=clk)
    futs = [aq.submit(Query(f"c{i}", "word_count")) for i in range(MAX_BATCH)]
    assert all(f.done() for f in futs)          # no poll needed
    assert aq.queue_depth == 0
    ev = aq.flush_log[-1]
    assert ev.reason == "max_batch" and ev.n_corpora == MAX_BATCH
    for i, f in enumerate(futs):
        _assert_same(f.result(),
                     np.asarray(word_count(eng._corpora[f"c{i}"],
                                           method="frontier")))


def test_deadline_flush_fires_within_one_estimated_latency():
    eng = _build_engine(n_corpora=2, seed=7)    # fresh: empty latency EWMA
    clk = SimClock()
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0, default_latency=0.05,
                              clock=clk)
    fut = aq.submit(Query("c0", "word_count"), deadline=1.0)
    aq.poll()
    assert not fut.done()                       # 1.0 - 0.0 > 0.05
    clk.t = 0.9
    aq.poll()
    assert not fut.done()                       # 0.1 > 0.05
    clk.t = 0.96
    aq.poll()                                   # 0.04 <= estimate: due now
    assert fut.done()
    assert aq.flush_log[-1].reason == "deadline"


def test_expired_deadline_is_shed_not_executed():
    """A query whose deadline passed before its flush gets DeadlineExceeded
    and never reaches the engine; an expired singleton group therefore
    costs zero engine calls (but still logs its flush)."""
    eng = _build_engine(n_corpora=2, seed=41)
    clk = SimClock()
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0, clock=clk)
    fut = aq.submit(Query("c0", "word_count"), deadline=0.5)
    clk.t = 1.0                                 # deadline long gone
    calls_before = eng.stats.batched_calls + eng.stats.single_calls
    aq.poll()                                   # deadline condition fires
    assert fut.done()
    with pytest.raises(DeadlineExceeded):
        fut.result()
    assert eng.stats.batched_calls + eng.stats.single_calls == calls_before
    assert eng.stats.shed == 1
    ev = aq.flush_log[-1]
    assert ev.n_shed == 1 and ev.n_queries == 0 and ev.n_corpora == 0
    assert ev.reason == "deadline"


def test_partial_shed_keeps_live_results_bit_identical():
    """Shedding one group member must not disturb the others: live members
    execute and stay bit-identical to the sync path."""
    eng = _build_engine(n_corpora=3, seed=43)
    clk = SimClock()
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0, clock=clk)
    f_dead = aq.submit(Query("c0", "word_count"), deadline=0.1)
    f_live = aq.submit(Query("c1", "word_count"))
    f_dup = aq.submit(Query("c1", "word_count"))    # duplicate rides along
    clk.t = 0.5
    aq.drain()
    with pytest.raises(DeadlineExceeded):
        f_dead.result()
    want = eng.run([Query("c1", "word_count")])[0]
    _assert_same(f_live.result(), want)
    _assert_same(f_dup.result(), want)
    assert eng.stats.shed == 1
    ev = aq.flush_log[-1]
    assert ev.reason == "drain" and ev.n_shed == 1
    assert ev.n_queries == 2 and ev.n_corpora == 1


def test_deadline_exactly_at_flush_time_is_not_shed():
    """now == deadline is the boundary: only strictly-passed deadlines are
    shed (the contract is 'already expired', not 'about to expire')."""
    eng = _build_engine(n_corpora=2, seed=47)
    clk = SimClock()
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0, clock=clk)
    fut = aq.submit(Query("c0", "word_count"), deadline=0.5)
    clk.t = 0.5
    aq.drain()
    _assert_same(fut.result(),
                 eng.run([Query("c0", "word_count")])[0])
    assert eng.stats.shed == 0


def test_idle_flush_after_timeout():
    eng = _shared_engine()
    clk = SimClock()
    aq = AsyncAnalyticsServer(eng, idle_timeout=0.5, clock=clk)
    fut = aq.submit(Query("c0", "word_count"))
    clk.t = 0.4
    aq.poll()
    assert not fut.done()
    clk.t = 0.3                                 # new arrival resets idleness
    f2 = aq.submit(Query("c0", "sort"))
    clk.t = 0.55
    aq.poll()
    assert fut.done()                           # word_count group: idle
    assert aq.flush_log[-1].reason == "idle"
    clk.t = 0.85
    aq.poll()
    assert f2.done()


def test_sustained_stream_bounded_by_max_wait():
    """A same-corpus stream resets idleness on every arrival and never
    fills a pack; the oldest query must still flush within max_wait."""
    eng = _build_engine(n_corpora=2, seed=15)
    clk = SimClock()
    aq = AsyncAnalyticsServer(eng, idle_timeout=0.05, max_wait=0.2,
                              clock=clk)
    first = aq.submit(Query("c0", "word_count"))
    t = 0.0
    while t < 0.13:                             # arrivals every 0.04 < idle
        t += 0.04
        clk.t = t
        aq.poll()
        aq.submit(Query("c0", "word_count"))
        assert not first.done()
    clk.t = 0.20                                # idle not yet due; age is
    aq.poll()
    assert first.done()
    assert aq.flush_log[-1].reason == "max_wait"
    aq.close()


def test_cancelled_future_does_not_break_its_flush():
    """A caller cancelling a pending future must not starve the rest of
    the group or raise out of the flush path."""
    eng = _shared_engine()
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0, clock=SimClock())
    f_cancel = aq.submit(Query("c0", "word_count"))
    f_keep = aq.submit(Query("c1", "word_count"))
    assert f_cancel.cancel()
    aq.drain()                                  # must not raise
    assert f_keep.done() and not f_keep.cancelled()
    _assert_same(f_keep.result(),
                 np.asarray(word_count(eng._corpora["c1"],
                                       method="frontier")))
    assert f_cancel.cancelled()
    ev = aq.flush_log[-1]
    assert ev.n_queries == 1 and ev.n_corpora == 1
    # a fully-cancelled group flushes without touching the engine
    aq2 = AsyncAnalyticsServer(eng, idle_timeout=100.0, clock=SimClock())
    f_only = aq2.submit(Query("c0", "sort"))
    assert f_only.cancel()
    calls_before = eng.stats.batched_calls + eng.stats.single_calls
    aq2.drain()
    assert eng.stats.batched_calls + eng.stats.single_calls == calls_before
    assert aq2.flush_log[-1].n_queries == 0


def test_backpressure_rejects_when_full():
    """max_pending bounds the queue depth: overflowing submits raise
    QueueFull (counted on stats.rejected), space freed by a flush admits
    new traffic, and the high-water mark is recorded."""
    eng = _build_engine(n_corpora=4, seed=23)
    clk = SimClock()
    aq = AsyncAnalyticsServer(eng, idle_timeout=0.5, clock=clk,
                              max_pending=2)
    # distinct kinds -> distinct groups: nothing fills max_batch
    f1 = aq.submit(Query("c0", "word_count"))
    f2 = aq.submit(Query("c1", "sort"))
    assert aq.queue_depth == 2
    with pytest.raises(QueueFull):
        aq.submit(Query("c2", "term_vector"))
    assert eng.stats.rejected == 1
    assert not f1.done() and not f2.done()      # rejection flushed nothing
    clk.t = 1.0
    aq.poll()                                   # idle flush frees the queue
    assert f1.done() and f2.done()
    f3 = aq.submit(Query("c2", "term_vector"))  # space again
    aq.drain()
    assert f3.done()
    assert eng.stats.max_queue_depth >= 2
    with pytest.raises(ValueError):
        AsyncAnalyticsServer(eng, max_pending=0)


def test_backpressure_block_waits_for_space():
    """submit(block=True) parks instead of raising and resumes as soon as
    a flush (driven elsewhere) frees queue depth."""
    eng = _build_engine(n_corpora=4, seed=29)
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0, clock=SimClock(),
                              max_pending=1)
    aq.submit(Query("c0", "word_count"))
    entered = threading.Event()
    futs = []

    def blocked_submit():
        entered.set()
        futs.append(aq.submit(Query("c1", "sort"), block=True))

    t = threading.Thread(target=blocked_submit)
    t.start()
    entered.wait(5)
    assert t.is_alive()                         # parked on the full queue
    aq.drain()                                  # frees space -> unblocks
    t.join(timeout=10)
    assert not t.is_alive() and len(futs) == 1
    aq.drain()
    assert futs[0].done()
    _assert_same(futs[0].result(),
                 eng.run([Query("c1", "sort")])[0])
    assert eng.stats.rejected == 0              # block never rejects


def test_backpressure_blocked_submit_raises_on_close():
    eng = _build_engine(n_corpora=2, seed=31)
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0, clock=SimClock(),
                              max_pending=1)
    aq.submit(Query("c0", "word_count"))
    raised = threading.Event()

    def blocked_submit():
        try:
            aq.submit(Query("c1", "word_count"), block=True)
        except RuntimeError:
            raised.set()

    t = threading.Thread(target=blocked_submit)
    t.start()
    # close() must wake the blocked submit and fail it, never hang it
    import time as _time
    _time.sleep(0.05)
    aq.close()
    t.join(timeout=10)
    assert raised.is_set()


def test_close_races_many_blocked_submits_under_running_thread():
    """Lifecycle race: several submits parked on max_pending while the
    background thread is live and another thread calls close().  Every
    blocked submit must resolve — either admitted-and-drained by close()
    or failed with RuntimeError — and nothing may hang."""
    eng = _build_engine(n_corpora=4, seed=37)
    # idle_timeout generous: blocked submits wait on close(), not a flush
    aq = AsyncAnalyticsServer(eng, idle_timeout=60.0, poll_interval=0.001,
                              max_pending=1).start()
    aq.submit(Query("c0", "word_count"))
    outcomes = []
    started = threading.Barrier(4)

    def blocked_submit(i):
        started.wait(5)
        try:
            outcomes.append(("ok", aq.submit(Query(f"c{i}", "sort"),
                                             block=True)))
        except RuntimeError:
            outcomes.append(("raised", None))

    threads = [threading.Thread(target=blocked_submit, args=(i,))
               for i in range(1, 4)]
    for t in threads:
        t.start()
    started.wait(5)
    import time as _time
    _time.sleep(0.05)                   # let them reach the wait
    aq.close()
    for t in threads:
        t.join(timeout=10)
        assert not t.is_alive(), "blocked submit hung across close()"
    assert len(outcomes) == 3
    # admitted submits were drained by close(); the rest raised
    for tag, fut in outcomes:
        assert tag == "raised" or fut.done()


def test_fully_cancelled_group_logs_flush_without_engine_call():
    """A flush whose every future was cancel()ed must not call the engine
    but must still log the flush (the observability ring stays complete) —
    here via the poll path, not drain."""
    eng = _build_engine(n_corpora=3, seed=53)
    clk = SimClock()
    aq = AsyncAnalyticsServer(eng, idle_timeout=0.5, clock=clk)
    f1 = aq.submit(Query("c0", "word_count"))
    f2 = aq.submit(Query("c0", "word_count"))   # same group, same corpus
    assert f1.cancel() and f2.cancel()
    calls_before = eng.stats.batched_calls + eng.stats.single_calls
    log_before = len(aq.flush_log)
    clk.t = 1.0
    aq.poll()                                   # idle flush of a dead group
    assert eng.stats.batched_calls + eng.stats.single_calls == calls_before
    assert len(aq.flush_log) == log_before + 1
    ev = aq.flush_log[-1]
    assert ev.reason == "idle" and ev.n_queries == 0 and ev.n_corpora == 0
    assert aq.queue_depth == 0


def test_submit_after_close_raises_instead_of_hanging():
    eng = _shared_engine()
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0, clock=SimClock())
    fut = aq.submit(Query("c0", "word_count"))
    aq.close()
    assert fut.done()                           # close drains
    with pytest.raises(RuntimeError):
        aq.submit(Query("c0", "word_count"))
    with pytest.raises(RuntimeError):
        aq.start()
    aq.close()                                  # idempotent


def test_poll_returns_next_trigger_time():
    eng = _build_engine(n_corpora=2, seed=9)
    clk = SimClock()
    aq = AsyncAnalyticsServer(eng, idle_timeout=1.0, default_latency=0.1,
                              clock=clk)
    assert aq.poll() is None
    aq.submit(Query("c0", "word_count"))        # idle trigger at 1.0
    nxt = aq.poll()
    assert nxt == pytest.approx(1.0)
    aq.submit(Query("c1", "word_count"), deadline=0.5)
    nxt = aq.poll()                             # deadline - estimate = 0.4
    assert nxt == pytest.approx(0.4)


def test_drain_and_close_leave_nothing_pending():
    eng = _shared_engine()
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0, clock=SimClock())
    futs = [aq.submit(Query("c0", "word_count")),
            aq.submit(Query("c1", "sequence_count", l=2))]
    assert aq.queue_depth == 2
    aq.close()                                  # no thread started: drains
    assert aq.queue_depth == 0
    assert all(f.done() for f in futs)
    assert aq.stats.flushes.get("drain", 0) >= 1


def test_queue_counters():
    eng = _build_engine(n_corpora=3, seed=11)
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0, clock=SimClock())
    aq.submit(Query("c0", "word_count"))
    aq.submit(Query("c1", "word_count"))
    assert eng.stats.submitted == 2
    assert eng.stats.max_queue_depth >= 2
    aq.drain()
    assert sum(eng.stats.flushes.values()) >= 1


# ------------------------------------------------------ sync equivalence --
def _mixed_queries(rng, eng, n):
    kinds = ("word_count", "sort", "term_vector", "inverted_index",
             "ranked_inverted_index", "sequence_count")
    names = eng.corpora()
    out = []
    for _ in range(n):
        kind = kinds[int(rng.integers(len(kinds)))]
        out.append(Query(names[int(rng.integers(len(names)))], kind,
                         l=int(rng.integers(2, 5))))
    return out


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 100_000))
def test_fuzz_policy_never_starves_and_matches_sync(seed):
    rng = np.random.default_rng(seed)
    eng = _shared_engine()
    clk = SimClock()
    aq = AsyncAnalyticsServer(eng, idle_timeout=4 * POLL_DT,
                              default_latency=POLL_DT, clock=clk)
    shed_before = eng.stats.shed
    queries = _mixed_queries(rng, eng, n=int(rng.integers(6, 16)))
    arrivals = np.cumsum(rng.exponential(POLL_DT, len(queries)))
    # deadline mix: none / feasible / already expired at submission (the
    # expired ones MUST be shed — they grow the shed path's fuzz coverage)
    deadlines = []
    for at in arrivals:
        r = rng.random()
        if r < 0.4:
            deadlines.append(None)
        elif r < 0.8:
            deadlines.append(float(at) + float(rng.uniform(POLL_DT,
                                                           10 * POLL_DT)))
        else:
            deadlines.append(float(at) - float(rng.uniform(0.1 * POLL_DT,
                                                           5 * POLL_DT)))

    futs = [None] * len(queries)
    done_at = {}
    i = 0
    tick = 0.0
    horizon = float(arrivals[-1]) + 100 * POLL_DT
    while len(done_at) < len(queries):
        next_tick = tick + POLL_DT
        if i < len(queries) and arrivals[i] <= next_tick:
            clk.t = float(arrivals[i])
            futs[i] = aq.submit(queries[i], deadline=deadlines[i])
            i += 1
        else:
            tick = next_tick
            clk.t = tick
            aq.poll()
        for j, f in enumerate(futs):
            if f is not None and j not in done_at and f.done():
                done_at[j] = clk.t
        assert clk.t <= horizon, "queries starved past the horizon"

    shed = [j for j, f in enumerate(futs)
            if f.exception() is not None]
    # (1) nothing starves: every future resolved; every query that
    # resolved WITH a result under a deadline met it within one tick
    for j, dl in enumerate(deadlines):
        if dl is not None and j not in shed:
            assert done_at[j] <= dl + POLL_DT + 1e-9, (
                f"query {j} finished {done_at[j]:.4f}, "
                f"deadline {dl:.4f} + tick {POLL_DT}")
    # (2) sheds are genuine and fully accounted: only deadline-carrying
    # queries shed, expired-at-submit deadlines always shed, exceptions
    # are DeadlineExceeded, and the counters agree with the futures
    for j in shed:
        assert deadlines[j] is not None
        assert isinstance(futs[j].exception(), DeadlineExceeded)
    for j, (at, dl) in enumerate(zip(arrivals, deadlines)):
        if dl is not None and dl < float(at):
            assert j in shed, f"expired-at-submit query {j} not shed"
    assert eng.stats.shed - shed_before == len(shed)
    assert sum(ev.n_shed for ev in aq.flush_log) == len(shed)
    # (3) flushes respect max_batch and are single-group
    for ev in aq.flush_log:
        assert ev.n_corpora <= eng.max_batch
        assert ev.kind in ("word_count", "sort", "term_vector",
                           "inverted_index", "ranked_inverted_index",
                           "sequence_count")
        assert (ev.l is None) == (ev.kind != "sequence_count")
    # (4) every non-shed result is bit-identical to the one-shot sync run
    # of the same query list (differential equivalence under shedding)
    want = eng.run(queries)
    for j, (f, w) in enumerate(zip(futs, want)):
        if j not in shed:
            _assert_same(f.result(), w)


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 100_000))
def test_fuzz_burst_submission_then_drain_matches_sync(seed):
    """Degenerate arrival pattern: everything at t=0, no polls, then drain
    (covers pure max_batch + drain flushing)."""
    rng = np.random.default_rng(seed)
    eng = _shared_engine()
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0, clock=SimClock())
    queries = _mixed_queries(rng, eng, n=int(rng.integers(4, 12)))
    futs = [aq.submit(q) for q in queries]
    aq.drain()
    assert all(f.done() for f in futs)
    for ev in aq.flush_log:
        assert ev.n_corpora <= eng.max_batch
    want = eng.run(queries)
    for f, w in zip(futs, want):
        _assert_same(f.result(), w)


def test_flush_groups_by_size_bucket():
    """Corpora in different grammar-size buckets never share a flush (the
    pack would pad everyone to the biggest member)."""
    rng = np.random.default_rng(3)
    eng = AnalyticsServer(max_batch=4)
    small = make_repetitive_files(rng, 10, n_files=1)
    g, nf = compress_files(small, 10)
    eng.register("small", flatten(g, 10, nf))
    from repro.data.synthetic import CorpusSpec, make_corpus
    big_files = make_corpus(CorpusSpec("big", n_files=4, tokens_per_file=900,
                                       vocab=300, phrase_rate=0.5,
                                       n_phrases=25, phrase_len=7, seed=5))
    g2, nf2 = compress_files(big_files, 300)
    eng.register("big", flatten(g2, 300, nf2))
    assert eng.size_bucket("small") != eng.size_bucket("big")
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0, clock=SimClock())
    fa = aq.submit(Query("small", "word_count"))
    fb = aq.submit(Query("big", "word_count"))
    aq.drain()
    assert fa.done() and fb.done()
    assert len(aq.flush_log) == 2               # one flush per size bucket
    assert {ev.n_corpora for ev in aq.flush_log} == {1}


def test_threaded_serving_smoke():
    """Real clock + background thread: submissions resolve without manual
    polling and close() drains."""
    eng = _shared_engine()
    with AsyncAnalyticsServer(eng, idle_timeout=0.01,
                              poll_interval=0.002) as aq:
        f1 = aq.submit(Query("c0", "word_count"))
        f2 = aq.submit(Query("c1", "sequence_count", l=3))
        r1 = f1.result(timeout=60)
        r2 = f2.result(timeout=60)
    _assert_same(r1, eng.run([Query("c0", "word_count")])[0])
    _assert_same(r2, eng.run([Query("c1", "sequence_count", l=3)])[0])
    with pytest.raises(RuntimeError):
        aq2 = AsyncAnalyticsServer(eng)
        aq2.start()
        try:
            aq2.start()                          # double start
        finally:
            aq2.close()
