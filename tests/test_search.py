"""Compressed search subsystem: index statistics, ranking determinism,
serving integration, and parameter normalization.

Bit-level correctness against the decompress-then-scan oracle lives in
tests/test_differential.py (single / batched / sharded paths); this module
covers the subsystem's own contracts: SearchIndex statistics, the masked
top-k primitive's tie-breaking, memoization on the store and the pack,
query validation, and the serving-layer group-key normalization for the
new search parameters (the regression family next to the l-normalization
tests).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import GrammarBatch, compress_files, flatten
from repro.data import CompressedCorpus
from repro.kernels.ops import masked_top_k
from repro.search import (DEFAULT_TOP_K, SEARCH_KINDS, batch_search_stats,
                          batched_search, build_search_index,
                          normalize_terms, search_corpus, search_index_topk)
from repro.serving import (AnalyticsServer, AsyncAnalyticsServer, Query,
                           SERVED_KINDS)
from _hypothesis_compat import given, settings, st
from _oracle import oracle_search
from conftest import make_repetitive_files


def _mk(rng, vocab=None, n_files=None):
    vocab = vocab or int(rng.integers(10, 50))
    files = make_repetitive_files(rng, vocab,
                                  n_files=n_files or int(rng.integers(1, 5)))
    g, nf = compress_files(files, vocab)
    return flatten(g, vocab, nf), files


# ----------------------------------------------------------------- index --
def test_search_index_statistics_match_raw_files(seeded_rng):
    ga, files = _mk(seeded_rng)
    si = build_search_index(ga)
    assert si.n_docs == len(files) and si.vocab_size == ga.vocab_size
    tv = np.stack([np.bincount(f, minlength=ga.vocab_size)
                   for f in files]).astype(np.float32)
    np.testing.assert_array_equal(si.tf, tv)
    np.testing.assert_array_equal(si.dl,
                                  np.array([len(f) for f in files],
                                           np.float32))
    np.testing.assert_array_equal(si.df, (tv > 0).sum(0).astype(np.float32))
    assert si.avgdl > 0 and si.norm.shape == (len(files),)
    assert (si.norm > 0).all()


def test_search_index_memoized_on_store(seeded_rng):
    _, files = _mk(seeded_rng, vocab=20, n_files=3)
    cc = CompressedCorpus.build(files, vocab_size=20)
    si = cc.search_index()
    assert cc.search_index() is si                       # memoized
    assert ("search_index", "frontier") in cc.cached_weight_keys()
    # the index build shares the memoized per-file traversal
    assert ("per_file", "frontier") in cc.cached_weight_keys()
    # "auto" still collapses onto the frontier base; the ELL methods now
    # run their own vector-payload per-file traversal — a distinct memo
    # entry with bit-identical statistics (frontier_fused shares the
    # frontier_ell base: the fused kernel is scalar-payload)
    assert cc.search_index("auto") is si
    si_ell = cc.search_index("frontier_ell")
    assert si_ell is not si
    assert cc.search_index("frontier_ell") is si_ell     # memoized too
    assert cc.search_index("frontier_fused") is si_ell
    np.testing.assert_array_equal(si_ell.tf, si.tf)
    np.testing.assert_array_equal(si_ell.df, si.df)
    cc.clear_weight_cache()
    assert cc.cached_weight_keys() == ()


def test_batch_search_stats_memoized_on_pack(seeded_rng):
    gas = [_mk(seeded_rng)[0] for _ in range(3)]
    gb = GrammarBatch.build(gas)
    st = batch_search_stats(gb)
    assert batch_search_stats(gb) is st                  # memoized
    assert batch_search_stats(gb, "auto") is st          # same base
    # ELL methods keep their own (bit-identical) stats entry now that the
    # per-file traversal runs on the vector-payload ELL engines
    st_ell = batch_search_stats(gb, "frontier_ell")
    assert st_ell is not st
    assert batch_search_stats(gb, "frontier_fused") is st_ell
    np.testing.assert_array_equal(np.asarray(st_ell.tv), np.asarray(st.tv))
    for i, ga in enumerate(gas):
        si = build_search_index(ga)
        np.testing.assert_array_equal(st.df[i, : ga.vocab_size], si.df)
        assert int(st.nf[i]) == ga.num_files
        np.testing.assert_array_equal(
            np.asarray(st.norm)[i, : ga.num_files], si.norm)
        assert np.asarray(st.fvalid)[i].sum() == ga.num_files


# ---------------------------------------------------------- masked top-k --
def test_masked_top_k_ties_break_toward_lower_index():
    scores = jnp.asarray(np.array([[1.0, 3.0, 3.0, 0.5, 3.0]], np.float32))
    valid = jnp.ones((1, 5), bool)
    vals, idx = masked_top_k(scores, valid, 4)
    np.testing.assert_array_equal(np.asarray(idx)[0], [1, 2, 4, 0])
    np.testing.assert_array_equal(np.asarray(vals)[0], [3, 3, 3, 1])
    # masked slots lose to every finite score
    valid = jnp.asarray(np.array([[True, False, True, True, True]]))
    vals, idx = masked_top_k(scores, valid, 4)
    np.testing.assert_array_equal(np.asarray(idx)[0], [2, 4, 0, 3])
    with pytest.raises(ValueError):
        masked_top_k(scores, valid, 0)
    with pytest.raises(ValueError):
        masked_top_k(scores, valid, 6)


def test_masked_top_k_k_exceeds_valid_count():
    """k larger than the number of VALID slots is legal (only k > M is an
    error): the tail of the row is filled with -inf values whose indices
    walk the masked slots in ascending order (lax.top_k's lower-index
    tie-break over equal -inf)."""
    scores = jnp.asarray(np.array([[2.0, 7.0, 1.0, 5.0]], np.float32))
    valid = jnp.asarray(np.array([[False, True, False, True]]))
    vals, idx = masked_top_k(scores, valid, 4)
    np.testing.assert_array_equal(np.asarray(idx)[0], [1, 3, 0, 2])
    np.testing.assert_array_equal(np.asarray(vals)[0],
                                  [7.0, 5.0, -np.inf, -np.inf])
    # the retrieval layer's contract: everything past the valid count is
    # exactly -inf, so callers can trim on finiteness alone
    assert np.isfinite(np.asarray(vals)[0, :2]).all()


def test_masked_top_k_all_invalid_rows():
    """A row with zero valid slots must yield all--inf values (never a
    stale score) with the deterministic 0..k-1 index walk, and must not
    poison sibling rows in the same batch."""
    scores = jnp.asarray(np.array([[3.0, 1.0, 2.0],
                                   [9.0, 8.0, 7.0]], np.float32))
    valid = jnp.asarray(np.array([[False, False, False],
                                  [True, True, True]]))
    vals, idx = masked_top_k(scores, valid, 2)
    np.testing.assert_array_equal(np.asarray(vals)[0], [-np.inf, -np.inf])
    np.testing.assert_array_equal(np.asarray(idx)[0], [0, 1])
    np.testing.assert_array_equal(np.asarray(vals)[1], [9.0, 8.0])
    np.testing.assert_array_equal(np.asarray(idx)[1], [0, 1])


@given(st.lists(st.integers(0, 5), min_size=1, max_size=12),
       st.integers(1, 12))
@settings(deadline=None, max_examples=25)
def test_masked_top_k_tie_break_deterministic_under_permutation(ints, k):
    """Property: ties resolve toward the LOWER index, so sorting by
    (-value, index) is a complete oracle — including duplicated scores and
    any k up to the axis length."""
    m = len(ints)
    k = min(k, m)
    scores = np.asarray(ints, np.float32)[None]
    valid = (scores >= 1.0)          # 0-scores double as invalid slots
    vals, idx = masked_top_k(jnp.asarray(scores), jnp.asarray(valid), k)
    masked = np.where(valid[0], scores[0], -np.inf)
    order = np.lexsort((np.arange(m), -masked))[:k]
    np.testing.assert_array_equal(np.asarray(idx)[0], order)
    np.testing.assert_array_equal(np.asarray(vals)[0], masked[order])


# ------------------------------------------------------ ranking contracts --
def test_single_and_batched_rankings_bit_identical(seeded_rng):
    gas = [_mk(seeded_rng)[0] for _ in range(4)]
    gb = GrammarBatch.build(gas)
    terms = (1, 5, 5, 2, 10_000)        # duplicate + out-of-vocab
    for scheme in ("bm25", "tfidf"):
        got = batched_search(gb, terms, k=3, scheme=scheme)
        assert len(got) == 4
        for ga, (ids, sc) in zip(gas, got):
            s_ids, s_sc = search_corpus(ga, terms, k=3, scheme=scheme)
            np.testing.assert_array_equal(ids, s_ids)
            np.testing.assert_array_equal(sc, s_sc)
            assert len(ids) == min(3, ga.num_files)
            assert (np.diff(sc) <= 0).all()              # descending


def test_k_clamps_to_file_count_and_buckets_share_programs(seeded_rng):
    ga, files = _mk(seeded_rng, vocab=25, n_files=3)
    ids, sc = search_corpus(ga, (1, 2), k=50)
    assert len(ids) == 3 == len(sc)
    # k=50 ranks every file: the full ordering matches the oracle's
    want_ids, want_sc = oracle_search(ga, (1, 2), k=50)
    np.testing.assert_array_equal(ids, want_ids)
    np.testing.assert_array_equal(sc, want_sc)
    # nearby k values are a prefix of the same ranking
    ids1, sc1 = search_corpus(ga, (1, 2), k=2)
    np.testing.assert_array_equal(ids1, ids[:2])
    np.testing.assert_array_equal(sc1, sc[:2])


def test_zero_file_corpus_returns_empty_ranking():
    """A corpus with no files must rank to empty arrays on both the single
    and batched paths (regression: the single path used to ask top-k for
    one candidate out of a zero-length file axis and crash)."""
    g0, n0 = compress_files([], 10)
    ga0 = flatten(g0, 10, n0)
    assert ga0.num_files == 0
    ids, sc = search_corpus(ga0, (1, 2), k=3)
    assert ids.shape == (0,) and sc.shape == (0,)
    got = batched_search(GrammarBatch.build([ga0]), (1, 2), k=3)
    assert got[0][0].shape == (0,) and got[0][1].shape == (0,)


def test_out_of_vocab_terms_contribute_nothing(seeded_rng):
    ga, _ = _mk(seeded_rng, vocab=15)
    base = search_corpus(ga, (1, 2), k=4)
    with_oov = search_corpus(ga, (1, 2, 999, 10_000), k=4)
    np.testing.assert_array_equal(base[0], with_oov[0])
    np.testing.assert_array_equal(base[1], with_oov[1])


def test_term_validation():
    with pytest.raises(ValueError):
        normalize_terms(None)
    with pytest.raises(ValueError):
        normalize_terms(())
    with pytest.raises(ValueError):
        normalize_terms((1, -2))
    assert normalize_terms([3, 1, 1]) == (3, 1, 1)       # order + dups kept


def test_search_rejects_bad_k_and_scheme(seeded_rng):
    ga, _ = _mk(seeded_rng)
    with pytest.raises(ValueError):
        search_corpus(ga, (1,), k=0)
    with pytest.raises(ValueError):
        search_corpus(ga, (1,), scheme="nope")
    si = build_search_index(ga)
    with pytest.raises(ValueError):
        search_index_topk(si, (1,), scheme="bm42")


# ------------------------------------------------- serving normalization --
def test_group_key_normalizes_terms_and_k():
    """The l-normalization contract, extended to the search parameters:
    terms/k are inert off the search kinds; distinct searches can never
    share a group; omitted k means DEFAULT_TOP_K."""
    assert (Query("a", "word_count", terms=(1, 2), k=5).group_key()
            == Query("a", "word_count").group_key())
    assert Query("a", "word_count", terms=(1, 2)).effective_terms() is None
    assert Query("a", "word_count", k=5).effective_k() is None
    assert (Query("a", "search_bm25", terms=(1, 2)).group_key()
            == Query("a", "search_bm25", terms=(1, 2),
                     k=DEFAULT_TOP_K).group_key())
    assert (Query("a", "search_bm25", terms=(1, 2)).group_key()
            != Query("a", "search_bm25", terms=(2, 1)).group_key())
    assert (Query("a", "search_bm25", terms=(1, 2)).group_key()
            != Query("a", "search_bm25", terms=(1, 2), k=3).group_key())
    assert (Query("a", "search_bm25", terms=(1, 2)).group_key()
            != Query("a", "search_tfidf", terms=(1, 2)).group_key())
    # list terms normalize to a hashable tuple
    assert Query("a", "search_bm25", terms=[1, 2]).terms == (1, 2)


def test_distinct_searches_never_share_a_chunk(seeded_rng):
    """Regression alongside test_word_count_l_variants_share_one_group:
    same-terms searches share ONE batched call; different terms/k/scheme
    split into separate groups and never mis-share results."""
    srv = AnalyticsServer(max_batch=8, mesh=None)
    gas = {}
    for i in range(4):
        ga, _ = _mk(seeded_rng, vocab=30)
        srv.register(f"c{i}", ga)
        gas[f"c{i}"] = ga
    before = srv.stats.batched_calls
    res = srv.run([Query(f"c{i}", "search_bm25", terms=(1, 2), k=4)
                   for i in range(4)])
    assert srv.stats.batched_calls == before + 1         # one group, 1 chunk
    for i in range(4):
        want = search_corpus(gas[f"c{i}"], (1, 2), k=4, scheme="bm25")
        np.testing.assert_array_equal(res[i][0], want[0])
        np.testing.assert_array_equal(res[i][1], want[1])

    before_g = srv.stats.groups
    res = srv.run([Query("c0", "search_bm25", terms=(1, 2), k=4),
                   Query("c0", "search_bm25", terms=(2, 1), k=4),
                   Query("c0", "search_bm25", terms=(1, 2), k=2),
                   Query("c0", "search_tfidf", terms=(1, 2), k=4)])
    assert srv.stats.groups == before_g + 4              # all distinct
    for (ids, sc), (terms, k, scheme) in zip(
            res, [((1, 2), 4, "bm25"), ((2, 1), 4, "bm25"),
                  ((1, 2), 2, "bm25"), ((1, 2), 4, "tfidf")]):
        want = search_corpus(gas["c0"], terms, k=k, scheme=scheme)
        np.testing.assert_array_equal(ids, want[0])
        np.testing.assert_array_equal(sc, want[1])


def test_server_validates_search_queries(seeded_rng):
    srv = AnalyticsServer()
    ga, _ = _mk(seeded_rng)
    srv.register("c", ga)
    assert set(SEARCH_KINDS) < set(SERVED_KINDS)
    with pytest.raises(ValueError):                      # no terms
        srv.run([Query("c", "search_bm25")])
    with pytest.raises(ValueError):                      # empty terms
        srv.run([Query("c", "search_bm25", terms=())])
    with pytest.raises(ValueError):                      # negative term
        srv.run([Query("c", "search_bm25", terms=(1, -3))])
    with pytest.raises(ValueError):                      # bad k
        srv.run([Query("c", "search_bm25", terms=(1,), k=0)])
    with pytest.raises(KeyError):
        srv.run([Query("nope", "search_bm25", terms=(1,))])


def test_execute_chunk_enforces_search_normalization(seeded_rng):
    srv = AnalyticsServer(mesh=None)
    ga, _ = _mk(seeded_rng)
    srv.register("c", ga)
    with pytest.raises(ValueError):                      # stray terms
        srv.execute_chunk("word_count", ["c"], terms=(1, 2))
    with pytest.raises(ValueError):                      # stray k
        srv.execute_chunk("word_count", ["c"], k=5)
    with pytest.raises(ValueError):                      # missing terms
        srv.execute_chunk("search_bm25", ["c"], k=5)
    with pytest.raises(ValueError):                      # missing k
        srv.execute_chunk("search_bm25", ["c"], terms=(1,))


def test_store_single_path_uses_memoized_index(seeded_rng):
    _, files = _mk(seeded_rng, vocab=18, n_files=3)
    cc = CompressedCorpus.build(files, vocab_size=18)
    srv = AnalyticsServer()
    srv.register("solo", cc)
    r1 = srv.run([Query("solo", "search_bm25", terms=(1, 4), k=2)])[0]
    assert ("search_index", "frontier") in cc.cached_weight_keys()
    assert srv.stats.single_calls == 1
    r2 = srv.run([Query("solo", "search_bm25", terms=(1, 4), k=2)])[0]
    np.testing.assert_array_equal(r1[0], r2[0])
    np.testing.assert_array_equal(r1[1], r2[1])
    want = oracle_search(cc.ga, (1, 4), k=2, scheme="bm25")
    np.testing.assert_array_equal(r1[0], want[0])
    np.testing.assert_array_equal(r1[1], want[1])


def test_async_queue_search_matches_sync(seeded_rng):
    srv = AnalyticsServer(max_batch=4, mesh=None)
    for i in range(3):
        ga, _ = _mk(seeded_rng, vocab=25)
        srv.register(f"c{i}", ga)
    clk = [0.0]
    aq = AsyncAnalyticsServer(srv, idle_timeout=100.0, clock=lambda: clk[0])
    queries = ([Query(f"c{i}", "search_bm25", terms=(1, 3), k=3)
                for i in range(3)]
               + [Query("c0", "search_tfidf", terms=(2,), k=2),
                  Query("c1", "word_count")])
    futs = [aq.submit(q) for q in queries]
    aq.drain()
    want = srv.run(queries)
    for f, w, q in zip(futs, want, queries):
        got = f.result(timeout=10)
        if isinstance(w, tuple):
            for a, b in zip(got, w):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_array_equal(np.asarray(got), np.asarray(w))
    # flush events carry the normalized search params
    ev = [e for e in aq.flush_log if e.kind == "search_bm25"]
    assert ev and ev[0].terms == (1, 3) and ev[0].k == 3
