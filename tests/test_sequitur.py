"""Sequitur grammar inference: losslessness + invariants (+property)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import compress, compress_files
from conftest import make_repetitive_files


def _check_utility(g):
    refs = {i: 0 for i in range(1, g.num_rules)}
    for r in g.rules:
        for s in r:
            if s >= g.num_terminals:
                refs[int(s) - g.num_terminals] += 1
    for i, c in refs.items():
        assert c >= 2, f"rule {i} referenced {c} < 2 times"


def test_roundtrip_simple():
    toks = np.array([1, 2, 3, 1, 2, 3, 1, 2, 3, 4], np.int64)
    g = compress(toks, 5)
    assert (g.expand() == toks).all()
    _check_utility(g)


def test_compresses_repetition():
    t = np.tile(np.arange(50), 50)
    g = compress(t, 50)
    assert (g.expand() == t).all()
    assert sum(len(r) for r in g.rules) < len(t) / 5


def test_rejects_out_of_range():
    with pytest.raises(ValueError):
        compress([7], 5)


def test_multifile_splitters_never_inside_rules():
    rng = np.random.default_rng(3)
    files = make_repetitive_files(rng, vocab=12, n_files=4)
    g, nf = compress_files(files, 12)
    assert nf == 4
    # splitters (>= vocab, < num_terminals) appear only in the root
    for rid in range(1, g.num_rules):
        b = g.rules[rid]
        assert not (((b >= 12) & (b < 12 + nf)).any()), rid
    expected = np.concatenate(
        [np.concatenate([f, [12 + i]]) for i, f in enumerate(files)])
    assert (g.expand() == expected).all()


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=300),
       st.integers(0, 1_000_000))
def test_property_lossless_and_utility(tokens, _salt):
    t = np.array(tokens, np.int64)
    g = compress(t, 8)
    assert (g.expand() == t).all()
    _check_utility(g)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_property_nested_repetition(seed):
    rng = np.random.default_rng(seed)
    files = make_repetitive_files(rng, vocab=int(rng.integers(3, 15)))
    g, nf = compress_files(files, int(max(np.concatenate(files))) + 1)
    exp = g.expand()
    got = exp[exp < g.num_terminals - nf]
    assert (got == np.concatenate(files)).all()
    _check_utility(g)
