"""Composable query operators (repro/query): algebra, frontend, serving.

Four enforcement layers on top of the differential suite's bit-equality
checks (test_differential.py):

* **operator algebra** — property-fuzzed invariants any correct filter /
  aggregate / phrase implementation must satisfy, checked on the ENGINE's
  outputs (so an engine bug cannot hide behind a matching oracle bug):
  AND == set intersection of its conjuncts, OR == set union, sequential
  filter refinement == the combined AND filter, aggregation is linear
  (sum) / idempotent-monotone (max) over term-set concatenation, and a
  phrase can never occur more often than its rarest unigram;
* **predicate IR** — canonicalization, validation errors, leaf/structure
  split (the jit-static sharing contract);
* **text frontend** — parsing, AND-over-OR precedence, and the
  never-mutate-the-vocab rule for unknown words;
* **serving normalization** — the regression family from the PR 5
  ``effective_l`` bug, extended to the query tier: inert parameters can
  neither split a group nor mis-share one, and ``execute_chunk`` rejects
  non-normalized parameter combinations loudly.

Runs without hypothesis via tests/_hypothesis_compat; the nightly
``query_fuzz`` lane rescales the algebra suite (QUERY_FUZZ_EXAMPLES).
"""

from __future__ import annotations

import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from _oracle import (assert_result_equal, full_stream, oracle_query,
                     oracle_term_vector, oracle_word_count, stream_segments)
from conftest import make_repetitive_files

from repro.core import GrammarBatch, compress_files, flatten
from repro.data.tokenizer import UNK, Tokenizer
from repro.query import (agg_corpus, and_, filter_corpus, lookup_term,
                         normalize_agg, normalize_phrase,
                         normalize_predicate, or_, phrase_corpus,
                         phrase_from_text, predicate_from_text,
                         predicate_leaves, predicate_mask,
                         predicate_structure, query_corpus,
                         run_batched_query, term_pred, terms_from_text)
from repro.serving import AnalyticsServer, Query


# ----------------------------------------------------------- generators --
def _grammar(rng, scale: int = 1):
    vocab = int(rng.integers(8, 30 * scale + 10))
    n_files = int(rng.integers(1, 4 + scale))
    files = make_repetitive_files(rng, vocab, n_files=n_files)
    g, nf = compress_files(files, vocab)
    return flatten(g, vocab, nf)


def _rand_pred(rng, vocab, depth: int = 0):
    """Random AND/OR tree; leaves may be out-of-vocab (zero column)."""
    if depth >= 2 or rng.random() < 0.5:
        return ("term", int(rng.integers(0, vocab + 4)),
                int(rng.integers(0, 4)))
    op = "and" if rng.random() < 0.5 else "or"
    return (op, tuple(_rand_pred(rng, vocab, depth + 1)
                      for _ in range(int(rng.integers(1, 4)))))


def _rand_terms(rng, vocab):
    nt = int(rng.integers(1, 6))
    return tuple(int(t) for t in rng.integers(0, vocab + 3, nt))


def _present_phrase(rng, ga, stream):
    """A window actually present in the corpus when one exists, else a
    random (usually absent) tuple."""
    l = int(rng.integers(2, 5))
    segs = [s for s in stream_segments(ga, stream) if len(s) >= l]
    if segs:
        seg = segs[int(rng.integers(0, len(segs)))]
        start = int(rng.integers(0, len(seg) - l + 1))
        return tuple(int(x) for x in seg[start: start + l])
    return tuple(int(t) for t in rng.integers(0, ga.vocab_size, l))


def _check_algebra(rng, ga, stream):
    """The full algebra suite on one corpus — shared by the fast property
    lane and the nightly query_fuzz lane."""
    vocab = ga.vocab_size
    a = _rand_pred(rng, vocab)
    b = _rand_pred(rng, vocab)
    fa = filter_corpus(ga, a)
    fb = filter_corpus(ga, b)
    # AND == intersection, OR == union (engine output set algebra)
    np.testing.assert_array_equal(
        filter_corpus(ga, and_(a, b)), np.intersect1d(fa, fb))
    np.testing.assert_array_equal(
        filter_corpus(ga, or_(a, b)),
        np.union1d(fa, fb).astype(np.int32))
    # sequential refinement (filter b applied to filter a's survivors)
    # == the combined AND filter
    tv = oracle_term_vector(ga, stream)
    refined = fa[predicate_mask(b, tv)[fa]] if len(fa) else fa
    np.testing.assert_array_equal(filter_corpus(ga, and_(a, b)), refined)
    # aggregation: sum is linear over term-set concatenation, max is the
    # elementwise max — totals follow (exact: integer-valued float32)
    t1, t2 = _rand_terms(rng, vocab), _rand_terms(rng, vocab)
    pf1, tot1 = agg_corpus(ga, t1, "sum")
    pf2, tot2 = agg_corpus(ga, t2, "sum")
    pf12, tot12 = agg_corpus(ga, t1 + t2, "sum")
    np.testing.assert_array_equal(pf12, pf1 + pf2)
    assert tot12 == np.float32(tot1 + tot2)
    mf1, mt1 = agg_corpus(ga, t1, "max")
    mf2, mt2 = agg_corpus(ga, t2, "max")
    mf12, mt12 = agg_corpus(ga, t1 + t2, "max")
    np.testing.assert_array_equal(mf12, np.maximum(mf1, mf2))
    assert mt12 == max(mt1, mt2)
    # a phrase occurs at most as often as its rarest unigram
    phrase = _present_phrase(rng, ga, stream)
    count = phrase_corpus(ga, phrase)
    wc = oracle_word_count(ga, stream)
    unigram_min = min(
        float(wc[t]) if t < vocab else 0.0 for t in phrase)
    assert float(count) <= unigram_min, (phrase, count, unigram_min)
    # and every engine result above is the oracle's result
    for kind, kw in (("filter_count", dict(predicate=and_(a, b))),
                     ("agg_terms", dict(terms=t1 + t2, agg="max")),
                     ("phrase_count", dict(terms=phrase))):
        assert_result_equal(query_corpus(ga, kind, **kw),
                            oracle_query(ga, kind, stream=stream, **kw),
                            kind, "(algebra suite)")


# ------------------------------------------------------ operator algebra --
@settings(max_examples=6, deadline=None)
@given(st.integers(0, 100_000))
def test_operator_algebra(seed):
    rng = np.random.default_rng(seed)
    ga = _grammar(rng)
    _check_algebra(rng, ga, full_stream(ga))


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 100_000))
def test_batched_operator_algebra(seed):
    """The same set-algebra identities hold row-wise on a batched pack
    (AND/OR composition must not leak across corpus rows)."""
    rng = np.random.default_rng(seed)
    gas = [_grammar(rng) for _ in range(3)]
    gb = GrammarBatch.build(gas)
    vocab = max(ga.vocab_size for ga in gas)
    a, b = _rand_pred(rng, vocab), _rand_pred(rng, vocab)
    fa = run_batched_query(gb, "filter_count", predicate=a)
    fb = run_batched_query(gb, "filter_count", predicate=b)
    fand = run_batched_query(gb, "filter_count", predicate=and_(a, b))
    f_or = run_batched_query(gb, "filter_count", predicate=or_(a, b))
    for i in range(len(gas)):
        np.testing.assert_array_equal(fand[i], np.intersect1d(fa[i], fb[i]))
        np.testing.assert_array_equal(
            f_or[i], np.union1d(fa[i], fb[i]).astype(np.int32))


# ----------------------------------------------------------- predicate IR --
def test_normalize_predicate_canonicalizes():
    raw = ["or", (["term", np.int64(3), 2.0], ("and", [["term", 1, 1]]))]
    want = ("or", (("term", 3, 2), ("and", (("term", 1, 1),))))
    assert normalize_predicate(raw) == want
    assert normalize_predicate(want) == want          # idempotent
    assert term_pred(5) == ("term", 5, 1)
    assert and_(term_pred(1), term_pred(2, 3)) == \
        ("and", (("term", 1, 1), ("term", 2, 3)))
    assert or_(term_pred(1)) == ("or", (("term", 1, 1),))


@pytest.mark.parametrize("bad", [
    None, (), ("term", 1), ("term", -1, 1), ("term", 1, -1),
    ("and", ()), ("or", ()), ("and", 3), ("xor", (("term", 1, 1),)),
    ("term", 1, 1, 1), 7,
])
def test_normalize_predicate_rejects(bad):
    with pytest.raises(ValueError):
        normalize_predicate(bad)


def test_predicate_leaf_structure_split():
    pred = or_(and_(term_pred(4, 2), term_pred(9)), term_pred(0, 5))
    assert predicate_leaves(pred) == [(4, 2), (9, 1), (0, 5)]
    structure = predicate_structure(pred)
    assert structure == ("or", (("and", (("leaf", 0), ("leaf", 1))),
                                ("leaf", 2)))
    # different terms/thresholds, same shape -> same structure (the jit
    # static): one compiled filter program serves both
    other = or_(and_(term_pred(1, 7), term_pred(2)), term_pred(3))
    assert predicate_structure(other) == structure
    assert hash(structure) == hash(predicate_structure(other))


def test_normalize_agg_and_phrase():
    assert normalize_agg(None) == "sum"
    assert normalize_agg("max") == "max"
    with pytest.raises(ValueError, match="aggregation"):
        normalize_agg("avg")
    assert normalize_phrase([np.int64(3), 4]) == (3, 4)
    for bad in (None, (7,), (3, -1)):
        with pytest.raises(ValueError):
            normalize_phrase(bad)


# ----------------------------------------------------------- text frontend --
def _tok():
    return Tokenizer.build(["the cat sat on the mat",
                            "the dog sat on the cat"])


def test_frontend_lookup_never_mutates():
    tok = _tok()
    before = dict(tok.word_to_id)
    assert lookup_term(tok, "cat") == tok.word_to_id["cat"]
    assert lookup_term(tok, "zebra") == UNK
    # even on an UNFROZEN tokenizer a query lookup must not grow the vocab
    tok.frozen = False
    assert lookup_term(tok, "zebra") == UNK
    assert phrase_from_text(tok, "zebra crossing") == (UNK, UNK)
    assert tok.word_to_id == before and tok.vocab_size == len(before)


def test_frontend_terms_and_phrase():
    tok = _tok()
    cat, dog, sat = (tok.word_to_id[w] for w in ("cat", "dog", "sat"))
    assert terms_from_text(tok, "cat dog cat") == (cat, dog, cat)
    assert phrase_from_text(tok, "dog sat") == (dog, sat)
    with pytest.raises(ValueError, match="no words"):
        terms_from_text(tok, "  ")
    with pytest.raises(ValueError, match="at least 2"):
        phrase_from_text(tok, "cat")


def test_frontend_predicate_parsing():
    tok = _tok()
    cat, dog, mat = (tok.word_to_id[w] for w in ("cat", "dog", "mat"))
    assert predicate_from_text(tok, "cat") == ("term", cat, 1)
    assert predicate_from_text(tok, "cat >= 3") == ("term", cat, 3)
    # AND binds tighter than OR
    assert predicate_from_text(tok, "cat AND dog >= 2 OR mat") == \
        ("or", (("and", (("term", cat, 1), ("term", dog, 2))),
                ("term", mat, 1)))
    # parens override precedence
    assert predicate_from_text(tok, "cat AND (dog OR mat)") == \
        ("and", (("term", cat, 1),
                 ("or", (("term", dog, 1), ("term", mat, 1)))))
    assert predicate_from_text(tok, "zebra") == ("term", UNK, 1)
    for bad in ("(cat", "cat)", "cat >= dog", "cat AND", "AND cat",
                "cat dog", ""):
        with pytest.raises(ValueError):
            predicate_from_text(tok, bad)


def test_frontend_to_engine_roundtrip(seeded_rng):
    """Text in, correct files out: encode a tiny text corpus, query it
    through the frontend, check against a plain python scan."""
    texts = ["the cat sat on the mat", "the dog ate the cat food",
             "mat mat mat", "the dog sat"]
    tok = Tokenizer.build(texts)
    files = [tok.encode(t) for t in texts]
    g, nf = compress_files(files, tok.vocab_size)
    ga = flatten(g, tok.vocab_size, nf)
    pred = predicate_from_text(tok, "cat AND the >= 2 OR mat >= 3")
    want = [i for i, t in enumerate(texts)
            if ("cat" in t.split() and t.split().count("the") >= 2)
            or t.split().count("mat") >= 3]
    np.testing.assert_array_equal(filter_corpus(ga, pred), want)
    phrase = phrase_from_text(tok, "the cat")
    want_n = sum(" ".join(t.split()).count("the cat") for t in texts)
    assert float(phrase_corpus(ga, phrase)) == float(want_n)


# -------------------------------------------------- serving normalization --
def test_group_key_nulls_inert_fields():
    """The PR 5 ``effective_l`` regression family, extended to the query
    tier: parameters a kind does not consume are normalized out of its
    group key — a stray value can neither split a group nor mis-share
    one."""
    plain = Query("c", "word_count")
    noisy = Query("c", "word_count", l=7, terms=(1, 2), k=5,
                  predicate=term_pred(1), agg="max")
    assert noisy.group_key() == plain.group_key()
    # kinds that DO consume a field always keep it
    p1, p2 = term_pred(1), term_pred(2)
    assert Query("c", "filter_count", predicate=p1).group_key() != \
        Query("c", "filter_count", predicate=p2).group_key()
    assert Query("c", "agg_terms", terms=(1, 2), agg="sum").group_key() != \
        Query("c", "agg_terms", terms=(1, 2), agg="max").group_key()
    # canonical defaults merge: omitted agg == explicit "sum"; predicate
    # lists canonicalize to the same tuples at construction
    assert Query("c", "agg_terms", terms=(1, 2)).group_key() == \
        Query("c", "agg_terms", terms=(1, 2), agg="sum").group_key()
    assert Query("c", "filter_count",
                 predicate=["and", [["term", 1, 1], ["term", 2, 2]]]
                 ).group_key() == \
        Query("c", "filter_count",
              predicate=and_(term_pred(1), term_pred(2, 2))).group_key()
    # inert-field nulling cannot leak ACROSS query kinds either
    assert Query("c", "filter_count", predicate=p1, agg="max").group_key() \
        == Query("c", "filter_count", predicate=p1).group_key()
    assert Query("c", "phrase_count", terms=(1, 2), k=9).group_key() == \
        Query("c", "phrase_count", terms=(1, 2)).group_key()


def test_server_validates_query_kinds(seeded_rng):
    srv = AnalyticsServer()
    srv.register("c", _grammar(seeded_rng))
    for bad in (Query("c", "filter_count"),                    # no predicate
                Query("c", "agg_terms"),                       # no terms
                Query("c", "agg_terms", terms=(1,), agg="avg"),
                Query("c", "phrase_count", terms=(1,))):       # 1-gram
        with pytest.raises(ValueError):
            srv.run([bad])
    with pytest.raises(ValueError):
        Query("c", "filter_count", predicate=("xor", ()))      # at __init__


def test_execute_chunk_rejects_unnormalized_params(seeded_rng):
    """``execute_chunk`` is the enforcement backstop below ``group_key``:
    a caller that bypasses ``Query.effective_*`` normalization (the PR 5
    bug shape) must fail loudly, not silently serve."""
    srv = AnalyticsServer()
    srv.register("c", _grammar(seeded_rng))
    bad_calls = [
        ("word_count", dict(terms=(1,))),
        ("word_count", dict(k=3)),
        ("word_count", dict(predicate=term_pred(1))),
        ("word_count", dict(agg="sum")),
        ("filter_count", dict()),                       # predicate required
        ("filter_count", dict(predicate=term_pred(1), agg="sum")),
        ("agg_terms", dict(terms=(1, 2), k=3)),
        ("agg_terms", dict(terms=(1, 2), agg="avg")),
        ("phrase_count", dict(terms=(7,))),
        ("phrase_count", dict(terms=(1, 2), predicate=term_pred(1))),
    ]
    for kind, kw in bad_calls:
        with pytest.raises(ValueError):
            srv.execute_chunk(kind, ["c"], **kw)


def test_server_serves_query_kinds(seeded_rng):
    """A mixed batch of query kinds through the real grouping path equals
    the single-corpus engine per query."""
    gas = {f"c{i}": _grammar(seeded_rng) for i in range(4)}
    srv = AnalyticsServer(max_batch=4)
    for name, ga in gas.items():
        srv.register(name, ga)
    pred = or_(and_(term_pred(1), term_pred(2)), term_pred(4, 2))
    qs = [Query(name, kind, **kw)
          for name in gas
          for kind, kw in (("filter_count", dict(predicate=pred)),
                           ("agg_terms", dict(terms=(1, 3, 3), agg="max")),
                           ("phrase_count", dict(terms=(1, 2))),
                           ("word_count", dict()))]
    for got, q in zip(srv.run(qs), qs):
        if q.kind == "word_count":
            continue
        want = query_corpus(gas[q.corpus], q.kind,
                            predicate=q.effective_predicate(),
                            terms=q.effective_terms(),
                            agg=q.effective_agg())
        assert_result_equal(got, want, q.kind, f"(server, {q.corpus})")
    assert srv.stats.batched_calls > 0


# ------------------------------------------------------- nightly fuzz lane --
@pytest.mark.slow
@pytest.mark.query_fuzz
@settings(max_examples=int(os.environ.get("QUERY_FUZZ_EXAMPLES", "200")),
          deadline=None)
@given(st.integers(0, 10_000_000))
def test_query_fuzz(seed):
    """Nightly lane: many more random grammars/predicates/phrases through
    the full algebra suite (QUERY_FUZZ_EXAMPLES scales it)."""
    rng = np.random.default_rng(seed)
    ga = _grammar(rng)
    _check_algebra(rng, ga, full_stream(ga))
