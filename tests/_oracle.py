"""Decompress-then-scan oracle for the six TADOC analytics.

TADOC (the CPU predecessor) validates every compressed-domain analytic
against a baseline that simply decompresses the corpus and scans the raw
token stream.  This module is that baseline: ``expand_range`` (or
``Grammar.expand``) materializes the full terminal stream, plain numpy
recomputes each analytic from it, and the differential suite
(test_differential.py) asserts the compressed-domain engines — single
corpus, batched segment_sum, batched ELL — agree exactly.

Semantics replicated from the engine:

* the stream interleaves word terminals (``< vocab_size``) with one unique
  file-splitter terminal after each file (``compress_files``);
* per-file analytics assign each inter-splitter segment to the file whose
  splitter terminates it; trailing content with no splitter joins the last
  file (mirrors ``grammar.flatten``'s ``_flush``);
* sequence windows never cross a splitter;
* ties in the sort / ranked-inverted-index orderings break by index
  (stable argsort on negated counts, exactly like the engine).

All counts are integer-valued and far below 2**24, so float32 arithmetic is
exact in both domains — comparisons can demand bit equality.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.grammar import GrammarArrays, expand_range


def full_stream(ga: GrammarArrays) -> np.ndarray:
    """The whole terminal stream (words + splitters) via random-access
    expansion from the root."""
    return expand_range(ga, 0, int(ga.exp_len[0]))


def stream_segments(ga: GrammarArrays,
                    stream: np.ndarray | None = None) -> List[np.ndarray]:
    """Word segments between file splitters, in stream order.

    Segment i (for i < F) is terminated by file i's splitter; a trailing
    segment (no terminator) may follow and belongs to the last file.
    """
    if stream is None:
        stream = full_stream(ga)
    is_split = (stream >= ga.vocab_size) & (stream < ga.num_terminals)
    cuts = np.flatnonzero(is_split)
    bounds = np.concatenate([[-1], cuts, [len(stream)]])
    segs = [stream[bounds[i] + 1: bounds[i + 1]]
            for i in range(len(bounds) - 1)]
    if len(segs) and len(segs[-1]) == 0 and len(cuts) == ga.num_files:
        segs.pop()                      # empty trailing pseudo-segment
    return segs


def _seg_file(ga: GrammarArrays, seg_idx: int) -> int:
    return min(seg_idx, max(ga.num_files - 1, 0))


def oracle_word_count(ga: GrammarArrays,
                      stream: np.ndarray | None = None) -> np.ndarray:
    if stream is None:
        stream = full_stream(ga)
    words = stream[stream < ga.vocab_size]
    return np.bincount(words, minlength=ga.vocab_size).astype(np.float32)


def oracle_sort(ga: GrammarArrays, stream: np.ndarray | None = None
                ) -> Tuple[np.ndarray, np.ndarray]:
    counts = oracle_word_count(ga, stream)
    order = np.argsort(-counts, kind="stable")
    return order, counts[order]


def oracle_term_vector(ga: GrammarArrays,
                       stream: np.ndarray | None = None) -> np.ndarray:
    tv = np.zeros((ga.num_files, ga.vocab_size), np.float32)
    for i, seg in enumerate(stream_segments(ga, stream)):
        tv[_seg_file(ga, i)] += np.bincount(seg,
                                            minlength=ga.vocab_size)
    return tv


def oracle_inverted_index(ga: GrammarArrays,
                          stream: np.ndarray | None = None) -> np.ndarray:
    return oracle_term_vector(ga, stream) > 0


def oracle_ranked_inverted_index(ga: GrammarArrays,
                                 stream: np.ndarray | None = None
                                 ) -> Tuple[np.ndarray, np.ndarray]:
    tv = oracle_term_vector(ga, stream)
    order = np.argsort(-tv, axis=0, kind="stable")      # [F, V]
    ranked = np.take_along_axis(tv, order, axis=0)
    return order.T, ranked.T


# ------------------------------------------------------------- search --
# float32 constants + expression ORDER deliberately mirror
# repro/search/scoring.py and repro/search/engine.py op for op: IEEE
# elementwise float32 add/mul/div are exactly specified and numpy's log is
# applied to identical float32 inputs on both sides (the engine keeps its
# idf/normalizer prep on host, in numpy, for exactly this reason), so the
# differential suite can demand bit equality of scores AND rankings.
_K1 = np.float32(1.2)
_B = np.float32(0.75)
_ONE = np.float32(1.0)
_HALF = np.float32(0.5)
_K1P1 = _K1 + _ONE


def oracle_search(ga: GrammarArrays, terms, k: int = 10,
                  scheme: str = "bm25",
                  stream: np.ndarray | None = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """BM25 / TF-IDF top-k ranking recomputed from the decompressed
    stream: tf/df/dl from a plain scan (via :func:`oracle_term_vector`),
    scoring in sequential-term float32, stable argsort for the ranking
    (ties -> lower file id, like ``jax.lax.top_k``)."""
    tv = oracle_term_vector(ga, stream)
    F, V = tv.shape
    dl = tv.sum(axis=1, dtype=np.float32)
    df = (tv > 0).sum(axis=0).astype(np.float32)
    n = np.float32(F)
    avgdl = np.float32(dl.sum(dtype=np.float32)) / np.float32(max(F, 1))
    if not avgdl > 0:
        avgdl = _ONE
    norm = (_K1 * (_ONE - _B + _B * (dl / np.float32(avgdl)))).astype(
        np.float32)
    t = np.asarray(terms, np.int64)
    ok = (t >= 0) & (t < V)
    tf_q = np.zeros((F, len(t)), np.float32)
    tf_q[:, ok] = tv[:, t[ok]]
    df_q = np.zeros(len(t), np.float32)
    df_q[ok] = df[t[ok]]
    if scheme == "bm25":
        idf = np.log(_ONE + (n - df_q + _HALF) / (df_q + _HALF)).astype(
            np.float32)
        quot = (tf_q * _K1P1) / (tf_q + norm[:, None])
    elif scheme == "tfidf":
        idf = (np.log((n + _ONE) / (df_q + _ONE)) + _ONE).astype(np.float32)
        quot = tf_q
    else:
        raise ValueError(f"unknown scoring scheme {scheme!r}")
    score = np.zeros(F, np.float32)
    for j in range(len(t)):           # sequential term order, like the engine
        score = score + idf[j] * quot[:, j]
    k_eff = min(int(k), F)
    order = np.argsort(-score, kind="stable")[:k_eff].astype(np.int32)
    return order, score[order]


def oracle_search_kind(ga: GrammarArrays, kind: str, terms, k: int = 10,
                       stream: np.ndarray | None = None):
    """``oracle_search`` addressed by serving query kind."""
    scheme = {"search_bm25": "bm25", "search_tfidf": "tfidf"}[kind]
    return oracle_search(ga, terms, k=k, scheme=scheme, stream=stream)


def oracle_sequence_count(ga: GrammarArrays, l: int = 3,
                          stream: np.ndarray | None = None
                          ) -> Tuple[np.ndarray, np.ndarray]:
    wins = [np.lib.stride_tricks.sliding_window_view(seg, l)
            for seg in stream_segments(ga, stream) if len(seg) >= l]
    if not wins:
        return np.zeros((0, l), np.int32), np.zeros(0, np.float32)
    grams, counts = np.unique(np.concatenate(wins), axis=0,
                              return_counts=True)
    return grams.astype(np.int32), counts.astype(np.float32)


# ---------------------------------------------------- query operators --
# The composable query tier (repro/query): filter predicates, term-set
# aggregations and phrase counts recomputed from the decompressed stream.
# Every value is an integer-valued float32 (< 2**24), so the oracle and
# the jitted pack programs agree bitwise in any reduce order.
def oracle_filter(ga: GrammarArrays, predicate,
                  stream: np.ndarray | None = None) -> np.ndarray:
    """Ascending int32 file ids satisfying a canonical predicate tree
    (``("term", t, c)`` / ``("and", kids)`` / ``("or", kids)``), evaluated
    recursively over the decompress-then-scan term vector."""
    tv = oracle_term_vector(ga, stream)
    F, V = tv.shape

    def ev(node):
        if node[0] == "term":
            _, t, c = node
            cnt = tv[:, t] if t < V else np.zeros(F, np.float32)
            return cnt >= np.float32(c)
        masks = [ev(ch) for ch in node[1]]
        out = masks[0]
        for m in masks[1:]:
            out = (out & m) if node[0] == "and" else (out | m)
        return out

    return np.flatnonzero(ev(predicate)).astype(np.int32)


def oracle_agg(ga: GrammarArrays, terms, op: str = "sum",
               stream: np.ndarray | None = None
               ) -> Tuple[np.ndarray, np.float32]:
    """(per_file [F] float32, total float32) sum/max of the term set's
    counts — sequential accumulation over term slots in query order, like
    the engine's fori_loop (exact either way: integer-valued float32)."""
    tv = oracle_term_vector(ga, stream)
    F, V = tv.shape
    pf = np.zeros(F, np.float32)
    for t in terms:
        cnt = tv[:, int(t)] if int(t) < V else np.zeros(F, np.float32)
        pf = pf + cnt if op == "sum" else np.maximum(pf, cnt)
    if op == "sum":
        total = np.float32(pf.sum(dtype=np.float32))
    else:
        total = np.float32(pf.max()) if F else np.float32(0.0)
    return pf, total


def oracle_phrase(ga: GrammarArrays, phrase,
                  stream: np.ndarray | None = None) -> np.float32:
    """Exact float32 occurrence count of the phrase: sliding windows over
    each decompressed file segment (windows never cross a splitter)."""
    ph = np.asarray(phrase, np.int64)
    l = len(ph)
    count = 0
    for seg in stream_segments(ga, stream):
        if len(seg) >= l:
            wins = np.lib.stride_tricks.sliding_window_view(seg, l)
            count += int((wins == ph[None, :]).all(axis=1).sum())
    return np.float32(count)


def oracle_query(ga: GrammarArrays, kind: str, predicate=None, terms=None,
                 agg: str = "sum", stream: np.ndarray | None = None):
    """Query-operator oracle addressed by serving kind, shaped exactly
    like ``repro.query.engine.query_corpus`` / ``run_batched_query``."""
    if kind == "filter_count":
        return oracle_filter(ga, predicate, stream)
    if kind == "agg_terms":
        return oracle_agg(ga, terms, op=agg, stream=stream)
    if kind == "phrase_count":
        return oracle_phrase(ga, terms, stream)
    raise ValueError(f"unknown query kind {kind!r}")


def oracle_batch(gas: List[GrammarArrays], kind: str, l: int = 3) -> List:
    """Per-corpus oracle results for a corpus list — the reference shape of
    ``run_batched`` / ``run_sharded`` output (the sharded differential
    suites compare whole batches against this)."""
    return [oracle(ga, kind, l=l) for ga in gas]


def oracle(ga: GrammarArrays, kind: str, l: int = 3,
           stream: np.ndarray | None = None):
    """Recompute one analytics kind from the decompressed stream, shaped
    exactly like the engine's output for that kind."""
    if kind == "word_count":
        return oracle_word_count(ga, stream)
    if kind == "sort":
        return oracle_sort(ga, stream)
    if kind == "term_vector":
        return oracle_term_vector(ga, stream)
    if kind == "inverted_index":
        return oracle_inverted_index(ga, stream)
    if kind == "ranked_inverted_index":
        return oracle_ranked_inverted_index(ga, stream)
    if kind == "sequence_count":
        return oracle_sequence_count(ga, l, stream)
    raise ValueError(f"unknown analytics kind {kind!r}")


def assert_result_equal(got, want, kind: str, context: str = "") -> None:
    """Bit-exact comparison of an engine result against the oracle (tuple
    kinds compare element-wise)."""
    gots = got if isinstance(got, tuple) else (got,)
    wants = want if isinstance(want, tuple) else (want,)
    assert len(gots) == len(wants), (kind, context)
    for part, (g, w) in enumerate(zip(gots, wants)):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(w),
            err_msg=f"{kind} part {part} diverged from the "
                    f"decompress-then-scan oracle {context}")
