"""Observability layer: registry semantics, exposition formats, thread
safety, and span-tree completeness across both serving paths.

The acceptance bar this suite holds (docs/observability.md):

* the registry is exact under concurrency — N threads hammering one
  counter/histogram lose nothing, and a threaded storm of async submits
  satisfies ``completed + shed + rejected == offered`` on the registry's
  own counters;
* ``render_prometheus()`` output parses under a strict text-format
  grammar, histogram buckets are cumulative-monotone and the ``+Inf``
  bucket equals ``_count``;
* every query through the sync server or the async queue produces a
  complete span tree — no stage gaps (``span_problems`` is the checker);
* the ``enabled`` flag's asymmetry: counters/gauges always record,
  histograms and span construction go dark when disabled.
"""

import json
import math
import re
import threading
import time

import numpy as np
import pytest

from repro.core import compress_files, flatten
from repro.data.store import CompressedCorpus
from repro.kernels import ops as kops
from repro.obs import (BoundedLog, MetricsRegistry, global_registry,
                       span_problems)
from repro.serving import (AnalyticsServer, AsyncAnalyticsServer,
                           DeadlineExceeded, Query, QueueFull)
from conftest import make_repetitive_files

MAX_BATCH = 3


class SimClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _build_engine(n_corpora=MAX_BATCH, seed=321, **kw):
    rng = np.random.default_rng(seed)
    eng = AnalyticsServer(max_batch=MAX_BATCH, **kw)
    for i in range(n_corpora):
        vocab = int(rng.integers(8, 20))
        files = make_repetitive_files(rng, vocab, n_files=2)
        g, nf = compress_files(files, vocab)
        eng.register(f"c{i}", flatten(g, vocab, nf))
    return eng


_ENGINE = None


def _shared_engine():
    """One warmed engine for the exposition/accounting tests (packs and
    compiled programs are reused; per-test registries are NOT needed here
    because these tests only ever read deltas or parse formats)."""
    global _ENGINE
    if _ENGINE is None:
        _ENGINE = _build_engine()
        _ENGINE.run([Query(f"c{i}", "word_count") for i in range(MAX_BATCH)])
    return _ENGINE


# ------------------------------------------------------- registry units --
def test_counter_semantics():
    reg = MetricsRegistry()
    c = reg.counter("repro_t_total", "help text")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    c.set(10.0)                         # forward set OK (the thin views)
    with pytest.raises(ValueError):
        c.set(5.0)                      # backwards never


def test_gauge_semantics():
    reg = MetricsRegistry()
    g = reg.gauge("repro_t_depth")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value == 3.0


def test_labels_fanout_and_remove():
    reg = MetricsRegistry()
    fam = reg.counter("repro_t_labeled_total", "", ("reason",))
    fam.labels("idle").inc()
    fam.labels("idle").inc()
    fam.labels("drain").inc()
    assert fam.labels("idle").value == 2.0
    assert dict((k, c.value) for k, c in fam.children()) == {
        ("drain",): 1.0, ("idle",): 2.0}
    with pytest.raises(ValueError):
        fam.inc()                       # labeled family has no bare child
    with pytest.raises(ValueError):
        fam.labels("a", "b")            # wrong arity
    fam.remove("drain")
    assert [k for k, _ in fam.children()] == [("idle",)]


def test_registration_validation():
    reg = MetricsRegistry()
    fam = reg.counter("repro_t_x_total", "first", ("a",))
    # idempotent re-registration returns the same family
    assert reg.counter("repro_t_x_total", "other help", ("a",)) is fam
    # conflicting kind or labelnames is refused loudly
    with pytest.raises(ValueError):
        reg.gauge("repro_t_x_total")
    with pytest.raises(ValueError):
        reg.counter("repro_t_x_total", "", ("b",))
    with pytest.raises(ValueError):
        reg.counter("0bad_name")
    with pytest.raises(ValueError):
        reg.counter("repro_t_y_total", "", ("bad-label",))


def test_histogram_bucket_validation():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.histogram("repro_t_h1_seconds", buckets=(1.0, 1.0, 2.0))
    with pytest.raises(ValueError):
        reg.histogram("repro_t_h2_seconds", buckets=(2.0, 1.0))
    h = reg.histogram("repro_t_h3_seconds", buckets=(1.0, 2.0))
    assert math.isinf(h.buckets[-1])    # +Inf auto-appended


def test_histogram_percentiles_interpolate():
    reg = MetricsRegistry()
    h = reg.histogram("repro_t_lat_seconds", buckets=(1.0, 2.0, 4.0))
    assert math.isnan(h.percentile(50))          # empty
    for v in (0.5, 1.5, 1.5, 3.0):
        h.observe(v)
    assert h.count == 4 and h.sum == pytest.approx(6.5)
    # rank 2 of 4 lands in the (1, 2] bucket; linear interpolation
    assert 1.0 <= h.percentile(50) <= 2.0
    assert 2.0 <= h.percentile(99) <= 4.0
    h.observe(100.0)                             # +Inf bucket
    assert h.percentile(99.9) == 4.0             # open-ended: lower bound


def test_disabled_registry_asymmetry():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("repro_t_total")
    h = reg.histogram("repro_t_seconds")
    c.inc()
    h.observe(1.0)
    assert c.value == 1.0               # counters ALWAYS record (policy)
    assert h.count == 0                 # histograms go dark


def test_reset_zeroes_in_place():
    reg = MetricsRegistry()
    fam = reg.counter("repro_t_total", "", ("x",))
    child = fam.labels("a")
    child.inc(5)
    h = reg.histogram("repro_t_seconds")
    h.observe(0.5)
    reg.reset()
    assert child.value == 0.0           # same handle, zeroed
    assert h.count == 0 and h.sum == 0.0


# ---------------------------------------------------------- exposition --
_HELP_RE = re.compile(r"^# HELP [a-zA-Z_:][a-zA-Z0-9_:]* .*$")
_TYPE_RE = re.compile(
    r"^# TYPE ([a-zA-Z_:][a-zA-Z0-9_:]*) (counter|gauge|histogram)$")
_LABEL_RE = (r'[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\\n]|\\\\|\\"|\\n)*"')
_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(" + _LABEL_RE + r"(?:," + _LABEL_RE + r")*)\})?"
    r" ([+-]?(?:Inf|NaN|\d+(?:\.\d+)?(?:[eE][+-]?\d+)?))$")


def _parse_prometheus(text: str) -> dict:
    """Strict line-by-line parse of the 0.0.4 text format; returns
    {family: {"type": ..., "samples": [(name, {label: value}, float)]}}."""
    families, current = {}, None
    assert text.endswith("\n")
    for line in text.splitlines():
        m = _TYPE_RE.match(line)
        if m:
            current = families.setdefault(
                m.group(1), {"type": m.group(2), "samples": []})
            continue
        if _HELP_RE.match(line):
            continue
        m = _SAMPLE_RE.match(line)
        assert m, f"unparseable exposition line: {line!r}"
        labels = {}
        if m.group(2):
            for part in re.findall(_LABEL_RE, m.group(2)):
                k, v = part.split("=", 1)
                labels[k] = v[1:-1]
        assert current is not None, f"sample before any # TYPE: {line!r}"
        current["samples"].append((m.group(1), labels, float(m.group(3))))
    return families


def _check_histogram_series(fam_name: str, fam: dict) -> None:
    """Cumulative buckets monotone, +Inf bucket == _count, per label set."""
    by_key = {}
    for name, labels, value in fam["samples"]:
        key = tuple(sorted((k, v) for k, v in labels.items() if k != "le"))
        entry = by_key.setdefault(key, {"buckets": [], "count": None})
        if name == fam_name + "_bucket":
            entry["buckets"].append((labels["le"], value))
        elif name == fam_name + "_count":
            entry["count"] = value
    for key, entry in by_key.items():
        counts = [v for _, v in entry["buckets"]]
        assert counts == sorted(counts), \
            f"{fam_name}{key}: buckets not cumulative-monotone: {counts}"
        assert entry["buckets"][-1][0] == "+Inf"
        assert entry["buckets"][-1][1] == entry["count"], \
            f"{fam_name}{key}: +Inf bucket != _count"


def test_prometheus_exposition_parses():
    eng = _shared_engine()
    eng.run([Query("c0", "word_count"), Query("c1", "term_vector")])
    for reg in (eng.registry, global_registry()):
        families = _parse_prometheus(reg.render_prometheus())
        assert families, "exposition rendered no families"
        for name, fam in families.items():
            if fam["type"] == "histogram":
                _check_histogram_series(name, fam)
            else:
                for sname, _, _ in fam["samples"]:
                    assert sname == name
    assert "repro_server_queries_total" in _parse_prometheus(
        eng.registry.render_prometheus())


def test_snapshot_is_json_safe_and_consistent():
    eng = _shared_engine()
    snap = eng.registry.snapshot()
    json.dumps(snap)                    # must not raise
    stage = snap["repro_server_stage_seconds"]
    assert stage["type"] == "histogram"
    for s in stage["samples"]:
        # cumulative table's last row is the +Inf bucket == count
        assert s["buckets"][-1][0] == "+Inf"
        assert s["buckets"][-1][1] == s["count"]
    json.dumps(global_registry().snapshot())


def test_label_escaping():
    reg = MetricsRegistry()
    reg.counter("repro_t_esc_total", "", ("v",)).labels('a"b\\c\nd').inc()
    text = reg.render_prometheus()
    assert r'v="a\"b\\c\nd"' in text
    _parse_prometheus(text)             # still parses


# -------------------------------------------------------- thread safety --
def test_registry_concurrent_updates_exact():
    reg = MetricsRegistry()
    c = reg.counter("repro_t_total")
    h = reg.histogram("repro_t_seconds", buckets=(0.5, 1.0))
    n_threads, n_iter = 8, 500

    def work():
        for i in range(n_iter):
            c.inc()
            h.observe((i % 3) * 0.4)

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n_threads * n_iter
    assert h.count == n_threads * n_iter
    # sum of per-bucket increments == count (no lost bucket update)
    assert sum(h.labels()._counts) == h.count


def test_concurrent_submit_exact_accounting():
    """Threaded submit storm against a small bounded queue: every offered
    query resolves exactly one way, and the registry's own counters agree
    with the observed outcomes."""
    eng = _shared_engine()
    sub0, rej0, shed0 = (eng.stats.submitted, eng.stats.rejected,
                         eng.stats.shed)
    outcomes = {"completed": 0, "shed": 0, "rejected": 0, "errors": 0}
    lock = threading.Lock()
    futs = []
    n_threads, per_thread = 6, 20

    with AsyncAnalyticsServer(eng, idle_timeout=0.002, poll_interval=0.001,
                              max_pending=16) as aq:
        def client(tid: int):
            rng = np.random.default_rng(tid)
            for j in range(per_thread):
                q = Query(f"c{int(rng.integers(MAX_BATCH))}", "word_count")
                # ~1 in 4 queries carries an already-hopeless deadline
                dl = (time.monotonic() - 1.0
                      if rng.random() < 0.25 else None)
                try:
                    f = aq.submit(q, deadline=dl)
                except QueueFull:
                    with lock:
                        outcomes["rejected"] += 1
                    continue
                with lock:
                    futs.append(f)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        aq.drain()

    for f in futs:
        try:
            f.result(timeout=60)
            outcomes["completed"] += 1
        except DeadlineExceeded:
            outcomes["shed"] += 1
        except Exception:
            outcomes["errors"] += 1

    offered = n_threads * per_thread
    assert outcomes["errors"] == 0
    assert (outcomes["completed"] + outcomes["shed"]
            + outcomes["rejected"]) == offered
    # the registry counted the same story
    assert eng.stats.submitted - sub0 == offered - outcomes["rejected"]
    assert eng.stats.rejected - rej0 == outcomes["rejected"]
    assert eng.stats.shed - shed0 == outcomes["shed"]
    # stage histograms stayed internally consistent under the storm
    for _, child in eng.stats.stage_seconds.children():
        assert sum(child._counts) == child.count


# ------------------------------------------------------------ span trees --
def test_sync_span_tree_complete():
    eng = _build_engine(seed=99)
    qs = [Query(f"c{i}", "word_count") for i in range(2)]
    eng.run(qs)                                  # cold: pays the compile
    for q in qs:
        root = q.trace
        assert root is not None and root.attrs["path"] == "sync"
        assert span_problems(
            root, require=("run_group", "chunk", "pack_build")) == []
        assert root.find("compile"), "first call must trace as compile"
    # the shared chunk subtree IS the batching: both roots hold it
    assert qs[0].trace.find("chunk")[0] is qs[1].trace.find("chunk")[0]

    warm = [Query(f"c{i}", "word_count") for i in range(2)]
    eng.run(warm)
    root = warm[0].trace
    assert span_problems(
        root, require=("run_group", "chunk", "pack_build", "execute")) == []
    assert not root.find("compile")              # warm: no compile stage
    chunk = root.find("chunk")[0]
    assert chunk.attrs["cache_hit"] is True
    assert len(eng.trace_log) == 4               # every root was logged


def test_async_span_tree_simclock():
    """One injectable clock through server, queue, registry, spans: the
    tree's durations are exact simulated time, and the flush subtree is
    shared by every query it answered."""
    clk = SimClock()
    # one grammar under three names: identical size buckets, so the three
    # submits share one pending group and the third fills it (max_batch)
    rng = np.random.default_rng(77)
    files = make_repetitive_files(rng, 12, n_files=2)
    g, nf = compress_files(files, 12)
    ga = flatten(g, 12, nf)
    eng = AnalyticsServer(max_batch=MAX_BATCH, clock=clk)
    for i in range(MAX_BATCH):
        eng.register(f"c{i}", ga)
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0)   # inherits clk
    qs = [Query(f"c{i}", "word_count") for i in range(MAX_BATCH)]
    futs = []
    for q in qs:
        futs.append(aq.submit(q))
        clk.t += 0.5                    # advance between submits
    assert all(f.done() for f in futs)  # max_batch flushed on last submit
    for q in qs:
        root = q.trace
        assert root.attrs["path"] == "async"
        assert root.attrs["outcome"] == "ok"
        assert span_problems(
            root, require=("queue_wait", "flush", "chunk",
                           "pack_build")) == []
    # queue_wait measured in pure simulated time: q0 waited two ticks
    waits = [q.trace.find("queue_wait")[0].duration for q in qs]
    assert waits == pytest.approx([1.0, 0.5, 0.0])
    # one flush span, shared under all three roots
    fspans = {id(q.trace.find("flush")[0]) for q in qs}
    assert len(fspans) == 1
    ev = aq.flush_log[-1]
    assert ev.span is qs[0].trace.find("flush")[0]
    assert ev.span.attrs["reason"] == "max_batch"
    aq.close()


def test_async_shed_span_outcome():
    clk = SimClock()
    eng = _build_engine(n_corpora=1, seed=55, clock=clk)
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0, default_latency=0.01)
    clk.t = 10.0
    q = Query("c0", "word_count")
    fut = aq.submit(q, deadline=9.0)    # already hopeless
    clk.t += 1.0
    aq.poll()
    with pytest.raises(DeadlineExceeded):
        fut.result(timeout=0)
    root = q.trace
    assert root.attrs["outcome"] == "shed" and root.finished
    assert root in list(eng.trace_log)
    aq.close()


def test_disabled_registry_skips_spans_not_counters():
    eng = _build_engine(n_corpora=1, seed=44,
                        registry=MetricsRegistry(enabled=False))
    q = Query("c0", "word_count")
    eng.run([q])
    assert q.trace is None              # no span tree built
    assert eng.stats.queries == 1       # policy counters still count
    assert eng.stats.stage_seconds.labels("execute").count == 0
    assert len(eng.trace_log) == 0


# ------------------------------------------------- serving stat views --
def test_stats_thin_views_are_registry_backed():
    eng = _shared_engine()
    assert eng.stats.queries == int(eng.registry.counter(
        "repro_server_queries_total").value)
    # dict-shaped views behave like dicts (the pre-registry call sites)
    flushes = eng.stats.flushes
    assert flushes == dict(flushes)
    assert flushes.get("no_such_reason", 0) == 0
    assert repr(flushes) == repr(dict(flushes))
    with pytest.raises(KeyError):
        flushes["no_such_reason"]
    sig_fam = eng.registry.counter(
        "repro_server_pack_signatures_total", "", ("signature",))
    assert len(sig_fam.children()) == len(eng.stats.signatures)


# ------------------------------------------------------- bounded logs --
def test_bounded_log_counts_drops():
    reg = MetricsRegistry()
    g = reg.gauge("repro_t_dropped")
    log = BoundedLog(2, gauge=g)
    for i in range(5):
        log.append(i)
    assert list(log) == [3, 4]
    assert log.dropped == 3 and g.value == 3.0
    assert log.maxlen == 2 and len(log) == 2 and log[-1] == 4
    with pytest.raises(ValueError):
        BoundedLog(0)


def test_flush_log_drop_gauge_wired():
    eng = _shared_engine()
    aq = AsyncAnalyticsServer(eng, idle_timeout=100.0, clock=SimClock())
    assert isinstance(aq.flush_log, BoundedLog)
    assert aq.flush_log._gauge is not None
    aq.close()


# ------------------------------------------------- library-layer metrics --
def _global_value(name: str, *labelvalues, labelnames=()) -> float:
    fam = global_registry().counter(name, "", labelnames)
    return fam.labels(*labelvalues).value if labelvalues else fam.value


def test_store_memo_and_ingest_counters():
    rng = np.random.default_rng(5)
    files = [rng.integers(0, 12, 40) for _ in range(3)]
    miss0 = _global_value("repro_store_memo_lookups_total", "miss",
                          labelnames=("result",))
    hit0 = _global_value("repro_store_memo_lookups_total", "hit",
                         labelnames=("result",))
    files0 = _global_value("repro_ingest_files_total")
    corpus = CompressedCorpus.build(files, vocab_size=12)
    assert _global_value("repro_ingest_files_total") - files0 == 3
    corpus.top_down_weights()
    corpus.top_down_weights()
    assert _global_value("repro_store_memo_lookups_total", "miss",
                         labelnames=("result",)) - miss0 == 1
    assert _global_value("repro_store_memo_lookups_total", "hit",
                         labelnames=("result",)) - hit0 == 1
    appends0 = _global_value("repro_store_appends_total")
    corpus.append_files([rng.integers(0, 12, 20)])
    assert _global_value("repro_store_appends_total") - appends0 == 1


def test_kernel_dispatch_counters():
    fam = global_registry().counter("repro_kernel_dispatch_total", "",
                                    ("decision", "path"))
    before = sum(c.value for k, c in fam.children() if k[0] == "ell_vs_seg")
    kops.ell_batched_use_ref(num_edges=64, n=2, rows=8, k=4)
    kops.ell_fused_use_kernel(rows=8)
    after = sum(c.value for k, c in fam.children() if k[0] == "ell_vs_seg")
    assert after - before == 1
    fused = {k[1]: c.value for k, c in fam.children()
             if k[0] == "fused_vs_per_round"}
    assert fused.get("fused", 0) >= 1


def test_trace_annotation_env_gate(monkeypatch):
    from contextlib import nullcontext

    from repro.kernels import autotune

    monkeypatch.delenv(autotune.ANNOTATE_ENV, raising=False)
    assert not autotune.annotations_enabled()
    assert isinstance(autotune.trace_annotation("x"), nullcontext)
    monkeypatch.setenv(autotune.ANNOTATE_ENV, "0")
    assert not autotune.annotations_enabled()
    monkeypatch.setenv(autotune.ANNOTATE_ENV, "1")
    assert autotune.annotations_enabled()
    with autotune.trace_annotation("obs-test"):   # real annotation works
        pass
