"""Documentation stays truthful: every internal reference in README.md and
docs/*.md must resolve to a real file, and the paths/symbols the docs lean
on must exist.  CI runs this as the docs link-check step."""

import os
import re

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# [text](target) markdown links; external schemes and pure anchors exempt
_LINK = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:", "#")


def _doc_files():
    docs = [os.path.join(REPO, "README.md")]
    docs_dir = os.path.join(REPO, "docs")
    if os.path.isdir(docs_dir):
        docs += [os.path.join(docs_dir, f) for f in sorted(
            os.listdir(docs_dir)) if f.endswith(".md")]
    return docs


def test_docs_exist():
    """The documentation pass ships README + architecture + benchmarks."""
    assert os.path.isfile(os.path.join(REPO, "README.md"))
    for name in ("architecture.md", "benchmarks.md"):
        assert os.path.isfile(os.path.join(REPO, "docs", name)), name


@pytest.mark.parametrize("doc", _doc_files(),
                         ids=[os.path.relpath(d, REPO) for d in _doc_files()])
def test_internal_links_resolve(doc):
    text = open(doc, encoding="utf-8").read()
    base = os.path.dirname(doc)
    broken = []
    for target in _LINK.findall(text):
        if target.startswith(_EXTERNAL):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, path))):
            broken.append(target)
    assert not broken, (f"{os.path.relpath(doc, REPO)} has broken internal "
                       f"links: {broken}")


def test_backticked_paths_resolve():
    """Inline-code path references (src/..., tests/..., benchmarks/...,
    docs/...) in the docs point at files that exist — docs rot is caught
    the moment a module moves."""
    pat = re.compile(r"`((?:src|tests|benchmarks|docs|examples|\.github)"
                     r"/[A-Za-z0-9_./-]+)`")
    broken = []
    for doc in _doc_files():
        for path in pat.findall(open(doc, encoding="utf-8").read()):
            if not os.path.exists(os.path.join(REPO, path)):
                broken.append(f"{os.path.relpath(doc, REPO)}: {path}")
    assert not broken, f"stale path references: {broken}"
