"""Per-arch smoke tests (reduced configs, paper-assigned families) +
decode/parallel consistency + SSD equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (apply_lm, decode_step, init_cache, init_lm,
                          prefill_cross, reduced, unbox)

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B, S, rng):
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))
    extra = None
    if cfg.family == "encdec":
        extra = jnp.asarray(rng.normal(
            size=(B, cfg.encoder_seq, cfg.d_model)).astype(np.float32))
    elif cfg.family == "vlm":
        extra = jnp.asarray(rng.normal(
            size=(B, cfg.num_patches, cfg.d_model)).astype(np.float32))
    return toks, extra


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch, rng):
    """One forward + one train step on the reduced config: shapes + no NaNs."""
    cfg = reduced(get_config(arch), dtype="float32")
    params, axes = unbox(init_lm(KEY, cfg))
    B, S = 2, 16
    toks, extra = _inputs(cfg, B, S, rng)
    logits, aux = apply_lm(cfg, params, toks, extra_embeds=extra)
    prefix = cfg.num_patches if cfg.family == "vlm" else 0
    assert logits.shape == (B, S + prefix, cfg.vocab_size)
    assert not jnp.isnan(logits).any()

    from repro.training import AdamW, make_train_step
    step = make_train_step(cfg, AdamW(lr=1e-3))
    batch = {"tokens": toks, "labels": toks}
    if extra is not None:
        batch["extra_embeds"] = extra
    opt_state = AdamW(lr=1e-3).init(params)
    params2, _, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), params, params2))
    assert max(moved) > 0


@pytest.mark.parametrize("arch", ["stablelm_12b", "qwen2_05b",
                                  "qwen2_moe_a27b", "jamba_v01_52b",
                                  "mamba2_27b", "whisper_large_v3"])
def test_decode_matches_parallel(arch, rng):
    over = {}
    base = get_config(arch)
    if base.moe_num_experts:
        over["moe_capacity_factor"] = 4.0   # no-drop: decode == parallel
    cfg = reduced(base, dtype="float32", **over)
    params, _ = unbox(init_lm(jax.random.PRNGKey(1), cfg))
    B, S = 2, 10
    toks, extra = _inputs(cfg, B, S, rng)
    full, _ = apply_lm(cfg, params, toks, extra_embeds=extra)
    cache = init_cache(cfg, B, S)
    if cfg.family == "encdec":
        cache = prefill_cross(cfg, params, cache, extra)
    outs = []
    for t in range(S):
        lg, cache = decode_step(cfg, params, cache, toks[:, t:t + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    scale = float(jnp.abs(full).max())
    assert float(jnp.abs(full - dec).max()) < 3e-3 * max(scale, 1.0)


def test_unroll_matches_scan(rng):
    cfg = reduced(get_config("yi_9b"), dtype="float32")
    params, _ = unbox(init_lm(KEY, cfg))
    toks, _ = _inputs(cfg, 2, 12, rng)
    a, _ = apply_lm(cfg, params, toks, unroll=False)
    b, _ = apply_lm(cfg, params, toks, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_param_counts_match_published():
    expected = {  # billions, loose envelope from the assignment table
        "stablelm_12b": (11.0, 13.5), "qwen15_4b": (3.5, 4.5),
        "yi_9b": (8.0, 9.5), "qwen2_05b": (0.4, 0.6),
        "llama4_maverick": (350.0, 450.0), "qwen2_moe_a27b": (13.0, 15.0),
        "whisper_large_v3": (1.4, 1.8), "jamba_v01_52b": (49.0, 54.0),
        "mamba2_27b": (2.4, 3.0), "pixtral_12b": (11.5, 13.0),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    assert 2.4e9 < get_config("qwen2_moe_a27b").active_param_count() < 3.0e9
    assert 12e9 < get_config("llama4_maverick").active_param_count() < 20e9


def test_ssd_chunk_invariance(rng):
    from repro.models.ssm import _ssd_chunked
    B, S, H, P, N = 1, 29, 2, 4, 3
    X = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    Bv = jnp.asarray(rng.normal(size=(B, S, H, N)).astype(np.float32))
    Cv = jnp.asarray(rng.normal(size=(B, S, H, N)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (B, S, H)).astype(np.float32))
    dA = -dt * 0.7
    outs = [np.asarray(_ssd_chunked(X, Bv, Cv, dt, dA, Q)) for Q in (4, 8, 29, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-5)


def test_chunked_attention_matches_full(rng):
    from repro.models.layers import gqa_attention
    B, S, H, Hkv, D = 2, 64, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    full = gqa_attention(q, k, v, causal=True, chunk=0)
    chunked = gqa_attention(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=1e-4, atol=1e-5)
