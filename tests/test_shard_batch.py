"""Device-sharded batch execution: host-side plan logic on any device
count, in-process sharded runs when >1 device is visible (CI's multidevice
lane forces 8 CPU host devices), and an 8-device subprocess running the
full sharded differential worker."""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core import GrammarBatch, compress_files, flatten, run_batched
from repro.core.batch import CORPUS_AXIS
from repro.distributed.shard_batch import (corpus_mesh, mesh_size,
                                           pad_corpora, run_sharded,
                                           shard_batch)
from repro.serving.analytics_server import AnalyticsServer, Query
from repro.serving.queue import AsyncAnalyticsServer

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (run with "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _mk(rng, vocab=40, nf=2, size=150):
    files = [rng.integers(0, vocab, size) for _ in range(nf)]
    g, n = compress_files(files, vocab)
    return flatten(g, vocab, n)


def _corpora(rng, n):
    return [_mk(rng, vocab=int(rng.integers(20, 60)),
                nf=int(rng.integers(1, 4)),
                size=int(rng.integers(60, 250))) for _ in range(n)]


# --------------------------------------------------------- host-side plan --
def test_pad_corpora_shapes(seeded_rng):
    gas = _corpora(seeded_rng, 5)
    padded, n_real = pad_corpora(gas, 8)
    assert n_real == 5 and len(padded) == 8
    # padding repeats the smallest grammar: no padded dim grows
    smallest = min(gas, key=lambda ga: ga.num_rules)
    assert all(p is smallest for p in padded[5:])
    # already divisible -> untouched
    same, n_real = pad_corpora(gas, 5)
    assert n_real == 5 and all(a is b for a, b in zip(same, gas))
    # multiple=1 never pads
    same, _ = pad_corpora(gas, 1)
    assert len(same) == 5 and all(a is b for a, b in zip(same, gas))
    with pytest.raises(ValueError):
        pad_corpora([], 4)
    with pytest.raises(ValueError):
        pad_corpora(gas, 0)


def test_corpus_mesh_single_device_fallback():
    assert corpus_mesh(max_shards=1) is None
    assert mesh_size(None) == 1
    with pytest.raises(ValueError):
        corpus_mesh(max_shards=0)
    if jax.device_count() < 2:
        # on a single-device host auto-detection yields no mesh, and the
        # whole sharding layer degrades to plain packs
        assert corpus_mesh() is None


def test_shard_validation(seeded_rng):
    gas = _corpora(seeded_rng, 3)
    gb = GrammarBatch.build(gas)
    bad_axis = Mesh(np.array(jax.devices()[:1]), ("data",))
    with pytest.raises(ValueError, match="corpus"):
        gb.shard(bad_axis)
    mesh1 = Mesh(np.array(jax.devices()[:1]), (CORPUS_AXIS,))
    with pytest.raises(ValueError, match="n_real"):
        gb.shard(mesh1, n_real=7)


def test_one_device_mesh_is_equivalent(seeded_rng):
    """A 1-device corpus mesh is legal and bit-equal to the plain pack —
    the degenerate end of the transparent-fallback contract."""
    gas = _corpora(seeded_rng, 3)
    mesh1 = Mesh(np.array(jax.devices()[:1]), (CORPUS_AXIS,))
    gb = GrammarBatch.build(gas)
    gbs = gb.shard(mesh1)
    assert gbs.shards == 1 and gbs.real == 3
    assert gbs.signature == gb.signature
    for method in ("frontier", "leveled", "frontier_ell"):
        want = run_batched(gb, "word_count", method=method)
        got = run_batched(gbs, "word_count", method=method)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


def test_run_sharded_single_device_fallback(seeded_rng):
    """mesh=None (auto-detect finds nothing to shard over on 1 device, or
    the caller passes None on many): run_sharded == run_batched."""
    gas = _corpora(seeded_rng, 3)
    want = run_batched(GrammarBatch.build(gas), "word_count")
    got = run_sharded(gas, "word_count", mesh=corpus_mesh(max_shards=1))
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


def test_signature_records_shard_count(seeded_rng):
    gb = GrammarBatch.build(_corpora(seeded_rng, 2))
    assert gb.signature[-1] == 1 and gb.shards == 1
    assert gb.real == 2 and gb.real_gas == gb.gas


# ------------------------------------------------------------ server knobs --
def test_server_shard_selection_without_mesh(seeded_rng):
    srv = AnalyticsServer(max_batch=4, mesh=None)
    assert srv.shard_count(1) == srv.shard_count(100) == 1
    assert srv.chunk_capacity(1) == srv.chunk_capacity(8) == 4
    with pytest.raises(ValueError):
        srv.chunk_capacity(0)
    with pytest.raises(ValueError):
        AnalyticsServer(shard_min_corpora=0)
    # run_group with a shard target still works (degrades to max_batch)
    for i, ga in enumerate(_corpora(seeded_rng, 6)):
        srv.register(f"c{i}", ga)
    out = srv.run_group("word_count", [f"c{i}" for i in range(6)],
                        target_shards=4)
    assert set(out) == {f"c{i}" for i in range(6)}
    assert srv.stats.sharded_calls == 0


def test_queue_target_shards_validation():
    srv = AnalyticsServer(max_batch=2, mesh=None)
    with pytest.raises(ValueError):
        AsyncAnalyticsServer(srv, target_shards=0)
    q = AsyncAnalyticsServer(srv, target_shards=4)
    assert q.target_shards == 4           # harmless without a mesh


# ----------------------------------------------------- in-process sharded --
@multidevice
def test_sharded_pack_bit_equal_in_process(seeded_rng):
    gas = _corpora(seeded_rng, 5)        # N < device count exercises padding
    mesh = corpus_mesh()
    gb1 = GrammarBatch.build(gas)
    gbs = shard_batch(gas, mesh)
    assert gbs.shards == jax.device_count()
    assert gbs.real == 5 and gbs.n % gbs.shards == 0
    for kind in ("word_count", "term_vector", "sequence_count"):
        for method in ("frontier", "leveled", "frontier_ell",
                       "leveled_ell"):
            want = run_batched(gb1, kind, method=method)
            got = run_batched(gbs, kind, method=method)
            assert len(got) == len(want) == 5
            for w, g in zip(want, got):
                ws = w if isinstance(w, tuple) else (w,)
                gs = g if isinstance(g, tuple) else (g,)
                for a, b in zip(ws, gs):
                    np.testing.assert_array_equal(
                        np.asarray(a), np.asarray(b),
                        err_msg=f"{kind}/{method} diverged under sharding")


@multidevice
def test_server_sharded_mode_in_process(seeded_rng):
    gas = _corpora(seeded_rng, 10)
    srv_s = AnalyticsServer(max_batch=4, shard_min_corpora=2)
    srv_1 = AnalyticsServer(max_batch=4, mesh=None)
    for i, ga in enumerate(gas):
        srv_s.register(f"c{i}", ga)
        srv_1.register(f"c{i}", ga)
    qs = [Query(f"c{i}", "word_count") for i in range(10)]
    for got, want in zip(srv_s.run(qs), srv_1.run(qs)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert srv_s.stats.sharded_calls > 0


# ------------------------------------------------------ 8-device subprocess --
def test_sharded_subprocess():
    """Full sharded differential worker on 8 forced host devices: oracle
    equality on ragged shards, server + queue sharded modes (fast lane —
    this is the sharding layer's primary correctness gate)."""
    worker = os.path.join(os.path.dirname(__file__), "_shard_worker.py")
    r = subprocess.run([sys.executable, worker], capture_output=True,
                       text=True, timeout=1200)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SHARDED ALL OK" in r.stdout
