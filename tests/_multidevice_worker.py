"""Worker run in a subprocess with 8 fake host devices.

Asserts (exit code is the test result):
  1. sharded (2x4 mesh) pjit train step == single-device train step;
  2. gpipe forward == sequential stage composition;
  3. elastic restart: checkpoint from dp=4 resumes on dp=2 with identical
     loss trajectory (same global batch, re-partitioned).
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.data import BatchPipeline, CompressedCorpus, synthetic
from repro.distributed import (batch_shardings, default_rules,
                               param_shardings, reshard_tree)
from repro.models import init_lm, reduced, unbox
from repro.training import AdamW, make_train_step


def tiny():
    cfg = reduced(get_config("yi_9b"), dtype="float32", num_layers=2,
                  d_model=32, num_heads=4, num_kv_heads=2, head_dim=8,
                  d_ff=64, vocab_size=400)
    boxed = init_lm(jax.random.PRNGKey(0), cfg)
    params, axes = unbox(boxed)
    files = synthetic.make_table2_corpus("D")
    cc = CompressedCorpus.build(files, vocab_size=400)
    return cfg, params, axes, cc


def test_sharded_equals_single():
    cfg, params, axes, cc = tiny()
    pl = BatchPipeline(cc, global_batch=8, seq_len=16, seed=0, prefetch=0)
    x, y = pl.batch_at(0)
    batch = {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}
    opt = AdamW(lr=1e-2)
    step = make_train_step(cfg, opt)

    # single device
    p1, _, m1 = jax.jit(step)(params, opt.init(params), batch)

    # 2x4 mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = default_rules(mesh)
    psh = param_shardings(axes, params, mesh, rules)
    params_s = jax.tree.map(jax.device_put, params, psh)
    batch_s = jax.tree.map(jax.device_put, batch,
                           batch_shardings(batch, mesh, rules))
    with mesh:
        p2, _, m2 = jax.jit(step)(params_s, opt.init(params_s), batch_s)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, \
        (float(m1["loss"]), float(m2["loss"]))
    d = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, p2)))
    assert d < 5e-3, d
    print("sharded==single OK", float(m1["loss"]))


def test_gpipe():
    from repro.distributed.pipeline import gpipe, make_pp_mesh
    mesh = make_pp_mesh(4)
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.normal(size=(4, 16, 16)).astype(np.float32)) * 0.5
    mb = jnp.asarray(rng.normal(size=(6, 3, 16)).astype(np.float32))

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    out = gpipe(stage_fn, mesh, 4)(ws, mb)
    ref = mb
    for i in range(4):
        ref = jnp.tanh(ref @ ws[i])
    assert float(jnp.abs(out - ref).max()) < 1e-5
    print("gpipe OK")


def test_elastic():
    import tempfile
    from repro.checkpoint import save_checkpoint, restore_checkpoint
    from repro.distributed.elastic import elastic_pipeline
    cfg, params, axes, cc = tiny()
    opt = AdamW(lr=1e-2)
    step = jax.jit(make_train_step(cfg, opt))

    def run(mesh_shape, start, stop, params, opt_state, losses):
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
        rules = default_rules(mesh)
        params = reshard_tree(params, axes, mesh, rules)
        opt_state = type(opt_state)(
            count=opt_state.count,
            mu=reshard_tree(opt_state.mu, axes, mesh, rules),
            nu=reshard_tree(opt_state.nu, axes, mesh, rules))
        with mesh:
            for s in range(start, stop):
                pl = elastic_pipeline(cc, global_batch=8, seq_len=16, seed=0,
                                      resume_step=s, shard=0, num_shards=1)
                x, y = pl.batch_at(s)
                batch = {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}
                params, opt_state, m = step(params, opt_state, batch)
                losses.append(float(m["loss"]))
        return params, opt_state

    # continuous run on 4x2
    l_ref = []
    p, o = run((4, 2), 0, 6, params, opt.init(params), l_ref)

    # run 0-3 on 4x2, checkpoint, resume 3-6 on 2x4 (elastic shrink of dp)
    l_el = []
    p1, o1 = run((4, 2), 0, 3, params, opt.init(params), l_el)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, {"p": p1, "o": o1})
        tree, st, _ = restore_checkpoint(d, {"p": p1, "o": o1})
    p2, o2 = run((2, 4), 3, 6, tree["p"], tree["o"], l_el)
    np.testing.assert_allclose(l_ref, l_el, rtol=1e-4)
    print("elastic OK", l_ref)


if __name__ == "__main__":
    test_sharded_equals_single()
    test_gpipe()
    test_elastic()
    print("MULTIDEVICE ALL OK")
