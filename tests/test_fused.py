"""Fused multi-round + vector-payload ELL kernels and the autotune table.

Covers the ISSUE-7 kernel surface directly against independent numpy
oracles (topologically-ordered DAG replay), the interpret-mode Pallas
lanes, the dispatch discipline (CPU production -> jnp reference, forced
interpret -> kernels, TPU -> real lowering WITHOUT interpret emulation),
and the autotune table's round-trip / override semantics.  The engine- and
analytics-level equivalence of the same paths lives in test_ell_batched.py
and test_differential.py.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import autotune, ops, ref
from repro.kernels._common import (FORCE_INTERPRET_ENV, force_interpret,
                                   resolve_interpret)
from repro.kernels.propagate_fused import ell_frontier_fused_pallas
from repro.kernels.propagate_vector import ell_propagate_vector_pallas


# --------------------------------------------------------------- helpers --
def _random_dag(rng, R, max_deg):
    """A random rule DAG in ELL form: rule indices are a topological order
    (parents of r come from [0, r)), so a direct numpy replay in index
    order is an exact oracle for the frontier fixpoint."""
    src = np.zeros((R, max_deg), np.int32)
    freq = np.zeros((R, max_deg), np.float32)
    in_deg = np.zeros(R, np.int32)
    w = np.zeros(R, np.float64)
    lvl = np.zeros(R, np.int64)
    w[0] = 1.0
    for r in range(1, R):
        d = int(rng.integers(1, min(max_deg, r) + 1))
        ps = rng.choice(r, size=d, replace=False)
        fs = rng.integers(1, 4, size=d)
        if float((fs * w[ps]).sum()) > (1 << 22):
            # keep every weight an integer < 2^23: exact in float32 under
            # ANY summation order, so the oracle compare stays bit-level
            # (mirrors the production invariant — counts < 2^24)
            ps, fs, d = np.array([0]), np.array([1]), 1
        src[r, :d] = ps
        freq[r, :d] = fs
        in_deg[r] = d
        w[r] = float((fs * w[ps]).sum())
        lvl[r] = 1 + int(lvl[ps].max())
    depth = int(lvl.max())
    return src, freq, in_deg, w.astype(np.float32), depth


def _batch_dags(rng, R, max_deg, n):
    """n independent DAGs padded onto one [n, R, K] plan."""
    parts = [_random_dag(rng, R, max_deg) for _ in range(n)]
    src = np.stack([p[0] for p in parts])
    freq = np.stack([p[1] for p in parts])
    ind = np.stack([p[2] for p in parts])
    want = np.stack([p[3] for p in parts])
    depths = np.array([p[4] for p in parts])
    w0 = np.zeros((n, R), np.float32)
    w0[:, 0] = 1.0
    return (jnp.asarray(src), jnp.asarray(freq),
            jnp.asarray(ind.astype(np.float32)), jnp.asarray(w0),
            want, depths)


# ------------------------------------------------------ fused multi-round --
@pytest.mark.parametrize("R,max_deg,n", [(40, 3, 1), (130, 5, 3),
                                         (500, 4, 2), (257, 2, 4)])
def test_fused_matches_dag_oracle(R, max_deg, n, rng):
    src, freq, ind, w0, want, depths = _batch_dags(rng, R, max_deg, n)
    max_rounds = int(depths.max()) + 1          # == num_levels
    got_ref = ref.ell_frontier_fused_ref(w0, ind, src, freq, max_rounds)
    np.testing.assert_array_equal(np.asarray(got_ref), want)
    got_k, rounds = ell_frontier_fused_pallas(w0, ind, src, freq,
                                              max_rounds, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_k), want)
    # each corpus converges after exactly depth+1 frontier rounds
    np.testing.assert_array_equal(np.asarray(rounds), depths + 1)


def test_fused_rounds_match_ref_counter(rng):
    src, freq, ind, w0, _, depths = _batch_dags(rng, 120, 4, 3)
    max_rounds = int(depths.max()) + 1
    _, r_ref = ref.ell_frontier_fused_ref(w0, ind, src, freq, max_rounds,
                                          with_rounds=True)
    _, r_k = ell_frontier_fused_pallas(w0, ind, src, freq, max_rounds,
                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(r_ref), np.asarray(r_k))


def test_fused_extra_rounds_are_exact_noops(rng):
    """Rounds past convergence must be bit-exact no-ops (the SMEM done
    flag skips them in the kernel; the ref adds literal 0.0)."""
    src, freq, ind, w0, want, depths = _batch_dags(rng, 90, 3, 2)
    exact = int(depths.max()) + 1
    for extra in (0, 3, 10):
        got = np.asarray(ref.ell_frontier_fused_ref(
            w0, ind, src, freq, exact + extra))
        np.testing.assert_array_equal(got, want)
        got_k, rounds = ell_frontier_fused_pallas(
            w0, ind, src, freq, exact + extra, interpret=True)
        np.testing.assert_array_equal(np.asarray(got_k), want)
        # converged corpora never bump the round counter
        np.testing.assert_array_equal(np.asarray(rounds), depths + 1)


@pytest.mark.parametrize("br", [8, 32, 256])
def test_fused_row_block_alignment(br, rng):
    """R not a multiple of br: alignment-padded rows get in_deg = -1 and
    must stay off every frontier (in_deg == 0 would seed them)."""
    src, freq, ind, w0, want, depths = _batch_dags(rng, 101, 3, 2)
    got, _ = ell_frontier_fused_pallas(w0, ind, src, freq,
                                       int(depths.max()) + 1, br=br,
                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_fused_ops_dispatch_and_empty():
    w0 = jnp.zeros((0, 5), jnp.float32)
    ind = jnp.zeros((0, 5), jnp.float32)
    plan = jnp.zeros((0, 5, 2), jnp.float32)
    out = ops.ell_frontier_fused(w0, ind, plan.astype(jnp.int32), plan, 3)
    assert out.shape == (0, 5)
    out, rounds = ops.ell_frontier_fused(w0, ind, plan.astype(jnp.int32),
                                         plan, 3, with_rounds=True)
    assert rounds.shape == (0,)
    assert ops.ell_fused_use_kernel(ops.ELL_FUSED_MAX_RULES)
    assert not ops.ell_fused_use_kernel(ops.ELL_FUSED_MAX_RULES + 1)


def test_fused_ops_ref_and_kernel_agree(rng):
    """ops-level: the CPU production (jnp fori) path and the interpret
    kernel path return identical weights."""
    src, freq, ind, w0, want, depths = _batch_dags(rng, 150, 4, 2)
    mr = int(depths.max()) + 1
    got_prod = ops.ell_frontier_fused(w0, ind, src, freq, mr)
    got_kern = ops.ell_frontier_fused(w0, ind, src, freq, mr,
                                      interpret=True)
    np.testing.assert_array_equal(np.asarray(got_prod), want)
    np.testing.assert_array_equal(np.asarray(got_kern), want)


# ----------------------------------------------------- vector payload ELL --
def _vector_oracle(W, active, src, freq):
    n, rows, k = src.shape
    F = W.shape[-1]
    delta = np.zeros((n, rows, F), np.float32)
    seen = np.zeros((n, rows), np.float32)
    for c in range(n):
        for r in range(rows):
            for j in range(k):
                s = src[c, r, j]
                if freq[c, r, j] > 0:
                    seen[c, r] += active[c, s]
                delta[c, r] += freq[c, r, j] * active[c, s] * W[c, s]
    return delta, seen


@pytest.mark.parametrize("R,K,F,n", [(64, 3, 4, 1), (130, 5, 17, 2),
                                     (300, 2, 129, 1)])
def test_vector_matches_oracle(R, K, F, n, rng):
    W = rng.integers(0, 4, (n, R, F)).astype(np.float32)
    active = (rng.random((n, R)) < 0.4).astype(np.float32)
    src = rng.integers(0, R, (n, R, K)).astype(np.int32)
    freq = rng.integers(0, 3, (n, R, K)).astype(np.float32)
    want_d, want_s = _vector_oracle(W, active, src, freq)
    for got_d, got_s in (
            ref.ell_propagate_vector_ref(jnp.asarray(W), jnp.asarray(active),
                                         jnp.asarray(src), jnp.asarray(freq)),
            ell_propagate_vector_pallas(jnp.asarray(W), jnp.asarray(active),
                                        jnp.asarray(src), jnp.asarray(freq),
                                        interpret=True)):
        np.testing.assert_array_equal(np.asarray(got_d), want_d)
        np.testing.assert_array_equal(np.asarray(got_s), want_s)


@pytest.mark.parametrize("br,wc,fc", [(8, 32, 4), (16, 64, 8), (64, 128, 64)])
def test_vector_block_streaming(br, wc, fc, rng):
    """Multi-chunk streaming on every axis (rule chunks, F-blocks, row
    blocks with ragged sizes) == jnp reference, bit-exact."""
    n, R, K, F = 2, 100, 4, 19
    W = jnp.asarray(rng.integers(0, 4, (n, R, F)).astype(np.float32))
    active = jnp.asarray((rng.random((n, R)) < 0.5).astype(np.float32))
    src = jnp.asarray(rng.integers(0, R, (n, R, K)).astype(np.int32))
    freq = jnp.asarray(rng.integers(0, 3, (n, R, K)).astype(np.float32))
    want_d, want_s = ref.ell_propagate_vector_ref(W, active, src, freq)
    got_d, got_s = ell_propagate_vector_pallas(W, active, src, freq,
                                               br=br, wc=wc, fc=fc,
                                               interpret=True)
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want_d))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


def test_vector_ops_validation_and_empty():
    with pytest.raises(ValueError):
        ops.ell_propagate_vector(jnp.zeros((2, 3), jnp.float32),
                                 jnp.zeros((2, 3), jnp.float32),
                                 jnp.zeros((2, 3, 1), jnp.int32),
                                 jnp.zeros((2, 3, 1), jnp.float32))
    d, s = ops.ell_propagate_vector(jnp.zeros((2, 3, 4), jnp.float32),
                                    jnp.zeros((2, 3), jnp.float32),
                                    jnp.zeros((2, 0, 1), jnp.int32),
                                    jnp.zeros((2, 0, 1), jnp.float32))
    assert d.shape == (2, 0, 4) and s.shape == (2, 0)
    assert ops.ell_vector_plan_ok(1, 1024, 8, 16)
    assert not ops.ell_vector_plan_ok(64, 1 << 18, 64, 1024)


# ----------------------------------------------- dispatch discipline (S1) --
def test_forced_interpret_lane_routes_to_kernels(rng, monkeypatch):
    """REPRO_FORCE_INTERPRET=1 must push production-shaped calls through
    the interpret-mode Pallas kernels instead of the jnp reference."""
    monkeypatch.delenv(FORCE_INTERPRET_ENV, raising=False)
    assert not force_interpret()
    assert resolve_interpret(None) is True        # CPU auto => interpret
    assert ops._use_jnp_ref(None)                 # ...but prod takes jnp

    monkeypatch.setenv(FORCE_INTERPRET_ENV, "1")
    assert force_interpret()
    assert not ops._use_jnp_ref(None)             # lane: kernels run
    calls = []
    real = ops.ell_propagate_batched_pallas

    def spy(*a, **kw):
        calls.append(kw.get("interpret"))
        return real(*a, **kw)

    monkeypatch.setattr(ops, "ell_propagate_batched_pallas", spy)
    w = jnp.asarray(rng.normal(size=(1, 70)).astype(np.float32))
    act = jnp.ones((1, 70), jnp.float32)
    src = jnp.asarray(rng.integers(0, 70, (1, 70, 2)).astype(np.int32))
    frq = jnp.asarray(rng.integers(0, 3, (1, 70, 2)).astype(np.float32))
    got = ops.ell_propagate_batched(w, act, src, frq)
    assert calls == [True]                        # interpret-mode kernel
    want = ref.ell_propagate_batched_ref(w, act, src, frq)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))


def test_tpu_production_never_runs_interpret(rng, monkeypatch):
    """Satellite regression: on TPU, production traffic (interpret=None)
    must reach the Pallas entry with interpret=False — the old
    ``interpret: bool = True`` jit default silently emulated every kernel.
    Backend is faked via the revocable probe (reset_backend_cache)."""

    class _Dev:
        platform = "tpu"

    captured = {}

    def fake_pallas(w, a, s, f, br=0, wc=0, interpret=None):
        captured["interpret"] = interpret
        return ref.ell_propagate_batched_ref(w, a, s, f)

    monkeypatch.delenv(FORCE_INTERPRET_ENV, raising=False)
    monkeypatch.setattr(ops, "ell_propagate_batched_pallas", fake_pallas)
    monkeypatch.setattr(ops.jax, "devices", lambda: [_Dev()])
    ops.reset_backend_cache()
    try:
        assert ops._on_tpu() is True
        assert resolve_interpret(None) is False   # real lowering
        w = jnp.ones((1, 70), jnp.float32)
        src = jnp.zeros((1, 70, 2), jnp.int32)
        frq = jnp.ones((1, 70, 2), jnp.float32)
        ops.ell_propagate_batched(w, w, src, frq)
        assert captured["interpret"] is False
    finally:
        monkeypatch.undo()
        ops.reset_backend_cache()
        assert ops._on_tpu() is False


# ------------------------------------------------------------ autotune --
@pytest.fixture
def tuned_table(tmp_path, monkeypatch):
    """Isolated autotune cache: point CACHE_ENV at a temp file and drop
    the module memo on both entry and exit."""
    path = tmp_path / "tuned.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    autotune.reset_table()
    yield path
    autotune.reset_table()


def test_autotune_table_roundtrip(tuned_table):
    bucket = autotune.shape_bucket(3, 100, 5)
    assert bucket == (4, 128, 8)                 # pow2 rounding
    assert autotune.get_entry("ell_batched", bucket) is None
    assert autotune.tuned_use_ref("ell_batched", bucket) is None
    assert autotune.tuned_blocks("ell_batched", bucket) == {}
    entry = {"winner": "br128_wc65536", "use_ref": False,
             "blocks": {"br": 128, "wc": 1 << 16}, "us": 10.0}
    autotune.put_entry("ell_batched", bucket, entry)
    autotune.save_table()
    autotune.reset_table()                        # force reload from disk
    got = autotune.get_entry("ell_batched", bucket)
    assert got["winner"] == "br128_wc65536"
    assert autotune.tuned_use_ref("ell_batched", bucket) is False
    assert autotune.tuned_blocks("ell_batched", bucket) == \
        {"br": 128, "wc": 1 << 16}


def test_autotune_corrupt_cache_is_empty(tuned_table):
    tuned_table.write_text("{not json")
    autotune.reset_table()
    assert autotune.load_table() == {}            # never crashes dispatch


def test_tuned_use_ref_overrides_heuristics(tuned_table):
    """An ``ell_vs_seg`` entry must override the static occupancy gates in
    BOTH directions (the tuned table timed the real engines)."""
    # tiny batch: static heuristics say ref...
    assert ops.ell_batched_use_ref(10, 1, 8, 2)
    autotune.put_entry("ell_vs_seg", autotune.shape_bucket(1, 8, 2),
                       {"use_ref": False})
    assert not ops.ell_batched_use_ref(10, 1, 8, 2)
    # healthy shape: static heuristics say kernel...
    assert not ops.ell_batched_use_ref(4000, 4, 1000, 4)
    autotune.put_entry("ell_vs_seg", autotune.shape_bucket(4, 1000, 4),
                       {"use_ref": True})
    assert ops.ell_batched_use_ref(4000, 4, 1000, 4)
    # sharded gate evaluates per-device width under the same override
    autotune.put_entry("ell_vs_seg", autotune.shape_bucket(2, 1000, 4),
                       {"use_ref": False})
    assert not ops.ell_batched_use_ref(4000, 4, 1000, 4, shards=2)


def test_tuned_blocks_feed_kernel_dispatch(tuned_table, rng, monkeypatch):
    """ops.ell_propagate_batched must launch with the TUNED block shape
    for the pack's bucket, falling back to defaults elsewhere."""
    n, R, K = 2, 100, 3
    autotune.put_entry("ell_batched", autotune.shape_bucket(n, R, K),
                       {"blocks": {"br": 128, "wc": 1 << 16,
                                   "bogus": 7}})   # unknown keys dropped
    seen = {}

    def spy(w, a, s, f, br=None, wc=None, interpret=None):
        seen.update(br=br, wc=wc)
        return ref.ell_propagate_batched_ref(w, a, s, f)

    monkeypatch.setattr(ops, "ell_propagate_batched_pallas", spy)
    w = jnp.ones((n, R), jnp.float32)
    src = jnp.asarray(rng.integers(0, R, (n, R, K)).astype(np.int32))
    frq = jnp.ones((n, R, K), jnp.float32)
    ops.ell_propagate_batched(w, w, src, frq, interpret=True)
    assert seen == {"br": 128, "wc": 1 << 16}


def test_tune_sweeps_record_and_persist(tuned_table, rng):
    """The three kernel sweeps run real candidates (interpret mode on CPU)
    and persist winner entries the dispatch layer can read back."""
    n, R, K, F = 1, 70, 2, 3
    src = jnp.asarray(rng.integers(0, R, (n, R, K)).astype(np.int32))
    frq = jnp.asarray(rng.integers(0, 3, (n, R, K)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, R)).astype(np.float32))
    act = jnp.ones((n, R), jnp.float32)
    e1 = autotune.tune_ell_batched(w, act, src, frq, brs=(8,), wcs=(64,),
                                   repeat=1, warmup=0)
    w0 = jnp.zeros((n, R), jnp.float32).at[:, 0].set(1.0)
    ind = jnp.asarray(
        (frq > 0).sum(axis=-1).astype(np.float32))   # consistent in-degrees
    e2 = autotune.tune_ell_fused(w0, ind, src, frq, 4, brs=(8,),
                                 repeat=1, warmup=0)
    W = jnp.asarray(rng.integers(0, 3, (n, R, F)).astype(np.float32))
    e3 = autotune.tune_ell_vector(W, act, src, frq, brs=(8,), fcs=(4,),
                                  repeat=1, warmup=0, save=True)
    for e in (e1, e2, e3):
        assert {"winner", "blocks", "use_ref", "us", "table_us"} <= set(e)
        assert e["us"] <= min(e["table_us"].values()) + 1e-9
    assert autotune.get_entry(
        "ell_batched", autotune.shape_bucket(n, R, K)) is not None
    assert autotune.get_entry(
        "ell_fused", autotune.shape_bucket(n, R, K, 4)) is not None
    assert autotune.get_entry(
        "ell_vector", autotune.shape_bucket(n, R, K, F)) is not None
    autotune.reset_table()                        # save=True hit the disk
    assert autotune.get_entry(
        "ell_vector", autotune.shape_bucket(n, R, K, F)) is not None


def test_sweep_xla_flags_injected_runner(tuned_table):
    """Flag-set sweep with an injected runner: 'default' is always a
    candidate, failures score inf and lose, the winner persists."""
    times = {"default": 2.0, "fast": 1.0, "broken": float("inf")}

    def runner(workload, flags):
        if "broken" in flags:
            return float("inf")
        return 1.0 if "fast" in flags else 2.0

    entry = autotune.sweep_xla_flags(
        "print(0.001)", backend="cpu",
        flag_sets={"fast": {"xla_fast": "true"},
                   "broken": {"xla_broken": "true"}},
        runner=runner)
    assert entry["winner"] == "fast" and entry["flags"] == \
        {"xla_fast": "true"}
    assert entry["table_us"]["broken"] == float("inf")
    assert entry["default_us"] == pytest.approx(times["default"] * 1e6)


def test_sweep_xla_flags_subprocess_runner(tuned_table):
    """The real subprocess runner on a trivial workload (no jax import:
    keeps it fast) — and inf on a failing workload."""
    entry = autotune.sweep_xla_flags("print(0.000001)", backend="cpu",
                                     flag_sets={})
    assert entry["winner"] == "default"
    assert entry["us"] == pytest.approx(1.0)
    bad = autotune._default_runner("raise SystemExit(3)", "")
    assert bad == float("inf")


def test_hlo_profile_reports_roofline(rng):
    """hlo_profile revives the HLO histogram + roofline instrumentation
    for any jitted workload."""
    x = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    out = autotune.hlo_profile(lambda a: a @ a, x)
    assert isinstance(out["ops"], dict) and out["ops"]
    assert out.get("collective_bytes", 0) == 0
    if "intensity" in out:
        assert out["bound"] in ("compute", "bandwidth")
