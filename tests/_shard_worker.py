"""Worker run in a subprocess with 8 fake host devices: device-sharded
batch execution must be bit-identical to the single-device engine AND to
the decompress-then-scan oracle.

Asserts (exit code is the test result):
  1. run_sharded == oracle == single-device run_batched for all six
     analytics, on ragged shard counts: N=5 (< devices), N=11 (not a
     multiple of 8) — frontier and leveled_ell methods;
  2. pack signatures: two sharded packs of different (same-bucket) corpus
     compositions share a signature (compile-cache reuse across traffic);
  3. server: sharded execution (shard_min_corpora) == mesh=None server,
     sharded_calls counted; a single-corpus query arriving in sharded
     mode (shard_min_corpora=1) is bit-equal too;
  4. queue: target_shards > 1 raises the fill condition to
     chunk_capacity and drains bit-equal to the sync path;
  5. search: BM25/TF-IDF top-k through the sharded pack (per-shard
     scoring + top-k, host merge) bit-equal to the decompress-then-scan
     oracle and the single-device batched path on the same ragged shard
     counts, including the sharded server mode;
  6. ingest: corpora grown by CompressedCorpus.append_files, run through
     the sharded pack (epoch stamps padded across shard-padding rows),
     bit-equal to from-scratch rebuilds of the concatenated files AND a
     sharded server serves post-append data after a mid-traffic append;
  7. query operators: filter_count / agg_terms / phrase_count through the
     sharded pack (per-shard predicate eval, aggregation, sequence-plan
     phrase matching) bit-equal to the decompress-then-scan oracle and
     the single-device batched path on the same ragged shard counts,
     including the sharded server mode.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np

import jax

from repro.core import (ANALYTICS_KINDS, GrammarBatch, compress_files,
                        flatten, run_batched)
from repro.distributed.shard_batch import (corpus_mesh, mesh_size,
                                           shard_batch, run_sharded)
from repro.query import run_batched_query
from repro.search import batched_search
from repro.serving.analytics_server import AnalyticsServer, Query
from repro.serving.queue import AsyncAnalyticsServer

from _oracle import (assert_result_equal, full_stream, oracle, oracle_query,
                     oracle_search)

rng = np.random.default_rng(20260801)


def mk(vocab, nf, size):
    files = [rng.integers(0, vocab, size) for _ in range(nf)]
    g, n = compress_files(files, vocab)
    return flatten(g, vocab, n)


def make_corpora(n):
    return [mk(int(rng.integers(25, 80)), int(rng.integers(1, 4)),
               int(rng.integers(80, 300))) for _ in range(n)]


def results_equal(a, b, kind, ctx):
    aa = a if isinstance(a, tuple) else (a,)
    bb = b if isinstance(b, tuple) else (b,)
    assert len(aa) == len(bb), (kind, ctx)
    for x, y in zip(aa, bb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{kind} {ctx}")


def test_sharded_matches_oracle_and_single_device():
    mesh = corpus_mesh()
    assert mesh is not None and mesh_size(mesh) == 8, jax.devices()
    for n in (5, 11):            # N < devices; N not divisible by devices
        gas = make_corpora(n)
        gb1 = GrammarBatch.build(gas)
        streams = [full_stream(ga) for ga in gas]
        for kind in ANALYTICS_KINDS:
            wants = [oracle(ga, kind, stream=s)
                     for ga, s in zip(gas, streams)]
            for method in ("frontier", "leveled_ell", "frontier_fused"):
                got = run_sharded(gas, kind, mesh=mesh, method=method)
                single = run_batched(gb1, kind, method=method)
                assert len(got) == n
                for i, (g_i, w_i, s_i) in enumerate(
                        zip(got, wants, single)):
                    assert_result_equal(
                        g_i, w_i, kind,
                        f"(sharded {method}, N={n}, corpus {i})")
                    results_equal(g_i, s_i, kind,
                                  f"(vs single-device, N={n}, corpus {i})")
    print("sharded == oracle == single-device (ragged N) OK")


def test_shard_signature_reuse():
    mesh = corpus_mesh()
    a = shard_batch(make_corpora(5), mesh)
    b = shard_batch(make_corpora(5), mesh)
    assert a.shards == b.shards == 8
    assert a.signature[-1] == 8
    # same bucketed dims -> same signature -> same compiled programs
    if a.signature == b.signature:
        print("shard signature reuse OK (equal signatures)")
    else:
        # random corpora may land in different buckets; the invariant that
        # MUST hold is padding-to-mesh keeps N a multiple of the shards
        assert a.n % 8 == 0 and b.n % 8 == 0
        print("shard signature reuse OK (different buckets, padded N)")


def test_server_sharded_equals_unsharded():
    gas = {f"c{i}": ga for i, ga in enumerate(make_corpora(18))}
    srv_s = AnalyticsServer(max_batch=4, shard_min_corpora=2)
    srv_1 = AnalyticsServer(max_batch=4, mesh=None)
    for name, ga in gas.items():
        srv_s.register(name, ga)
        srv_1.register(name, ga)
    qs = [Query(f"c{i}", kind) for i in range(18)
          for kind in ("word_count", "term_vector", "sequence_count")]
    for got, want, q in zip(srv_s.run(qs), srv_1.run(qs), qs):
        results_equal(got, want, q.kind, f"(server sharded, {q.corpus})")
    assert srv_s.stats.sharded_calls > 0, srv_s.stats
    assert srv_1.stats.sharded_calls == 0, srv_1.stats

    # a single-corpus query arriving in sharded mode
    srv_one = AnalyticsServer(max_batch=4, shard_min_corpora=1)
    srv_one.register("c0", gas["c0"])
    got = srv_one.run([Query("c0", "word_count")])[0]
    want = srv_1.run([Query("c0", "word_count")])[0]
    results_equal(got, want, "word_count", "(single corpus, sharded mode)")
    assert srv_one.stats.sharded_calls == 1, srv_one.stats
    print("server sharded == unsharded OK "
          f"(sharded_calls={srv_s.stats.sharded_calls})")


def test_queue_target_shards():
    gas = {f"c{i}": ga for i, ga in enumerate(make_corpora(16))}
    srv = AnalyticsServer(max_batch=4, shard_min_corpora=2)
    srv_sync = AnalyticsServer(max_batch=4, mesh=None)
    for name, ga in gas.items():
        srv.register(name, ga)
        srv_sync.register(name, ga)
    assert srv.chunk_capacity(4) == 16
    t = [0.0]
    q = AsyncAnalyticsServer(srv, clock=lambda: t[0], target_shards=4)
    queries = [Query(f"c{i}", "word_count") for i in range(16)]
    futs = [q.submit(qq) for qq in queries]
    q.drain()
    wants = srv_sync.run(queries)
    for f, want, qq in zip(futs, wants, queries):
        results_equal(f.result(timeout=10), want, "word_count",
                      f"(queue target_shards, {qq.corpus})")
    assert srv.stats.sharded_calls > 0, srv.stats
    print("queue target_shards OK "
          f"(flushes={dict(srv.stats.flushes)})")


def test_sharded_search_matches_oracle_and_single_device():
    mesh = corpus_mesh()
    terms = (1, 7, 7, 23, 5000)          # duplicate + out-of-vocab term
    for n in (5, 11):
        gas = make_corpora(n)
        gb1 = GrammarBatch.build(gas)
        streams = [full_stream(ga) for ga in gas]
        for kind, scheme in (("search_bm25", "bm25"),
                             ("search_tfidf", "tfidf")):
            wants = [oracle_search(ga, terms, k=4, scheme=scheme, stream=s)
                     for ga, s in zip(gas, streams)]
            got = run_sharded(gas, kind, mesh=mesh, terms=terms, k=4)
            single = batched_search(gb1, terms, k=4, scheme=scheme)
            assert len(got) == n
            for i, (g_i, w_i, s_i) in enumerate(zip(got, wants, single)):
                assert_result_equal(g_i, w_i, kind,
                                    f"(sharded search, N={n}, corpus {i})")
                results_equal(g_i, s_i, kind,
                              f"(search vs single-device, N={n}, "
                              f"corpus {i})")
    # sharded server mode serves search bit-equal to the unsharded server
    gas = {f"s{i}": ga for i, ga in enumerate(make_corpora(12))}
    srv_s = AnalyticsServer(max_batch=4, shard_min_corpora=2)
    srv_1 = AnalyticsServer(max_batch=4, mesh=None)
    for name, ga in gas.items():
        srv_s.register(name, ga)
        srv_1.register(name, ga)
    qs = [Query(f"s{i}", "search_bm25", terms=terms, k=3)
          for i in range(12)]
    for got, want, q in zip(srv_s.run(qs), srv_1.run(qs), qs):
        results_equal(got, want, q.kind, f"(server sharded search, "
                                         f"{q.corpus})")
    assert srv_s.stats.sharded_calls > 0, srv_s.stats
    print("sharded search == oracle == single-device OK")


def test_sharded_query_operators_match_oracle_and_single_device():
    mesh = corpus_mesh()
    pred = ("or", (("and", (("term", 3, 1), ("term", 7, 2))),
                   ("term", 11, 3), ("term", 5000, 1)))
    cases = [
        ("filter_count", dict(predicate=pred)),
        ("agg_terms", dict(terms=(3, 7, 7, 11, 5000), agg="sum")),
        ("agg_terms", dict(terms=(3, 7, 11), agg="max")),
    ]
    for n in (5, 11):
        gas = make_corpora(n)
        gb1 = GrammarBatch.build(gas)
        streams = [full_stream(ga) for ga in gas]
        # a phrase actually present in corpus 0 (nonzero count somewhere)
        seg0 = streams[0][streams[0] < gas[0].vocab_size]
        phrase = tuple(int(x) for x in seg0[:2])
        for kind, kw in cases + [("phrase_count", dict(terms=phrase))]:
            wants = [oracle_query(ga, kind, stream=s, **kw)
                     for ga, s in zip(gas, streams)]
            got = run_sharded(gas, kind, mesh=mesh, **kw)
            single = run_batched_query(gb1, kind, **kw)
            assert len(got) == n
            for i, (g_i, w_i, s_i) in enumerate(zip(got, wants, single)):
                assert_result_equal(g_i, w_i, kind,
                                    f"(sharded query, N={n}, corpus {i})")
                results_equal(g_i, s_i, kind,
                              f"(query vs single-device, N={n}, "
                              f"corpus {i})")
    # sharded server mode serves query kinds bit-equal to the unsharded
    gas = {f"q{i}": ga for i, ga in enumerate(make_corpora(12))}
    srv_s = AnalyticsServer(max_batch=4, shard_min_corpora=2)
    srv_1 = AnalyticsServer(max_batch=4, mesh=None)
    for name, ga in gas.items():
        srv_s.register(name, ga)
        srv_1.register(name, ga)
    qs = [Query(f"q{i}", kind, **qkw) for i in range(12)
          for kind, qkw in (("filter_count", dict(predicate=pred)),
                            ("agg_terms", dict(terms=(3, 7), agg="max")),
                            ("phrase_count", dict(terms=(3, 7))))]
    for got, want, q in zip(srv_s.run(qs), srv_1.run(qs), qs):
        results_equal(got, want, q.kind,
                      f"(server sharded query, {q.corpus})")
    assert srv_s.stats.sharded_calls > 0, srv_s.stats
    print("sharded query operators == oracle == single-device OK")


def test_sharded_ingest_appended_equals_rebuilt():
    from repro.data import CompressedCorpus

    mesh = corpus_mesh()
    stores, rebuilt = [], []
    for _ in range(5):                   # N=5 < 8 devices: padding + epochs
        vocab = int(rng.integers(25, 60))
        base = [rng.integers(0, vocab, int(rng.integers(60, 150)))
                for _ in range(2)]
        tail = [rng.integers(0, vocab, int(rng.integers(60, 150)))
                for _ in range(int(rng.integers(1, 3)))]
        stores.append(CompressedCorpus.build(base, vocab).append_files(tail))
        rebuilt.append(CompressedCorpus.build(base + tail, vocab))
    gas_a = [c.ga for c in stores]
    gas_r = [c.ga for c in rebuilt]
    # the epoch stamp survives shard padding (pad rows inherit their
    # source row's epoch) and passes against the real-row prefix
    gb = shard_batch(gas_a, mesh, epochs=[c.epoch for c in stores])
    gb.check_epochs([c.epoch for c in stores])
    for kind in ("word_count", "term_vector", "sequence_count"):
        got = run_sharded(gas_a, kind, mesh=mesh)
        want = run_sharded(gas_r, kind, mesh=mesh)
        for i, (g_i, w_i) in enumerate(zip(got, want)):
            results_equal(g_i, w_i, kind,
                          f"(sharded appended vs rebuilt, corpus {i})")

    # sharded server: append mid-traffic, the next sharded flush must
    # serve post-append data (refresh + re-pack on the sharded path too)
    srv = AnalyticsServer(max_batch=4, shard_min_corpora=2)
    srv_ref = AnalyticsServer(max_batch=4, shard_min_corpora=2)
    for i, (s, r) in enumerate(zip(stores, rebuilt)):
        srv.register(f"i{i}", s)
        srv_ref.register(f"i{i}", r)
    qs = [Query(f"i{i}", "word_count") for i in range(5)]
    srv.run(qs)                          # warm the sharded pack cache
    extra = [rng.integers(0, stores[0].ga.vocab_size, 40)]
    stores[0].append_files(extra)
    rebuilt0 = CompressedCorpus.build(
        [stores[0].window(f, 0, int(stores[0].file_lens[f]))
         for f in range(len(stores[0].file_lens))],
        int(stores[0].ga.vocab_size))
    srv_ref.register("i0", rebuilt0)
    got = srv.run(qs)
    want = srv_ref.run(qs)
    for g_i, w_i, q in zip(got, want, qs):
        results_equal(g_i, w_i, q.kind,
                      f"(sharded server post-append, {q.corpus})")
    assert srv.stats.epoch_invalidations >= 1, srv.stats
    print("sharded ingest: appended == rebuilt, post-append serving OK")


if __name__ == "__main__":
    test_sharded_matches_oracle_and_single_device()
    test_shard_signature_reuse()
    test_server_sharded_equals_unsharded()
    test_queue_target_shards()
    test_sharded_search_matches_oracle_and_single_device()
    test_sharded_query_operators_match_oracle_and_single_device()
    test_sharded_ingest_appended_equals_rebuilt()
    print("SHARDED ALL OK")
