"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.bincount import weighted_bincount_pallas
from repro.kernels.propagate import ell_row_sums_pallas


@pytest.mark.parametrize("n,v", [(64, 8), (513, 129), (1000, 777),
                                 (5000, 2000), (4096, 512), (100_000, 30_000)])
def test_bincount_shapes(n, v, rng):
    ids = jnp.asarray(rng.integers(0, v, n).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    got = ops.weighted_bincount(ids, vals, v)
    want = ref.weighted_bincount_ref(ids, vals, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("tn,bv", [(128, 128), (512, 512), (256, 1024)])
def test_bincount_block_shapes(tn, bv, rng):
    ids = jnp.asarray(rng.integers(0, 300, 1500).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=1500).astype(np.float32))
    got = weighted_bincount_pallas(ids, vals, 300, tn=tn, bv=bv)
    want = ref.weighted_bincount_ref(ids, vals, 300)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_bincount_negative_ids_ignored(rng):
    ids = jnp.asarray(np.array([-1, 0, 1, -1, 1] * 40, np.int32))
    vals = jnp.ones(200, jnp.float32)
    got = np.asarray(ops.weighted_bincount(ids, vals, 4))
    assert got[0] == 40 and got[1] == 80 and got[2] == 0


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_bincount_val_dtypes(dtype, rng):
    ids = jnp.asarray(rng.integers(0, 50, 600).astype(np.int32))
    vals = jnp.asarray(rng.integers(0, 5, 600).astype(dtype))
    got = ops.weighted_bincount(ids, vals, 50)
    want = ref.weighted_bincount_ref(ids, vals.astype(jnp.float32), 50)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("rows,w,R", [(64, 1, 10), (100, 4, 50),
                                      (1000, 16, 333), (5000, 8, 4000),
                                      (257, 3, 129)])
def test_ell_row_sums_shapes(rows, w, R, rng):
    src = jnp.asarray(rng.integers(0, R, (rows, w)).astype(np.int32))
    freq = jnp.asarray(rng.integers(0, 5, (rows, w)).astype(np.float32))
    wts = jnp.asarray(rng.normal(size=R).astype(np.float32))
    got = ops.ell_row_sums(wts, src, freq)
    want = ref.ell_row_sums_ref(wts, src, freq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("br", [8, 64, 256])
def test_ell_block_shapes(br, rng):
    src = jnp.asarray(rng.integers(0, 77, (300, 5)).astype(np.int32))
    freq = jnp.asarray(rng.integers(0, 3, (300, 5)).astype(np.float32))
    wts = jnp.asarray(rng.normal(size=77).astype(np.float32))
    got = ell_row_sums_pallas(wts, src, freq, br=br)
    want = ref.ell_row_sums_ref(wts, src, freq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


# ------------------------------------------------------ fallback branches --
def test_bincount_empty_input():
    got = ops.weighted_bincount(jnp.zeros(0, jnp.int32),
                                jnp.zeros(0, jnp.float32), 7)
    assert got.shape == (7,) and (np.asarray(got) == 0).all()


def test_ell_empty_input():
    got = ops.ell_row_sums(jnp.ones(5, jnp.float32),
                           jnp.zeros((0, 3), jnp.int32),
                           jnp.zeros((0, 3), jnp.float32))
    assert got.shape == (0,)


@pytest.mark.parametrize("n,v", [(1, 100), (63, 100), (200, 7), (5, 3)])
def test_bincount_small_shape_fallback(n, v, rng):
    """< 64 elements or < 8 bins must route to (and agree with) the ref."""
    assert ops.bincount_use_ref(n, v)
    ids = jnp.asarray(rng.integers(0, v, n).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    got = ops.weighted_bincount(ids, vals, v)
    want = ref.weighted_bincount_ref(ids, vals, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("rows", [1, 63])
def test_ell_small_shape_fallback(rows, rng):
    assert ops.ell_use_ref(50, rows)
    src = jnp.asarray(rng.integers(0, 50, (rows, 4)).astype(np.int32))
    freq = jnp.asarray(rng.integers(0, 3, (rows, 4)).astype(np.float32))
    wts = jnp.asarray(rng.normal(size=50).astype(np.float32))
    got = ops.ell_row_sums(wts, src, freq)
    want = ref.ell_row_sums_ref(wts, src, freq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_ell_no_vmem_cliff():
    """The old ELL_VMEM_WEIGHT_LIMIT hard fallback is gone: weight size no
    longer routes to the ref — the blocked kernel streams chunks."""
    assert not hasattr(ops, "ELL_VMEM_WEIGHT_LIMIT")
    assert not ops.ell_use_ref((3 << 20) + 1, 1000)
    assert not ops.ell_use_ref(100 * (3 << 20), 1 << 20)
    assert not ops.ell_use_ref(1000, 1000)
    assert ops.ell_use_ref(1000, ops.ELL_MIN_ROWS - 1)   # rows floor stays


@pytest.mark.parametrize("wc", [64, 128, 1024])
def test_ell_blocked_weight_chunks(wc, rng):
    """Multi-chunk weight streaming == single-chunk == jnp ref."""
    R = 1000
    src = jnp.asarray(rng.integers(0, R, (300, 5)).astype(np.int32))
    freq = jnp.asarray(rng.integers(0, 3, (300, 5)).astype(np.float32))
    wts = jnp.asarray(rng.normal(size=R).astype(np.float32))
    got = ell_row_sums_pallas(wts, src, freq, br=64, wc=wc)
    want = ref.ell_row_sums_ref(wts, src, freq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.slow
def test_ell_weights_straddle_old_vmem_limit(rng):
    """> 3.5M-rule weight vector through the ops wrapper in interpret mode:
    the blocked kernel must handle it (the fallback used to hide it)."""
    R = (3 << 20) + 4096
    wts = np.zeros(R, np.float32)
    hot = rng.integers(0, R, 512)
    wts[hot] = rng.normal(size=512).astype(np.float32)
    src = jnp.asarray(np.concatenate(
        [hot[:128], rng.integers(0, R, 128)]).reshape(128, 2).astype(np.int32))
    freq = jnp.asarray(rng.integers(1, 4, (128, 2)).astype(np.float32))
    wtsj = jnp.asarray(wts)
    got = ops.ell_row_sums(wtsj, src, freq, interpret=True)
    want = ref.ell_row_sums_ref(wtsj, src, freq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_bincount_batched_matches_per_row(rng):
    ids = rng.integers(0, 40, (5, 300)).astype(np.int32)
    ids[2, 10:20] = -1                            # padding entries ignored
    vals = rng.normal(size=(5, 300)).astype(np.float32)
    got = np.asarray(ops.weighted_bincount_batched(
        jnp.asarray(ids), jnp.asarray(vals), 40))
    assert got.shape == (5, 40)
    for i in range(5):
        want = np.asarray(ref.weighted_bincount_ref(
            jnp.asarray(ids[i]), jnp.asarray(vals[i]), 40))
        np.testing.assert_allclose(got[i], want, rtol=1e-4, atol=1e-4)


def test_bincount_batched_chunking_crossover(rng, monkeypatch):
    """Above the flat-bin limit the batch is chunked; results must match
    the unchunked path exactly, including the single-row degenerate."""
    ids = rng.integers(0, 40, (7, 300)).astype(np.int32)
    ids[3, 5:25] = -1
    vals = rng.normal(size=(7, 300)).astype(np.float32)
    want = np.asarray(ops.weighted_bincount_batched(
        jnp.asarray(ids), jnp.asarray(vals), 40))
    for limit, rows in ((120, 3), (40, 1), (80, 2)):
        monkeypatch.setattr(ops, "BINCOUNT_BATCH_FLAT_LIMIT", limit)
        assert ops.bincount_batch_rows(7, 40) == rows
        got = np.asarray(ops.weighted_bincount_batched(
            jnp.asarray(ids), jnp.asarray(vals), 40))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_bincount_batch_rows_predicate():
    limit = ops.BINCOUNT_BATCH_FLAT_LIMIT
    assert ops.bincount_batch_rows(16, limit // 16) == 16       # fits: whole
    assert ops.bincount_batch_rows(16, limit) == 1              # huge vocab
    assert ops.bincount_batch_rows(16, 10 * limit) == 1         # per-row
    assert ops.bincount_batch_rows(1000, limit // 100) == 100   # chunked


def test_on_tpu_cache_resettable(monkeypatch):
    """The backend probe must not leak across monkeypatched backends (the
    old functools.lru_cache did)."""

    class _Dev:
        platform = "tpu"

    assert ops._on_tpu() is False                 # CPU test environment
    try:
        monkeypatch.setattr(ops.jax, "devices", lambda: [_Dev()])
        assert ops._on_tpu() is False             # memo still holds
        ops.reset_backend_cache()
        assert ops._on_tpu() is True              # re-probed after reset
    finally:
        monkeypatch.undo()
        ops.reset_backend_cache()
        assert ops._on_tpu() is False


def test_bincount_batched_empty_and_bad_shapes():
    assert ops.weighted_bincount_batched(
        jnp.zeros((3, 0), jnp.int32), jnp.zeros((3, 0), jnp.float32),
        5).shape == (3, 5)
    with pytest.raises(ValueError):
        ops.weighted_bincount_batched(jnp.zeros((3, 4), jnp.int32),
                                      jnp.zeros((3, 5), jnp.float32), 5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_property_bincount(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(64, 2000))
    v = int(rng.integers(8, 500))
    ids = jnp.asarray(rng.integers(0, v, n).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    got = np.asarray(ops.weighted_bincount(ids, vals, v))
    want = np.zeros(v, np.float32)
    np.add.at(want, np.asarray(ids), np.asarray(vals))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
    # conservation: total mass preserved
    np.testing.assert_allclose(got.sum(), float(vals.sum()), rtol=1e-4,
                               atol=1e-3)
