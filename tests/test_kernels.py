"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.bincount import weighted_bincount_pallas
from repro.kernels.propagate import ell_row_sums_pallas


@pytest.mark.parametrize("n,v", [(64, 8), (513, 129), (1000, 777),
                                 (5000, 2000), (4096, 512), (100_000, 30_000)])
def test_bincount_shapes(n, v, rng):
    ids = jnp.asarray(rng.integers(0, v, n).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    got = ops.weighted_bincount(ids, vals, v)
    want = ref.weighted_bincount_ref(ids, vals, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("tn,bv", [(128, 128), (512, 512), (256, 1024)])
def test_bincount_block_shapes(tn, bv, rng):
    ids = jnp.asarray(rng.integers(0, 300, 1500).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=1500).astype(np.float32))
    got = weighted_bincount_pallas(ids, vals, 300, tn=tn, bv=bv)
    want = ref.weighted_bincount_ref(ids, vals, 300)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_bincount_negative_ids_ignored(rng):
    ids = jnp.asarray(np.array([-1, 0, 1, -1, 1] * 40, np.int32))
    vals = jnp.ones(200, jnp.float32)
    got = np.asarray(ops.weighted_bincount(ids, vals, 4))
    assert got[0] == 40 and got[1] == 80 and got[2] == 0


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_bincount_val_dtypes(dtype, rng):
    ids = jnp.asarray(rng.integers(0, 50, 600).astype(np.int32))
    vals = jnp.asarray(rng.integers(0, 5, 600).astype(dtype))
    got = ops.weighted_bincount(ids, vals, 50)
    want = ref.weighted_bincount_ref(ids, vals.astype(jnp.float32), 50)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("rows,w,R", [(64, 1, 10), (100, 4, 50),
                                      (1000, 16, 333), (5000, 8, 4000),
                                      (257, 3, 129)])
def test_ell_row_sums_shapes(rows, w, R, rng):
    src = jnp.asarray(rng.integers(0, R, (rows, w)).astype(np.int32))
    freq = jnp.asarray(rng.integers(0, 5, (rows, w)).astype(np.float32))
    wts = jnp.asarray(rng.normal(size=R).astype(np.float32))
    got = ops.ell_row_sums(wts, src, freq)
    want = ref.ell_row_sums_ref(wts, src, freq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("br", [8, 64, 256])
def test_ell_block_shapes(br, rng):
    src = jnp.asarray(rng.integers(0, 77, (300, 5)).astype(np.int32))
    freq = jnp.asarray(rng.integers(0, 3, (300, 5)).astype(np.float32))
    wts = jnp.asarray(rng.normal(size=77).astype(np.float32))
    got = ell_row_sums_pallas(wts, src, freq, br=br)
    want = ref.ell_row_sums_ref(wts, src, freq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_ell_propagate_end_to_end(rng):
    R = 120
    src = jnp.asarray(rng.integers(0, R, (200, 4)).astype(np.int32))
    freq = jnp.asarray(rng.integers(0, 4, (200, 4)).astype(np.float32))
    dst = jnp.asarray(rng.integers(0, R, 200).astype(np.int32))
    wts = jnp.asarray(rng.normal(size=R).astype(np.float32))
    got = np.asarray(ops.ell_propagate(wts, src, freq, dst, R))
    sums = np.asarray(ref.ell_row_sums_ref(wts, src, freq))
    want = np.zeros(R)
    np.add.at(want, np.asarray(dst), sums)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_property_bincount(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(64, 2000))
    v = int(rng.integers(8, 500))
    ids = jnp.asarray(rng.integers(0, v, n).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    got = np.asarray(ops.weighted_bincount(ids, vals, v))
    want = np.zeros(v, np.float32)
    np.add.at(want, np.asarray(ids), np.asarray(vals))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
    # conservation: total mass preserved
    np.testing.assert_allclose(got.sum(), float(vals.sum()), rtol=1e-4,
                               atol=1e-3)
