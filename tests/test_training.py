"""Optimizer, gradient compression, FT driver: restart-exactness."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.data import BatchPipeline, CompressedCorpus, synthetic
from repro.models import init_lm, reduced, unbox
from repro.training import (AdamW, FailureInjector, StragglerWatchdog,
                            init_error, int8_roundtrip, topk_compress,
                            topk_wire_bytes, train)


def _tiny():
    cfg = reduced(get_config("qwen2_05b"), dtype="float32", num_layers=2,
                  d_model=32, d_ff=64, vocab_size=400)
    params, _ = unbox(init_lm(jax.random.PRNGKey(0), cfg))
    files = synthetic.make_table2_corpus("D")
    cc = CompressedCorpus.build(files, vocab_size=400)
    pl = BatchPipeline(cc, global_batch=4, seq_len=16, seed=0, prefetch=0)
    return cfg, params, pl


def test_adamw_decreases_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adamw_grad_clip_reported():
    opt = AdamW(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, m = opt.update({"w": jnp.array([3.0, 4.0, 0.0])}, state, params)
    assert abs(float(m["grad_norm"]) - 5.0) < 1e-5


def test_loss_decreases_and_restart_exactness(tmp_path):
    cfg, params, pl = _tiny()
    opt = AdamW(lr=1e-2, warmup_steps=2)
    out = train(cfg, params, opt, pl, steps=10,
                ckpt_dir=str(tmp_path / "a"), ckpt_every=4, log_every=100,
                log=lambda s: None)
    assert out["history"][-1] < out["history"][0]

    params2, _ = unbox(init_lm(jax.random.PRNGKey(0), cfg))
    with pytest.raises(RuntimeError):
        train(cfg, params2, opt, pl, steps=10, ckpt_dir=str(tmp_path / "b"),
              ckpt_every=4, injector=FailureInjector(at_step=6),
              log_every=100, log=lambda s: None)
    params3, _ = unbox(init_lm(jax.random.PRNGKey(0), cfg))
    out2 = train(cfg, params3, opt, pl, steps=10,
                 ckpt_dir=str(tmp_path / "b"), ckpt_every=4, log_every=100,
                 log=lambda s: None)
    # crash-resume run converges to the SAME trajectory (deterministic data
    # + checkpointed state)
    np.testing.assert_allclose(out["history"][-3:], out2["history"][-3:],
                               rtol=1e-5)


def test_straggler_watchdog():
    events = []
    wd = StragglerWatchdog(threshold=2.0,
                           on_straggler=lambda s, dt, ema: events.append(s))
    for step, dt in enumerate([1.0, 1.0, 1.1, 5.0, 1.0]):
        wd.observe(step, dt)
    assert events == [3] and wd.events == 1


def test_topk_error_feedback_conserves_mass():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=512).astype(np.float32))}
    err = init_error(g)
    sent = jnp.zeros(512)
    T = 60
    for _ in range(T):
        sparse, err = topk_compress(g, err, k_frac=0.05)
        sent = sent + sparse["w"]
    # EF invariant (exact): everything not yet sent sits in the error
    # buffer — sum(sent) + residual == T * g elementwise
    np.testing.assert_allclose(np.asarray(sent) + np.asarray(err["w"]),
                               T * np.asarray(g["w"]), rtol=1e-4, atol=1e-3)
    # and the residual is sublinear in T (every entry cycles through top-k)
    assert float(jnp.abs(err["w"]).max()) < T * float(jnp.abs(g["w"]).max()) / 2
    # wire bytes: 5% of entries at 8 bytes each
    assert topk_wire_bytes(g, 0.05) == max(1, int(512 * 0.05)) * 8


def test_topk_sparsity():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=1000).astype(np.float32))}
    sparse, _ = topk_compress(g, init_error(g), k_frac=0.01)
    nz = int((np.asarray(sparse["w"]) != 0).sum())
    assert nz <= 12     # ~1% + ties


def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(2)
    g = {"w": jnp.asarray(rng.normal(size=2048).astype(np.float32))}
    rt = int8_roundtrip(g)
    scale = float(jnp.abs(g["w"]).max()) / 127.0
    assert float(jnp.abs(rt["w"] - g["w"]).max()) <= scale * 0.5 + 1e-6


def test_microbatch_accumulation_matches_full_batch():
    from repro.training import make_train_step
    cfg, params, pl = _tiny()
    opt = AdamW(lr=1e-2, clip_norm=0.0)   # clipping differs across schemes
    x, y = pl.batch_at(0)
    batch = {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}
    s1 = make_train_step(cfg, opt, microbatches=1)
    s2 = make_train_step(cfg, opt, microbatches=2)
    p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, opt.init(params), batch)
    d = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, p2)))
    assert d < 5e-3, d
