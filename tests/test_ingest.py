"""Streaming ingestion tier (data/store.py ``append_files``).

Three claims, each with its own enforcement:

* **incremental == from-scratch** — appending files to a live corpus
  yields grammar arrays BIT-identical to rebuilding from the concatenated
  file list (Sequitur is online; both paths run the same op sequence).
  Held to exhaustive field equality here and to full analytics/search
  equality in tests/test_differential.py.
* **invariants survive every append** — the property suite checks the
  full Sequitur invariant set (tests/_invariants.py) after EVERY single
  append, over random and adversarial streams.
* **a stale epoch can never serve** — every memo layer (store weight
  cache, server pack cache, the pack's own epoch stamp) is attacked
  directly: poisoned stale entries must be detected, not returned.
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from _invariants import check_all, expected_stream
from conftest import make_repetitive_files

from repro.core import GrammarBatch, IncrementalSequitur, StaleGrammarError
from repro.core.sequitur import Grammar
from repro.data import CompressedCorpus
from repro.serving import AnalyticsServer, AsyncAnalyticsServer, Query

VOCAB = 30


def _ga_fields_equal(a, b) -> None:
    """Exhaustive GrammarArrays equality: every dataclass field, arrays
    bit-exact — a new field can never silently escape the comparison."""
    for f in dataclasses.fields(type(a)):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray) or hasattr(va, "shape"):
            np.testing.assert_array_equal(
                np.asarray(va), np.asarray(vb),
                err_msg=f"GrammarArrays.{f.name} differs")
        else:
            assert va == vb, f"GrammarArrays.{f.name}: {va} != {vb}"


def _corpora_equal(a: CompressedCorpus, b: CompressedCorpus) -> None:
    _ga_fields_equal(a.ga, b.ga)
    np.testing.assert_array_equal(a.file_starts, b.file_starts)
    np.testing.assert_array_equal(a.file_lens, b.file_lens)


# ------------------------------------------------------------------ core --
def test_append_matches_rebuild_bit_exact(seeded_rng):
    base = make_repetitive_files(seeded_rng, VOCAB, n_files=3)
    tail = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    appended = CompressedCorpus.build(base, VOCAB).append_files(tail)
    rebuilt = CompressedCorpus.build(base + tail, VOCAB)
    _corpora_equal(appended, rebuilt)
    assert appended.epoch == 1 and rebuilt.epoch == 0


def test_repeated_appends_match_rebuild(seeded_rng):
    files = make_repetitive_files(seeded_rng, VOCAB, n_files=6)
    corpus = CompressedCorpus.build(files[:1], VOCAB)
    for i in range(1, len(files)):
        corpus.append_files([files[i]])
        _corpora_equal(corpus, CompressedCorpus.build(files[:i + 1], VOCAB))
    assert corpus.epoch == len(files) - 1


def test_windows_after_append(seeded_rng):
    """Per-file and global windows address the appended files correctly."""
    base = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    tail = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    corpus = CompressedCorpus.build(base, VOCAB).append_files(tail)
    for fid, f in enumerate(base + tail):
        np.testing.assert_array_equal(corpus.window(fid, 0, len(f)), f)
    stream = expected_stream(base + tail, VOCAB)
    np.testing.assert_array_equal(
        corpus.global_window(0, len(stream)), stream)


def test_empty_append_is_noop(seeded_rng):
    files = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    corpus = CompressedCorpus.build(files, VOCAB)
    corpus.top_down_weights()
    keys = corpus.cached_weight_keys()
    assert corpus.append_files([]) is corpus
    assert corpus.epoch == 0 and corpus.cached_weight_keys() == keys


def test_word_token_validation():
    inc = IncrementalSequitur(vocab_size=5)
    with pytest.raises(ValueError, match="outside word range"):
        inc.append_file(np.array([0, 5]))        # splitter-range collision
    with pytest.raises(ValueError, match="outside word range"):
        inc.append_file(np.array([-1]))
    with pytest.raises(ValueError, match="1-D"):
        inc.append_file(np.zeros((2, 2), np.int64))


# -------------------------------------------------------- property suite --
@given(st.lists(st.lists(st.integers(0, 7), min_size=0, max_size=14),
                min_size=1, max_size=6))
def test_invariants_after_every_append(files):
    """Full invariant set after EVERY append of a random stream (tiny
    vocab forces heavy rule formation)."""
    inc = IncrementalSequitur(vocab_size=8)
    so_far = []
    for f in files:
        arr = np.asarray(f, np.int64)
        inc.append_file(arr)
        so_far.append(arr)
        check_all(inc, so_far)


def _adversarial_streams(kind: str, rng):
    if kind == "repetitive":            # one motif tiled: maximal reuse
        phrase = rng.integers(0, 6, 4)
        return [np.tile(phrase, int(rng.integers(2, 6)))
                for _ in range(4)], 6
    if kind == "all_unique":            # no digram ever repeats
        return [np.arange(i * 20, i * 20 + 20, dtype=np.int64)
                for i in range(3)], 60
    if kind == "single_token":          # overlap chains ("aaaa...")
        return [np.zeros(int(rng.integers(1, 12)), np.int64)
                for _ in range(4)], 3
    if kind == "empty":                 # splitter-only files
        return [np.zeros(0, np.int64) for _ in range(3)], 5
    # mixed: empties interleaved with repetitive content
    phrase = rng.integers(0, 5, 5)
    return [np.zeros(0, np.int64), np.tile(phrase, 3),
            np.zeros(0, np.int64), np.tile(phrase, 4),
            phrase], 5


@pytest.mark.parametrize(
    "kind", ["repetitive", "all_unique", "single_token", "empty", "mixed"])
def test_adversarial_streams(kind, seeded_rng):
    files, vocab = _adversarial_streams(kind, seeded_rng)
    inc = IncrementalSequitur(vocab)
    for i, f in enumerate(files):
        inc.append_file(f)
        check_all(inc, files[:i + 1])
    # and the corpus-level append path stays bit-exact on these too
    appended = CompressedCorpus.build(files[:2], vocab).append_files(
        files[2:])
    _corpora_equal(appended, CompressedCorpus.build(files, vocab))


# ------------------------------------------------------------ epoch guard --
def test_append_bumps_epoch_and_invalidates_memos(seeded_rng):
    files = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    tail = make_repetitive_files(seeded_rng, VOCAB, n_files=1)
    corpus = CompressedCorpus.build(files, VOCAB)
    w0 = corpus.top_down_weights()
    assert corpus.cached_weight_keys() == (("top_down", "frontier"),)
    corpus.append_files(tail)
    assert corpus.epoch == 1 and corpus.stats()["epoch"] == 1
    assert corpus.cached_weight_keys() == ()
    w1 = corpus.top_down_weights()
    fresh = CompressedCorpus.build(files + tail, VOCAB).top_down_weights()
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(fresh))
    assert np.asarray(w0).shape != np.asarray(w1).shape or \
        not np.array_equal(np.asarray(w0), np.asarray(w1))


def test_poisoned_stale_memo_is_never_returned(seeded_rng):
    """The memo check happens on READ: even if invalidation-on-append were
    lost, a stale-stamped entry must be recomputed, not served."""
    files = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    corpus = CompressedCorpus.build(files, VOCAB)
    poison = object()
    for key in (("top_down", "frontier"), ("per_file", "frontier")):
        corpus._weights_cache[key] = (corpus.epoch - 1, poison)
    assert corpus.top_down_weights() is not poison
    assert corpus.per_file_weights() is not poison
    # current-epoch entries DO serve (the memo still memoizes)
    w = corpus.top_down_weights()
    assert corpus.top_down_weights() is w


def test_check_epoch_raises_on_stale(seeded_rng):
    files = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    corpus = CompressedCorpus.build(files, VOCAB)
    corpus.check_epoch(0)
    corpus.append_files(make_repetitive_files(seeded_rng, VOCAB, n_files=1))
    with pytest.raises(StaleGrammarError, match="epoch"):
        corpus.check_epoch(0)
    corpus.check_epoch(1)


def test_grammar_batch_epoch_stamp(seeded_rng):
    gas = [CompressedCorpus.build(
        make_repetitive_files(seeded_rng, VOCAB, n_files=2), VOCAB).ga
        for _ in range(2)]
    gb = GrammarBatch.build(gas, epochs=(0, 3))
    gb.check_epochs((0, 3))
    with pytest.raises(StaleGrammarError, match="row 1"):
        gb.check_epochs((0, 4))
    # padded pack: current may be shorter (prefix = the real rows)
    gb.check_epochs((0,))
    with pytest.raises(StaleGrammarError, match="stamped with"):
        gb.check_epochs((0, 3, 0))
    # unstamped packs (no ingest tier in play) never raise
    GrammarBatch.build(gas).check_epochs((7, 7))
    with pytest.raises(ValueError, match="epochs"):
        GrammarBatch.build(gas, epochs=(0,))


# --------------------------------------------------------------- serving --
#: Per-kind query parameters for the mid-ingest serving tests: one
#: representative of each parameter family (plain analytics, search, and
#: the three query operators — every pack-cache flavor must refresh).
_INGEST_QUERY_PARAMS = {
    "word_count": {},
    "search_bm25": dict(terms=(1, 2, 3)),
    "filter_count": dict(predicate=("or", (("and", (("term", 1, 1),
                                                    ("term", 2, 1))),
                                           ("term", 3, 2)))),
    "agg_terms": dict(terms=(1, 2, 2, 50), agg="max"),
    "phrase_count": dict(terms=(1, 2)),
}


def _ingest_query(corpus: str, kind: str) -> Query:
    return Query(corpus=corpus, kind=kind, **_INGEST_QUERY_PARAMS[kind])


def _expected_single(files, vocab, q: Query):
    srv = AnalyticsServer()
    srv.register(q.corpus, CompressedCorpus.build(files, vocab))
    return srv.run([q])[0]


def _assert_results_equal(got, want):
    """Bit-exact result equality over whatever shape a kind returns
    (arrays, or tuples/lists of arrays for the search kinds)."""
    if isinstance(got, (tuple, list)):
        assert isinstance(want, (tuple, list)) and len(got) == len(want)
        for x, y in zip(got, want):
            _assert_results_equal(x, y)
    else:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("kind", list(_INGEST_QUERY_PARAMS))
def test_server_serves_post_append_data(kind, seeded_rng):
    files = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    tail = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    store = CompressedCorpus.build(list(files), VOCAB)
    srv = AnalyticsServer()
    srv.register("c", store)
    q = _ingest_query("c", kind)
    srv.run([q])                         # warm every memo/pack layer
    store.append_files(tail)
    got = srv.run([q])[0]
    _assert_results_equal(got, _expected_single(files + tail, VOCAB, q))
    assert srv.stats.epoch_invalidations >= 1


def test_server_batched_path_refreshes(seeded_rng):
    files_a = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    files_b = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    tail = make_repetitive_files(seeded_rng, VOCAB, n_files=1)
    store_a = CompressedCorpus.build(list(files_a), VOCAB)
    srv = AnalyticsServer()
    srv.register("a", store_a)
    srv.register("b", CompressedCorpus.build(files_b, VOCAB))
    qs = [Query(corpus="a", kind="word_count"),
          Query(corpus="b", kind="word_count")]
    srv.run(qs)                          # populates the pack cache
    assert srv._batches
    store_a.append_files(tail)
    got = srv.run(qs)
    _assert_results_equal(
        got[0],
        _expected_single(files_a + tail, VOCAB,
                         Query(corpus="a", kind="word_count")))
    _assert_results_equal(
        got[1], _expected_single(files_b, VOCAB,
                                 Query(corpus="b", kind="word_count")))


@pytest.mark.parametrize("kind", ["word_count", "filter_count"])
def test_stale_pack_reinserted_into_cache_is_detected(kind, seeded_rng):
    """Attack the pack-cache layer directly: plant a pre-append pack back
    into the cache (simulating a lost purge).  The epoch stamp on the
    cached pack must flag it as a miss — the stale pack cannot serve.
    Query-kind packs ride the same cache, so the attack covers them."""
    files_a = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    files_b = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    store_a = CompressedCorpus.build(list(files_a), VOCAB)
    srv = AnalyticsServer()
    srv.register("a", store_a)
    srv.register("b", CompressedCorpus.build(files_b, VOCAB))
    qs = [_ingest_query("a", kind), _ingest_query("b", kind)]
    srv.run(qs)
    stale_pack = next(iter(srv._batches.values()))
    assert stale_pack.epochs is not None
    tail = make_repetitive_files(seeded_rng, VOCAB, n_files=1)
    store_a.append_files(tail)
    srv.run(qs)                          # refresh purges + rebuilds
    # the lost-purge scenario: overwrite the fresh pack (under whatever
    # key the post-append chunking uses) with the pre-append pack
    key = next(k for k in srv._batches if "a" in k[0])
    srv._batches[key] = stale_pack
    before = srv.stats.epoch_invalidations
    got = srv.run(qs)
    assert srv.stats.epoch_invalidations > before
    assert srv._batches[key] is not stale_pack
    _assert_results_equal(
        got[0], _expected_single(files_a + tail, VOCAB,
                                 _ingest_query("a", kind)))


@pytest.mark.parametrize(
    "kind", ["word_count", "filter_count", "agg_terms", "phrase_count"])
def test_queue_submit_append_drain_serves_fresh(kind, seeded_rng):
    """A query queued BEFORE an append must serve post-append data at
    flush time (the flush-time refresh in execute_chunk) — for the plain
    analytics and every query-operator kind."""
    files = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    tail = make_repetitive_files(seeded_rng, VOCAB, n_files=1)
    store = CompressedCorpus.build(list(files), VOCAB)
    srv = AnalyticsServer()
    srv.register("c", store)
    aq = AsyncAnalyticsServer(srv, max_wait=60.0)
    fut = aq.submit(_ingest_query("c", kind))
    store.append_files(tail)             # mutation lands while queued
    aq.drain()
    _assert_results_equal(
        fut.result(timeout=30),
        _expected_single(files + tail, VOCAB, _ingest_query("c", kind)))


# ------------------------------------------------------------ save / load --
def test_save_load_append_resumes_bit_exact(tmp_path, seeded_rng):
    """A corpus restored from disk (no live compressor state) replays its
    stream on the first append and continues bit-identically."""
    base = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    t1 = make_repetitive_files(seeded_rng, VOCAB, n_files=1)
    t2 = make_repetitive_files(seeded_rng, VOCAB, n_files=2)
    corpus = CompressedCorpus.build(base, VOCAB).append_files(t1)
    path = str(tmp_path / "c.npz")
    corpus.save(path)
    loaded = CompressedCorpus.load(path)
    assert loaded.epoch == 1 and loaded._sq is None
    _corpora_equal(loaded, corpus)
    loaded.append_files(t2)              # replay, then true append
    corpus.append_files(t2)              # live state, no replay
    assert loaded.epoch == corpus.epoch == 2
    _corpora_equal(loaded, corpus)
    _corpora_equal(loaded, CompressedCorpus.build(base + t1 + t2, VOCAB))


# ------------------------------------------------- deep-grammar regression --
def test_expand_survives_deep_chain_grammar():
    """Sequitur-built grammars are log-deep, but expand() must not assume
    that: a 3000-deep chain killed the old recursive form (RecursionError)
    long before Python's default limit in frames-per-level terms."""
    depth = 3000
    nt = 2
    rules = [np.array([0, nt + i + 1, 1], np.int64) for i in range(depth)]
    rules.append(np.array([0, 1], np.int64))
    g = Grammar(num_terminals=nt, rules=rules)
    out = g.expand(0)
    want = np.concatenate([np.zeros(depth + 1, np.int64),
                           np.ones(depth + 1, np.int64)])
    np.testing.assert_array_equal(out, want)


# ------------------------------------------------------- nightly fuzz lane --
@pytest.mark.slow
@pytest.mark.ingest_fuzz
@settings(max_examples=int(os.environ.get("INGEST_FUZZ_EXAMPLES", "200")),
          deadline=None)
@given(st.lists(st.lists(st.integers(0, 5), min_size=0, max_size=40),
                min_size=1, max_size=10))
def test_ingest_fuzz(files):
    """Nightly lane: many more examples (INGEST_FUZZ_EXAMPLES), invariants
    after every append AND corpus-level bit-exactness per stream."""
    vocab = 6
    inc = IncrementalSequitur(vocab)
    so_far = []
    for f in files:
        arr = np.asarray(f, np.int64)
        inc.append_file(arr)
        so_far.append(arr)
        check_all(inc, so_far)
    if len(so_far) >= 2:
        appended = CompressedCorpus.build(so_far[:1], vocab).append_files(
            so_far[1:])
        _corpora_equal(appended, CompressedCorpus.build(so_far, vocab))
