"""Query-dispatch layer: grouping, batching, memoization, correctness."""

import numpy as np
import pytest

from repro.core import (compress_files, flatten, sequence_count, sort_words,
                        term_vector, word_count)
from repro.data import CompressedCorpus
from repro.serving import AnalyticsServer, Query
from conftest import make_repetitive_files


def _make(rng, vocab, n_files):
    files = make_repetitive_files(rng, vocab, n_files=n_files)
    g, nf = compress_files(files, vocab)
    return flatten(g, vocab, nf), files


@pytest.fixture(scope="module")
def server():
    rng = np.random.default_rng(11)
    srv = AnalyticsServer(max_batch=4)
    gas = {}
    for i, (vocab, n_files) in enumerate([(10, 2), (25, 3), (60, 4),
                                          (18, 1), (35, 5)]):
        ga, _ = _make(rng, vocab, n_files)
        name = f"c{i}"
        srv.register(name, ga)
        gas[name] = ga
    return srv, gas


def test_mixed_queries_match_single_corpus(server):
    srv, gas = server
    queries = [
        Query("c0", "word_count"),
        Query("c2", "term_vector"),
        Query("c1", "word_count"),
        Query("c0", "word_count"),          # duplicate: shares the result
        Query("c3", "sort"),
        Query("c4", "sequence_count", l=3),
        Query("c2", "word_count"),
        Query("c1", "sequence_count", l=3),
    ]
    res = srv.run(queries)
    assert len(res) == len(queries)
    np.testing.assert_allclose(res[0], np.asarray(word_count(gas["c0"])))
    np.testing.assert_allclose(res[1], np.asarray(term_vector(gas["c2"])))
    np.testing.assert_allclose(res[2], np.asarray(word_count(gas["c1"])))
    np.testing.assert_allclose(res[3], res[0])
    o, c = sort_words(gas["c3"])
    assert np.array_equal(res[4][0], np.asarray(o))
    np.testing.assert_allclose(res[4][1], np.asarray(c))
    for name, r in (("c4", res[5]), ("c1", res[7])):
        g_s, c_s = sequence_count(gas[name], l=3, method="frontier")
        assert np.array_equal(r[0], g_s)
        np.testing.assert_allclose(r[1], c_s, rtol=1e-6)
    np.testing.assert_allclose(res[6], np.asarray(word_count(gas["c2"])))


def test_grouping_batches_queries(server):
    srv, gas = server
    before = srv.stats.batched_calls
    srv.run([Query(f"c{i}", "word_count") for i in range(4)])
    # 4 distinct corpora, one kind, max_batch=4 -> exactly one batched call
    assert srv.stats.batched_calls == before + 1


def test_batch_pack_cache(server):
    srv, gas = server
    queries = [Query(f"c{i}", "word_count") for i in range(4)]
    srv.run(queries)
    before = srv.stats.batch_cache_hits
    srv.run(queries)
    assert srv.stats.batch_cache_hits > before


def test_single_corpus_uses_memoized_store_weights():
    rng = np.random.default_rng(3)
    files = make_repetitive_files(rng, vocab=15, n_files=2)
    cc = CompressedCorpus.build(files, vocab_size=15)
    assert cc.cached_weight_keys() == ()
    srv = AnalyticsServer(max_batch=16)
    srv.register("solo", cc)
    r1 = srv.run([Query("solo", "word_count")])[0]
    assert ("top_down", "frontier") in cc.cached_weight_keys()
    w_cached = cc.top_down_weights("frontier")
    assert cc.top_down_weights("frontier") is w_cached      # memoized
    r2 = srv.run([Query("solo", "word_count")])[0]
    np.testing.assert_allclose(r1, r2)
    np.testing.assert_allclose(r1, np.asarray(word_count(cc.ga)))
    cc.clear_weight_cache()
    assert cc.cached_weight_keys() == ()


def test_unknown_corpus_and_kind(server):
    srv, _ = server
    with pytest.raises(KeyError):
        srv.run([Query("nope", "word_count")])
    with pytest.raises(ValueError):
        srv.run([Query("c0", "nope")])


def test_method_validated_and_leveled_served():
    rng = np.random.default_rng(13)
    ga, files = _make(rng, 20, 2)
    with pytest.raises(ValueError):
        AnalyticsServer(method="nope")
    srv = AnalyticsServer(method="auto")         # occupancy dispatch per pack
    assert srv.method == "auto"
    srv_lv = AnalyticsServer(method="leveled")
    ga2, _ = _make(rng, 25, 3)
    srv_lv.register("a", ga)
    srv_lv.register("b", ga2)
    res = srv_lv.run([Query("a", "word_count"),      # batched leveled pair
                      Query("b", "word_count"),
                      Query("a", "term_vector")])    # single-corpus leveled
    np.testing.assert_allclose(res[0], np.asarray(word_count(ga)))
    np.testing.assert_allclose(res[1], np.asarray(word_count(ga2)))
    np.testing.assert_allclose(res[2], np.asarray(term_vector(ga)))
    assert srv_lv.stats.batched_calls == 1 and srv_lv.stats.single_calls == 1


def test_failed_register_leaves_prior_registration_intact():
    rng = np.random.default_rng(14)
    files = make_repetitive_files(rng, vocab=12, n_files=2)
    cc = CompressedCorpus.build(files, vocab_size=12)
    srv = AnalyticsServer()
    srv.register("x", cc)
    with pytest.raises(TypeError):
        srv.register("x", np.zeros(3))           # invalid type
    # prior store (and its memoization fast path) must survive
    srv.run([Query("x", "word_count")])
    assert ("top_down", "frontier") in cc.cached_weight_keys()


def test_reregister_drops_stale_store_weights():
    """Replacing a CompressedCorpus with a bare GrammarArrays under the
    same name must not serve the old store's memoized weights."""
    rng = np.random.default_rng(8)
    files_a = make_repetitive_files(rng, vocab=12, n_files=2)
    cc = CompressedCorpus.build(files_a, vocab_size=12)
    srv = AnalyticsServer()
    srv.register("x", cc)
    srv.run([Query("x", "word_count")])          # memoizes cc's weights
    ga_b, files_b = _make(rng, 12, 2)
    srv.register("x", ga_b)                      # plain arrays, same name
    got = srv.run([Query("x", "word_count")])[0]
    np.testing.assert_allclose(got, np.asarray(word_count(ga_b)))


def test_single_query_memoizes_only_needed_traversal():
    rng = np.random.default_rng(9)
    files = make_repetitive_files(rng, vocab=14, n_files=2)
    cc = CompressedCorpus.build(files, vocab_size=14)
    srv = AnalyticsServer()
    srv.register("y", cc)
    srv.run([Query("y", "word_count")])
    assert cc.cached_weight_keys() == (("top_down", "frontier"),)
    srv.run([Query("y", "term_vector")])
    assert ("per_file", "frontier") in cc.cached_weight_keys()
    # sequence_count reuses the memoized top-down weights
    g1, c1 = srv.run([Query("y", "sequence_count", l=3)])[0]
    g2, c2 = sequence_count(cc.ga, l=3, method="frontier")
    assert np.array_equal(g1, g2)
    np.testing.assert_allclose(c1, c2, rtol=1e-6)


@pytest.mark.parametrize("method", ["frontier_ell", "leveled_ell",
                                    "frontier_fused", "auto"])
def test_ell_methods_served(method):
    """ELL-plan methods run both the batched pair path and the single path
    and still match the single-corpus analytics exactly."""
    rng = np.random.default_rng(21)
    ga, _ = _make(rng, 22, 2)
    ga2, _ = _make(rng, 31, 3)
    srv = AnalyticsServer(method=method)
    srv.register("a", ga)
    srv.register("b", ga2)
    res = srv.run([Query("a", "word_count"),      # batched ELL pair
                   Query("b", "word_count"),
                   Query("a", "term_vector")])    # single-corpus path
    np.testing.assert_allclose(res[0], np.asarray(word_count(ga)))
    np.testing.assert_allclose(res[1], np.asarray(word_count(ga2)))
    np.testing.assert_allclose(res[2], np.asarray(term_vector(ga)))
    assert srv.stats.batched_calls == 1 and srv.stats.single_calls == 1


def test_method_fallbacks_counted(monkeypatch):
    """An explicitly requested ELL method that the shape gates degrade to
    its segment_sum base must be COUNTED in ServerStats.method_fallbacks —
    the historical silent remap is gone."""
    import repro.kernels.ops as kops

    rng = np.random.default_rng(33)
    ga, _ = _make(rng, 24, 3)
    ga2, _ = _make(rng, 30, 2)

    # clean run: gates don't trip on these small packs -> no fallbacks
    srv = AnalyticsServer(method="frontier_ell")
    srv.register("a", ga)
    srv.register("b", ga2)
    srv.run([Query("a", "word_count"), Query("b", "word_count")])
    assert srv.stats.method_fallbacks == {}

    # trip the plan-width valve: every dense plan is now ineligible, so
    # frontier_ell degrades to frontier on both batched and single paths
    monkeypatch.setattr(kops, "ELL_BATCH_MAX_WIDTH", 0)
    srv2 = AnalyticsServer(method="frontier_ell")
    srv2.register("a", ga)
    srv2.register("b", ga2)
    res = srv2.run([Query("a", "word_count"),       # batched pair
                    Query("b", "word_count"),
                    Query("a", "term_vector")])     # single (size-1 pack)
    assert srv2.stats.method_fallbacks == {"frontier_ell->frontier": 2}
    # the degraded engine still produces the exact frontier results
    np.testing.assert_allclose(res[0], np.asarray(word_count(ga)))
    np.testing.assert_allclose(res[2], np.asarray(term_vector(ga)))

    # store-backed single path counts too; search kinds resolve via their
    # per-file base (frontier_fused -> frontier_ell -> frontier here)
    files = make_repetitive_files(rng, vocab=16, n_files=2)
    cc = CompressedCorpus.build(files, vocab_size=16)
    srv3 = AnalyticsServer(method="frontier_fused")
    srv3.register("s", cc)
    srv3.run([Query("s", "word_count"),
              Query("s", "search_bm25", terms=(1, 2), k=2)])
    assert srv3.stats.method_fallbacks == {"frontier_fused->frontier": 1,
                                           "frontier_ell->frontier": 1}


def test_constructor_validation():
    with pytest.raises(ValueError):
        AnalyticsServer(max_batch=0)
    with pytest.raises(ValueError):
        AnalyticsServer(max_cached_batches=0)


def test_invalid_sequence_length_raises(server):
    srv, _ = server
    with pytest.raises(ValueError):           # same contract as direct API
        srv.run([Query("c0", "sequence_count", l=0)])


def test_word_count_l_variants_share_one_group(server):
    """Regression: ``Query.l`` is a sequence_count parameter; stray values
    on other kinds must neither split the group (extra batched calls) nor
    leak into execution."""
    srv, gas = server
    before = srv.stats.batched_calls
    res = srv.run([Query("c0", "word_count", l=3),
                   Query("c1", "word_count", l=9),
                   Query("c2", "word_count", l=5),
                   Query("c3", "word_count", l=7)])
    assert srv.stats.batched_calls == before + 1    # one group, one chunk
    for i, name in enumerate(["c0", "c1", "c2", "c3"]):
        np.testing.assert_allclose(res[i], np.asarray(word_count(gas[name])))


def test_sequence_count_l_still_splits_groups(server):
    srv, gas = server
    before = srv.stats.groups
    res = srv.run([Query("c0", "sequence_count", l=2),
                   Query("c0", "sequence_count", l=3)])
    assert srv.stats.groups == before + 2
    for l, r in zip((2, 3), res):
        g_s, c_s = sequence_count(gas["c0"], l=l, method="frontier")
        assert np.array_equal(r[0], g_s)
        np.testing.assert_allclose(r[1], c_s, rtol=1e-6)


def test_group_key_normalizes_l():
    assert (Query("a", "word_count", l=3).group_key()
            == Query("a", "word_count", l=9).group_key())
    assert Query("a", "word_count", l=9).effective_l() is None
    assert (Query("a", "sequence_count", l=3).group_key()
            != Query("a", "sequence_count", l=4).group_key())


def test_execute_chunk_enforces_l_normalization(server):
    srv, gas = server
    with pytest.raises(ValueError):
        srv.execute_chunk("word_count", ["c0"], l=5)     # stray l
    with pytest.raises(ValueError):
        srv.execute_chunk("sequence_count", ["c0"])      # missing l
    # over-capacity chunk: pinned on an unsharded server — with a corpus
    # mesh the capacity legitimately grows to max_batch * devices
    srv1 = AnalyticsServer(max_batch=4, mesh=None)
    for name, ga in gas.items():
        srv1.register(name, ga)
    with pytest.raises(ValueError):
        srv1.execute_chunk("word_count", [f"c{i}" for i in range(5)])


def test_pack_cache_is_bounded_and_order_canonical():
    rng = np.random.default_rng(7)
    srv = AnalyticsServer(max_batch=2, max_cached_batches=2)
    for i in range(6):
        ga, _ = _make(rng, 10 + i, 2)
        srv.register(f"b{i}", ga)
    # same corpus pair queried in either order must hit one cached pack
    srv.run([Query("b0", "word_count"), Query("b1", "word_count")])
    before = srv.stats.batch_cache_hits
    srv.run([Query("b1", "word_count"), Query("b0", "word_count")])
    assert srv.stats.batch_cache_hits == before + 1
    # cache never exceeds its bound
    for i in range(0, 6, 2):
        srv.run([Query(f"b{i}", "word_count"),
                 Query(f"b{i + 1}", "word_count")])
    assert len(srv._batches) <= 2
