"""End-to-end system behaviour: the paper's pipeline + the LM stack on top.

corpus -> Sequitur compression -> analytics WITHOUT decompression
       -> vocab from compressed-domain counts -> batches via random access
       -> train an LM -> generate.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import sequence_count, sort_words, word_count
from repro.data import BatchPipeline, CompressedCorpus, Tokenizer, synthetic
from repro.models import init_lm, reduced, unbox
from repro.serving import greedy_generate
from repro.training import AdamW, train


def test_end_to_end_compressed_training():
    # 1. corpus, compressed at rest
    files = synthetic.make_table2_corpus("D")
    cc = CompressedCorpus.build(files, vocab_size=400)
    stats = cc.stats()
    assert stats["compression_ratio"] > 1.2

    # 2. analytics directly on compression == direct analytics
    direct = np.bincount(np.concatenate(files), minlength=400)
    assert np.allclose(np.asarray(word_count(cc.ga)), direct)
    order, cnts = sort_words(cc.ga)
    assert np.allclose(np.asarray(cnts), np.sort(direct)[::-1])

    # 3. vocabulary induced from compressed-domain counts
    words = [f"w{i}" for i in range(400)]
    tok = Tokenizer.from_tadoc_counts(words, np.asarray(word_count(cc.ga)))
    assert tok.vocab_size <= 401

    # 4. batches by random-access expansion; train a tiny LM
    cfg = reduced(get_config("qwen2_05b"), dtype="float32", num_layers=2,
                  d_model=32, d_ff=64, vocab_size=400)
    params, _ = unbox(init_lm(jax.random.PRNGKey(0), cfg))
    pl = BatchPipeline(cc, global_batch=4, seq_len=16, seed=0, prefetch=0)
    out = train(cfg, params, AdamW(lr=1e-2, warmup_steps=2), pl, steps=8,
                log_every=100, log=lambda s: None)
    assert out["history"][-1] < out["history"][0]

    # 5. serve a few tokens from the trained model
    prompt = jnp.asarray(pl.batch_at(0)[0][:2, :8])
    gen = greedy_generate(cfg, out["params"], prompt, steps=4)
    assert gen.shape == (2, 4)
    assert int(gen.max()) < cfg.vocab_size


def test_ngram_statistics_for_curation():
    """The data-curation path: corpus-wide 3-gram stats without
    decompression (what dedup/quality filters consume)."""
    files = synthetic.make_table2_corpus("D")
    cc = CompressedCorpus.build(files, vocab_size=400)
    grams, cnt = sequence_count(cc.ga, l=3)
    from collections import Counter
    oracle = Counter()
    for f in files:
        for i in range(len(f) - 2):
            oracle[tuple(int(x) for x in f[i:i + 3])] += 1
    got = {tuple(int(x) for x in grams[i]): float(cnt[i])
           for i in range(len(cnt))}
    assert got == {k: float(v) for k, v in oracle.items()}
    # repeated phrases produce high-count n-grams (the compression signal)
    assert max(got.values()) >= 5
