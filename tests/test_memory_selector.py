"""Memory-pool planning (paper §IV-C, Eq. 1) + traversal strategy selector."""

import numpy as np

from repro.core import (compress_files, flatten, head_tail_upper_limit,
                        stream_upper_limit, plan_local_tables, plan_streams,
                        bottom_up_tables, select_direction, estimate_costs)
from repro.core.sequence import plan_stream
from conftest import make_repetitive_files


def _build(seed=0, vocab=15, n_files=3):
    rng = np.random.default_rng(seed)
    files = make_repetitive_files(rng, vocab, n_files=n_files)
    g, nf = compress_files(files, vocab)
    return flatten(g, vocab, nf)


def test_stream_bound_dominates_actual_stream():
    ga = _build()
    for l in (2, 3, 4):
        sp = plan_stream(ga, l)
        per_rule = np.bincount(
            # stream positions per rule: recompute ownership from windows
            np.repeat(np.arange(ga.num_rules),
                      [len(ga.rule_body(r)) for r in range(ga.num_rules)]))
        bound = stream_upper_limit(ga, l)
        # total stream length bounded
        assert sp.st_kind.shape[0] <= bound.sum()


def test_paper_equation1_formula():
    ga = _build()
    l = 3
    ul = head_tail_upper_limit(ga, l)
    # Equation 1: wordSize + (l-1)*subRuleSize - (l-1)
    for r in (0, min(1, ga.num_rules - 1)):
        b = ga.rule_body(r)
        words = int((b < ga.num_terminals).sum())
        subs = int((b >= ga.num_terminals).sum())
        assert ul[r] == words + (l - 1) * subs - (l - 1)


def test_arena_plans_are_disjoint_and_sized():
    ga = _build()
    plan = plan_local_tables(ga)
    assert plan.total == int(plan.sizes.sum())
    ends = plan.offsets + plan.sizes
    assert (plan.offsets[1:] == ends[:-1]).all()     # contiguous, disjoint
    # bound >= true local table size
    C, _ = bottom_up_tables(ga)
    actual = (np.asarray(C) > 0).sum(axis=1)
    assert (plan.sizes >= np.minimum(actual, ga.vocab_size) - 1e-6).all()


def test_stream_arena():
    ga = _build()
    plan = plan_streams(ga, 3)
    assert plan.total >= plan_stream(ga, 3).st_kind.shape[0]


def test_selector_many_files_prefers_bottom_up():
    # dataset-A-like: many small files -> top-down payload (width F) explodes
    rng = np.random.default_rng(1)
    files = [rng.integers(0, 40, 30) for _ in range(64)]
    g, nf = compress_files(files, 40)
    ga = flatten(g, 40, nf)
    assert select_direction(ga) == "bottom_up"


def test_selector_few_files_prefers_top_down():
    # dataset-B-like: few large files
    rng = np.random.default_rng(2)
    files = [np.tile(rng.integers(0, 500, 200), 10) for _ in range(2)]
    g, nf = compress_files(files, 500)
    ga = flatten(g, 500, nf)
    costs = estimate_costs(ga)
    assert select_direction(ga) == "top_down", costs
