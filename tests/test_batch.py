"""Batched multi-corpus engine == per-corpus sequential loop (property)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (GrammarBatch, batched_per_file_weights,
                        batched_ranked_inverted_index, batched_sequence_count,
                        batched_sort_words, batched_term_vector,
                        batched_top_down_weights, batched_word_count,
                        compress_files, flatten, inverted_index,
                        per_file_weights, ranked_inverted_index, run_batched,
                        sequence_count, sort_words, term_vector,
                        top_down_weights, word_count)
from conftest import make_repetitive_files


def _build_corpus(rng, vocab, n_files, size):
    phrase = rng.integers(0, vocab, int(rng.integers(3, 9)))
    files = []
    for _ in range(n_files):
        parts, total = [], 0
        while total < size:
            p = (phrase if rng.random() < 0.5
                 else rng.integers(0, vocab, int(rng.integers(2, 12))))
            parts.append(p)
            total += len(p)
        files.append(np.concatenate(parts)[:size] if parts
                     else np.zeros(0, np.int64))
    g, nf = compress_files(files, vocab)
    return flatten(g, vocab, nf), files, vocab


def _ragged_batch(rng):
    """>= 4 corpora with wildly different R / V / F, incl. an empty one."""
    specs = [(7, 1, 40), (50, 4, 300), (400, 6, 900), (15, 2, 120),
             (30, 3, 0)]                       # last corpus: empty files
    return [_build_corpus(rng, *s) for s in specs]


@pytest.fixture(scope="module")
def ragged():
    rng = np.random.default_rng(42)
    built = _ragged_batch(rng)
    gas = [b[0] for b in built]
    return GrammarBatch.build(gas), built


def test_batched_weights_match_sequential(ragged):
    gb, built = ragged
    for method in ("frontier", "leveled", "frontier_ell", "leveled_ell"):
        w = np.asarray(batched_top_down_weights(gb, method=method))
        for i, (ga, _, _) in enumerate(built):
            want = np.asarray(top_down_weights(ga, method=method))
            np.testing.assert_allclose(w[i, : ga.num_rules], want,
                                       rtol=1e-6, err_msg=f"corpus {i}")
            assert (w[i, ga.num_rules:] == 0).all()     # padding untouched


def test_batched_per_file_weights_match(ragged):
    gb, built = ragged
    for method in ("frontier", "leveled"):
        Wf = np.asarray(batched_per_file_weights(gb, method=method))
        for i, (ga, _, _) in enumerate(built):
            want = np.asarray(per_file_weights(ga, method="frontier"))
            np.testing.assert_allclose(
                Wf[i, : ga.num_rules, : ga.num_files], want, rtol=1e-6,
                err_msg=f"{method} corpus {i}")
    with pytest.raises(ValueError):
        batched_per_file_weights(gb, method="nope")


def test_batched_word_count_and_sort(ragged):
    gb, built = ragged
    wc = np.asarray(batched_word_count(gb))
    wc_pallas = np.asarray(batched_word_count(gb, backend="pallas"))
    srt = batched_sort_words(gb)
    for i, (ga, files, V) in enumerate(built):
        oracle = np.bincount(np.concatenate(files).astype(np.int64),
                             minlength=V) if any(len(f) for f in files) \
            else np.zeros(V)
        np.testing.assert_allclose(wc[i, :V], oracle)
        np.testing.assert_allclose(wc_pallas[i, :V], oracle, atol=1e-4)
        o_s, c_s = sort_words(ga)
        assert np.array_equal(np.asarray(srt[i][0]), np.asarray(o_s))
        np.testing.assert_allclose(np.asarray(srt[i][1]), np.asarray(c_s))


def test_batched_term_vector_and_indexes(ragged):
    gb, built = ragged
    tv = np.asarray(batched_term_vector(gb))
    for i, (ga, files, V) in enumerate(built):
        want = np.asarray(term_vector(ga))
        got = tv[i, : ga.num_files, :V]
        np.testing.assert_allclose(got, want, rtol=1e-6)
        assert ((got > 0) == np.asarray(inverted_index(ga))).all()
    ranked = batched_ranked_inverted_index(gb)
    for i, (ga, _, _) in enumerate(built):
        r_s, c_s = ranked_inverted_index(ga)
        assert np.array_equal(np.asarray(ranked[i][0]), np.asarray(r_s))
        np.testing.assert_allclose(np.asarray(ranked[i][1]),
                                   np.asarray(c_s))


@pytest.mark.parametrize("l", [2, 3])
def test_batched_sequence_count(ragged, l):
    gb, built = ragged
    got = batched_sequence_count(gb, l=l)
    for i, (ga, _, _) in enumerate(built):
        g_s, c_s = sequence_count(ga, l=l, method="frontier")
        assert np.array_equal(got[i][0], g_s), f"corpus {i}"
        np.testing.assert_allclose(got[i][1], c_s, rtol=1e-6)
    # host-side planning is memoized per (batch, l) and stays correct
    assert l in gb._plan_cache
    again = batched_sequence_count(gb, l=l)
    for i in range(gb.n):
        assert np.array_equal(again[i][0], got[i][0])
        np.testing.assert_allclose(again[i][1], got[i][1])


def test_batch_size_one():
    rng = np.random.default_rng(1)
    files = make_repetitive_files(rng, vocab=20, n_files=3)
    g, nf = compress_files(files, 20)
    ga = flatten(g, 20, nf)
    gb = GrammarBatch.build([ga])
    assert gb.n == 1
    np.testing.assert_allclose(
        np.asarray(batched_word_count(gb))[0, :20], np.asarray(word_count(ga)))
    got = batched_sequence_count(gb, l=3)[0]
    want = sequence_count(ga, l=3, method="frontier")
    assert np.array_equal(got[0], want[0])
    np.testing.assert_allclose(got[1], want[1], rtol=1e-6)


def test_bucketing_reuses_signature():
    """The padded signature is set by the largest corpus (rounded to power
    of two), so swapping the small corpora of a batch must not change it —
    that is what lets the dispatch layer reuse one compiled program."""
    rng = np.random.default_rng(5)
    big = _build_corpus(rng, 200, 5, 800)[0]
    small_a = _build_corpus(rng, 10, 2, 60)[0]
    small_b = _build_corpus(rng, 12, 1, 80)[0]
    sig_a = GrammarBatch.build([big, small_a]).signature
    sig_b = GrammarBatch.build([big, small_b]).signature
    assert sig_a == sig_b
    # bucketed dims are powers of two
    from repro.core.batch import _round_up_pow2
    for x, want in [(1, 8), (8, 8), (9, 16), (1000, 1024)]:
        assert _round_up_pow2(x) == want


def test_run_batched_all_kinds(ragged):
    gb, built = ragged
    for kind in ("word_count", "sort", "inverted_index", "term_vector",
                 "sequence_count", "ranked_inverted_index"):
        res = run_batched(gb, kind)
        assert len(res) == gb.n
    with pytest.raises(ValueError):
        run_batched(gb, "nope")


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 100_000))
def test_property_batched_equals_loop(seed):
    rng = np.random.default_rng(seed)
    n = 4 + int(rng.integers(0, 3))
    built = [_build_corpus(rng, int(rng.integers(5, 120)),
                           int(rng.integers(1, 5)),
                           int(rng.integers(0, 300))) for _ in range(n)]
    gas = [b[0] for b in built]
    gb = GrammarBatch.build(gas)
    wc = np.asarray(batched_word_count(gb))
    tv = np.asarray(batched_term_vector(gb))
    for i, (ga, files, V) in enumerate(built):
        np.testing.assert_allclose(wc[i, :V], np.asarray(word_count(ga)),
                                   rtol=1e-5)
        np.testing.assert_allclose(tv[i, : ga.num_files, :V],
                                   np.asarray(term_vector(ga)), rtol=1e-5)
