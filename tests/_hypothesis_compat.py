"""Optional-``hypothesis`` shim for the property-based test modules.

``hypothesis`` is a dev-only dependency; the tier-1 suite must collect and
pass without it.  When it is installed we re-export the real ``given`` /
``settings`` / ``strategies``.  When it is absent we fall back to a small,
deterministic fixed-example harness: each ``@given(...)`` test becomes a
``pytest.mark.parametrize`` over ``FALLBACK_EXAMPLES`` samples drawn from a
seeded generator (first sample is the boundary/minimal draw of every
strategy, the rest are random).  Coverage is weaker than real hypothesis but
the tests still execute the exact same assertions.

Only the strategy surface the test suite uses is implemented:
``st.integers(lo, hi)`` and ``st.lists(elem, min_size=, max_size=)``.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised implicitly by which branch imports
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np
    import pytest

    HAVE_HYPOTHESIS = False
    FALLBACK_EXAMPLES = 5
    _SEED = 20260801

    class _IntStrategy:
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def example(self, rng, boundary=False):
            if boundary:
                return self.lo
            return int(rng.integers(self.lo, self.hi + 1))

    class _ListStrategy:
        def __init__(self, elem, min_size=0, max_size=10):
            self.elem = elem
            self.min_size, self.max_size = int(min_size), int(max_size)

        def example(self, rng, boundary=False):
            if boundary:
                return [self.elem.example(rng, boundary=True)
                        for _ in range(self.min_size)]
            n = int(rng.integers(self.min_size, self.max_size + 1))
            return [self.elem.example(rng) for _ in range(n)]

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _IntStrategy(min_value, max_value)

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            return _ListStrategy(elem, min_size=min_size, max_size=max_size)

    st = _Strategies()

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            rng = np.random.default_rng(_SEED)
            examples = [
                tuple(s.example(rng, boundary=(i == 0)) for s in strategies)
                for i in range(FALLBACK_EXAMPLES)
            ]
            ids = [f"ex{i}" for i in range(len(examples))]

            @pytest.mark.parametrize("_hc_example", examples, ids=ids)
            def wrapper(_hc_example):
                return fn(*_hc_example)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
