"""Sequitur invariant checkers for the streaming ingestion tests.

The live :class:`~repro.core.sequitur.IncrementalSequitur` state must hold
the two classic Sequitur invariants *at every moment between appends* —
that is what makes incremental ingestion sound:

  * digram uniqueness — no pair of adjacent symbols occurs more than once
    in the grammar (the only tolerated exception: an odd-length run like
    ``aaa`` holds two *overlapping* occurrences of ``(a, a)``, which the
    algorithm deliberately leaves alone);
  * rule utility — enforced lazily by the implementation, so on the LIVE
    state we check refcount *consistency* (the tracked refcount equals the
    number of occurrences), and the >= 2 utility on the EXPORTED grammar,
    where single-use rules have been inlined away.

On top of those, structural health: doubly-linked-list integrity, the
digram index maps exactly the digrams present, no rule other than the
root contains a file splitter (rules never span files), and the exported
grammar expands losslessly back to the original token stream.

These checkers reach into ``_Sequitur`` internals on purpose — they are
the test-side mirror of the data structure, kept separate from the
implementation so a bug cannot hide in shared code.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.sequitur import (GUARD, Grammar, IncrementalSequitur,
                                 _is_rule, _sym_rule)


def body_nodes(sq, rid: int) -> List[int]:
    """Node indices of rule ``rid``'s body, in order (guard excluded)."""
    g = sq.rule_guard[rid]
    nodes: List[int] = []
    n = sq.nxt[g]
    steps = 0
    while not sq._is_guard(n):
        nodes.append(n)
        n = sq.nxt[n]
        steps += 1
        assert steps <= len(sq.val), \
            f"rule {rid} body does not terminate (cycle outside the guard)"
    return nodes


def check_list_integrity(sq) -> None:
    """Every rule body is a well-formed circular doubly-linked list and no
    node is reachable from two places."""
    seen: Dict[int, int] = {}
    for rid in sq.rule_guard:
        g = sq.rule_guard[rid]
        assert sq.val[g] <= GUARD, f"rule {rid} guard has non-guard value"
        for n in [g] + body_nodes(sq, rid):
            assert sq.prv[sq.nxt[n]] == n, \
                f"broken link at node {n} (rule {rid}): prv(nxt(n)) != n"
            assert sq.nxt[sq.prv[n]] == n, \
                f"broken link at node {n} (rule {rid}): nxt(prv(n)) != n"
            assert n not in seen, \
                f"node {n} reachable from rules {seen[n]} and {rid}"
            seen[n] = rid
    for n in sq.free:
        assert n not in seen, f"freed node {n} still reachable (rule {seen[n]})"


def _digram_occurrences(sq) -> Dict[Tuple[int, int], List[Tuple[int, int, int]]]:
    """digram value-pair -> [(rule, position, node)] over every live body."""
    occ: Dict[Tuple[int, int], List[Tuple[int, int, int]]] = {}
    for rid in sq.rule_guard:
        nodes = body_nodes(sq, rid)
        for i in range(len(nodes) - 1):
            d = (sq.val[nodes[i]], sq.val[nodes[i + 1]])
            occ.setdefault(d, []).append((rid, i, nodes[i]))
    return occ


def check_digram_uniqueness(sq) -> None:
    """No digram occurs twice — except overlapping same-symbol runs
    (``aaa``), which must be consecutive positions of ONE rule."""
    for d, occs in _digram_occurrences(sq).items():
        if len(occs) == 1:
            continue
        a, b = d
        assert a == b, \
            f"digram {d} occurs {len(occs)} times at {occs[:4]}"
        rids = {rid for rid, _, _ in occs}
        assert len(rids) == 1, \
            f"overlapping digram {d} spans rules {sorted(rids)}"
        positions = sorted(i for _, i, _ in occs)
        assert positions == list(range(positions[0],
                                       positions[0] + len(positions))), \
            f"digram {d} occurrences {positions} are not one contiguous run"


def check_digram_index(sq) -> None:
    """The index maps exactly the digrams present: every entry points at a
    live occurrence of its key, and every digram in the grammar is
    indexed (at one of its occurrences)."""
    occ = _digram_occurrences(sq)
    for d, n in sq.digrams.items():
        assert d in occ, f"index entry {d} -> node {n} but digram is gone"
        assert n in [node for _, _, node in occ[d]], \
            f"index entry {d} -> node {n} is not an occurrence " \
            f"(live ones: {occ[d]})"
    for d in occ:
        assert d in sq.digrams, f"digram {d} at {occ[d]} is unindexed"


def check_refcounts(sq) -> None:
    """Tracked refcounts equal actual occurrence counts (the export-time
    utility decision — inline vs keep — reads these)."""
    counts = {rid: 0 for rid in sq.rule_guard}
    for rid in sq.rule_guard:
        for n in body_nodes(sq, rid):
            v = sq.val[n]
            if _is_rule(v):
                counts[_sym_rule(v)] += 1
    for rid, want in counts.items():
        have = sq.rule_ref.get(rid, 0)
        assert have == want, \
            f"rule {rid} refcount {have} but {want} occurrence(s)"
    assert counts.get(0, 0) == 0, "the root rule must never be referenced"


def check_splitters_only_in_root(inc: IncrementalSequitur) -> None:
    """Splitter terminals are globally unique, so no rule may ever absorb
    one — rules never span file boundaries."""
    sq = inc._sq
    for rid in sq.rule_guard:
        if rid == 0:
            continue
        for n in body_nodes(sq, rid):
            v = sq.val[n]
            assert not (v >= inc.vocab_size), \
                f"rule {rid} contains splitter terminal {v} " \
                f"(vocab_size={inc.vocab_size}) — a rule spans files"


def check_exported_utility(g: Grammar) -> None:
    """Every exported non-root rule is referenced >= 2 times (single-use
    rules must have been inlined away at export)."""
    refs = {r: 0 for r in range(g.num_rules)}
    for body in g.rules:
        for s in body:
            s = int(s)
            if s >= g.num_terminals:
                refs[s - g.num_terminals] += 1
    assert refs[0] == 0, "exported root rule is referenced"
    for r in range(1, g.num_rules):
        assert refs[r] >= 2, \
            f"exported rule {r} has utility {refs[r]} < 2"


def expected_stream(files: Sequence[np.ndarray], vocab_size: int
                    ) -> np.ndarray:
    """The concatenated terminal stream: each file followed by its unique
    splitter ``vocab_size + file_index``."""
    parts: List[np.ndarray] = []
    for i, f in enumerate(files):
        parts.append(np.asarray(f, np.int64))
        parts.append(np.array([vocab_size + i], np.int64))
    return (np.concatenate(parts) if parts else np.zeros(0, np.int64))


def check_roundtrip(g: Grammar, files: Sequence[np.ndarray],
                    vocab_size: int) -> None:
    """Lossless: expanding the exported root reproduces every appended
    token (with splitters interleaved) exactly."""
    got = g.expand(0) if g.num_rules else np.zeros(0, np.int64)
    want = expected_stream(files, vocab_size)
    assert got.shape == want.shape and bool(np.array_equal(got, want)), \
        f"round-trip mismatch: expanded {got.shape[0]} tokens, " \
        f"expected {want.shape[0]}"


def check_all(inc: IncrementalSequitur,
              files: Sequence[np.ndarray]) -> None:
    """Every invariant, on the live state AND on a fresh export.  Called
    after every single append in the property suite, so a violation is
    pinned to the exact append that introduced it."""
    sq = inc._sq
    check_list_integrity(sq)
    check_digram_uniqueness(sq)
    check_digram_index(sq)
    check_refcounts(sq)
    check_splitters_only_in_root(inc)
    g = inc.export()
    check_exported_utility(g)
    check_roundtrip(g, files, inc.vocab_size)
