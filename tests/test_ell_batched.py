"""Batched ELL propagation: kernel == oracle, ELL engines == segment_sum.

Covers the ISSUE-2 acceptance surface: the fused [N, R, K] kernel against
the jnp reference (interpret mode), the frontier_ell / leveled_ell batched
traversals and all six analytics bit-identical to the segment_sum path on
ragged / empty / size-1 batches, weight vectors straddling the old VMEM
limit, and the occupancy dispatch predicates.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GrammarBatch, batched_per_file_weights,
                        batched_top_down_weights, compress_files, flatten,
                        run_batched, top_down_weights)
from repro.kernels import ops, ref
from repro.kernels.propagate_batched import ell_propagate_batched_pallas


def _build_corpus(rng, vocab, n_files, size):
    phrase = rng.integers(0, vocab, int(rng.integers(3, 9)))
    files = []
    for _ in range(n_files):
        parts, total = [], 0
        while total < size:
            p = (phrase if rng.random() < 0.5
                 else rng.integers(0, vocab, int(rng.integers(2, 12))))
            parts.append(p)
            total += len(p)
        files.append(np.concatenate(parts)[:size] if parts
                     else np.zeros(0, np.int64))
    g, nf = compress_files(files, vocab)
    return flatten(g, vocab, nf)


@pytest.fixture(scope="module")
def ragged_gb():
    """>= 4 corpora with wildly different R / V / F, incl. an empty one."""
    rng = np.random.default_rng(1234)
    specs = [(7, 1, 40), (50, 4, 300), (400, 6, 900), (15, 2, 120),
             (30, 3, 0)]                       # last corpus: empty files
    gas = [_build_corpus(rng, *s) for s in specs]
    return GrammarBatch.build(gas), gas


# --------------------------------------------------------------- kernel --
@pytest.mark.parametrize("n,rows,k,R", [(1, 64, 1, 10), (3, 100, 4, 50),
                                        (2, 300, 16, 333), (4, 257, 3, 129)])
def test_kernel_matches_ref(n, rows, k, R, rng):
    src = jnp.asarray(rng.integers(0, R, (n, rows, k)).astype(np.int32))
    freq = jnp.asarray(rng.integers(0, 3, (n, rows, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, R)).astype(np.float32))
    a = jnp.asarray((rng.random((n, R)) < 0.5).astype(np.float32))
    d, s = ell_propagate_batched_pallas(w, a, src, freq, br=64)
    d_ref, s_ref = ref.ell_propagate_batched_ref(w, a, src, freq)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d_ref),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("wc", [32, 64, 512])
def test_kernel_weight_chunking(wc, rng):
    """Streaming the weight vector through small VMEM chunks must not
    change the result (every source falls in exactly one chunk)."""
    n, rows, k, R = 2, 130, 5, 777
    src = jnp.asarray(rng.integers(0, R, (n, rows, k)).astype(np.int32))
    freq = jnp.asarray(rng.integers(0, 4, (n, rows, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, R)).astype(np.float32))
    a = jnp.asarray((rng.random((n, R)) < 0.7).astype(np.float32))
    d, s = ell_propagate_batched_pallas(w, a, src, freq, br=64, wc=wc)
    d_ref, s_ref = ref.ell_propagate_batched_ref(w, a, src, freq)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d_ref),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-4)


def test_kernel_seen_counts_valid_entries_only(rng):
    """seen must count (freq > 0) entries whose source is active — padding
    (src=0, freq=0) never counts even when the root is active."""
    src = jnp.asarray(np.array([[[1, 0], [0, 0], [1, 2]]], np.int32))
    freq = jnp.asarray(np.array([[[2, 0], [0, 0], [1, 3]]], np.float32))
    w = jnp.asarray(np.array([[1.0, 5.0, 7.0]], np.float32))
    a = jnp.asarray(np.array([[1.0, 1.0, 0.0]], np.float32))  # rule 2 off
    d, s = ops.ell_propagate_batched(w, a, src, freq)
    np.testing.assert_allclose(np.asarray(d)[0], [10.0, 0.0, 5.0])
    np.testing.assert_allclose(np.asarray(s)[0], [1.0, 0.0, 1.0])


@pytest.mark.slow
def test_kernel_weights_straddle_old_vmem_limit(rng):
    """[N, R] weights with R > the old 3.5M-rule limit run through the
    blocked batched kernel in interpret mode (no fallback left to hide it)."""
    R = (3 << 20) + 2048
    rows, k = 96, 2
    w = np.zeros((1, R), np.float32)
    hot = rng.integers(0, R, rows * k)
    w[0, hot] = rng.normal(size=rows * k).astype(np.float32)
    src = jnp.asarray(hot.reshape(1, rows, k).astype(np.int32))
    freq = jnp.asarray(rng.integers(1, 4, (1, rows, k)).astype(np.float32))
    a = jnp.asarray((np.arange(R) % 2 == 0).astype(np.float32)[None, :])
    wj = jnp.asarray(w)
    d, s = ell_propagate_batched_pallas(wj, a, src, freq, interpret=True)
    d_ref, s_ref = ref.ell_propagate_batched_ref(wj, a, src, freq)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d_ref),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-4)


def test_wrapper_validation_and_empty():
    with pytest.raises(ValueError):
        ops.ell_propagate_batched(jnp.zeros((2, 4)), jnp.zeros((2, 4)),
                                  jnp.zeros((2, 4, 3), jnp.int32),
                                  jnp.zeros((2, 4, 2)))
    d, s = ops.ell_propagate_batched(jnp.zeros((2, 4)), jnp.zeros((2, 4)),
                                     jnp.zeros((2, 0, 3), jnp.int32),
                                     jnp.zeros((2, 0, 3)))
    assert d.shape == (2, 0) and s.shape == (2, 0)


# ------------------------------------------------------------- dispatch --
def test_ell_batched_dispatch_predicate():
    # tiny batches never amortize a launch
    assert ops.ell_batched_use_ref(100, 1, 32, 4)
    # absurd plan width
    assert ops.ell_batched_use_ref(10_000, 4, 256,
                                   ops.ELL_BATCH_MAX_WIDTH + 1)
    # pathological sparsity: K-padded work >256x the real edges
    assert ops.ell_batched_use_ref(10, 16, 1024, 512)
    # the bench shape (16 corpora, R_pad 256, K 64, ~3k edges) must take ELL
    assert not ops.ell_batched_use_ref(3000, 16, 256, 64)


def test_auto_method_matches_frontier(ragged_gb):
    gb, _ = ragged_gb
    w_auto = np.asarray(batched_top_down_weights(gb, method="auto"))
    w_frontier = np.asarray(batched_top_down_weights(gb, method="frontier"))
    np.testing.assert_array_equal(w_auto, w_frontier)


def test_ell_plan_layout(ragged_gb):
    gb, gas = ragged_gb
    src, freq, level, num_levels = gb.ell_plan()
    K = gb.ell_plan_width()
    assert src.shape == (gb.n, gb.R_pad, K) and freq.shape == src.shape
    assert (K & (K - 1)) == 0                       # power of two
    assert gb.ell_plan() is gb._plan_cache[("ell",)]   # memoized
    srcn, freqn, leveln = (np.asarray(src), np.asarray(freq),
                           np.asarray(level))
    for i, ga in enumerate(gas):
        # per-rule entry counts == in-degrees; padding is freq 0
        np.testing.assert_array_equal(
            (freqn[i, : ga.num_rules] > 0).sum(axis=1), ga.in_deg)
        assert (freqn[i, ga.num_rules:] == 0).all()
        np.testing.assert_array_equal(leveln[i, : ga.num_rules], ga.level)
        assert (leveln[i, ga.num_rules:] == -1).all()
        # edge multiset round-trips: (parent, child, freq) recoverable
        rows, cols = np.nonzero(freqn[i, : ga.num_rules])
        got = sorted(zip(srcn[i][rows, cols].tolist(), rows.tolist(),
                         freqn[i][rows, cols].astype(int).tolist()))
        want = sorted(zip(ga.edge_parent.tolist(), ga.edge_child.tolist(),
                          ga.edge_freq.tolist()))
        assert got == want
    assert num_levels == max(ga.num_levels for ga in gas)


def test_wide_plan_falls_back_to_segment_sum(monkeypatch):
    """Explicit ELL methods must not build an O(R*K) dense plan when a hub
    rule's in-degree exceeds the width gate — they take the segment_sum
    base (identical results) instead."""
    rng = np.random.default_rng(99)
    gas = [_build_corpus(rng, 40, 2, 250), _build_corpus(rng, 30, 2, 200)]
    gb = GrammarBatch.build(gas)
    want = np.asarray(batched_top_down_weights(gb, method="frontier"))
    for gate in ("ELL_BATCH_MAX_WIDTH", "ELL_PLAN_MAX_ENTRIES"):
        monkeypatch.setattr(ops, gate, 0)
        for method in ("frontier_ell", "leveled_ell"):
            got = np.asarray(batched_top_down_weights(gb, method=method))
            np.testing.assert_array_equal(got, want,
                                          err_msg=f"{gate}/{method}")
        assert ("ell",) not in gb._plan_cache       # plan never built
        got_single = np.asarray(top_down_weights(gas[0], "frontier_ell"))
        np.testing.assert_allclose(
            got_single, np.asarray(top_down_weights(gas[0], "frontier")),
            rtol=1e-6)
        monkeypatch.undo()


def test_single_corpus_ell_cache_evicted_on_gc():
    """The id(ga)-keyed plan memo must die with the grammar: a recycled id
    must never serve another grammar's plan."""
    import gc

    from repro.core import traversal

    rng = np.random.default_rng(101)
    ga = _build_corpus(rng, 35, 2, 200)
    w = np.asarray(top_down_weights(ga, "frontier_ell"))
    np.testing.assert_allclose(
        w, np.asarray(top_down_weights(ga, "frontier")), rtol=1e-6)
    key = ("ell", id(ga))
    assert key in traversal._ENGINE_CACHE
    del ga
    gc.collect()
    assert key not in traversal._ENGINE_CACHE


# -------------------------------------------------- engine equivalence --
def test_ell_engines_match_segment_sum_ragged(ragged_gb):
    gb, gas = ragged_gb
    want = np.asarray(batched_top_down_weights(gb, method="frontier"))
    for method in ("frontier_ell", "leveled_ell", "frontier_fused"):
        got = np.asarray(batched_top_down_weights(gb, method=method))
        np.testing.assert_array_equal(got, want, err_msg=method)
    # and against the single-corpus oracle on true sizes
    for i, ga in enumerate(gas):
        np.testing.assert_allclose(
            want[i, : ga.num_rules],
            np.asarray(top_down_weights(ga, "frontier")), rtol=1e-6)


def test_ell_engines_size1_batch():
    rng = np.random.default_rng(77)
    ga = _build_corpus(rng, 60, 3, 400)
    gb = GrammarBatch.build([ga])
    want = np.asarray(batched_top_down_weights(gb, method="frontier"))
    for method in ("frontier_ell", "leveled_ell", "frontier_fused", "auto"):
        got = np.asarray(batched_top_down_weights(gb, method=method))
        np.testing.assert_array_equal(got, want, err_msg=method)


def test_ell_engines_empty_corpus_batch():
    rng = np.random.default_rng(78)
    gas = [_build_corpus(rng, 20, 2, 0), _build_corpus(rng, 25, 2, 150)]
    gb = GrammarBatch.build(gas)
    want = np.asarray(batched_top_down_weights(gb, method="frontier"))
    for method in ("frontier_ell", "leveled_ell", "frontier_fused"):
        got = np.asarray(batched_top_down_weights(gb, method=method))
        np.testing.assert_array_equal(got, want, err_msg=method)


def test_per_file_ell_engines_match_segment_sum(ragged_gb):
    """The per-file ELL methods run REAL vector-payload [R, F] rounds now
    (kernels/propagate_vector.py) — the historical silent remap to the
    segment_sum bases is gone — and stay bit-identical to them.
    ``frontier_fused`` takes its per-round ELL base per-file (the fused
    kernel is scalar-payload)."""
    gb, _ = ragged_gb
    want = np.asarray(batched_per_file_weights(gb, method="frontier"))
    for method in ("frontier_ell", "frontier_fused"):
        got = np.asarray(batched_per_file_weights(gb, method=method))
        np.testing.assert_array_equal(got, want, err_msg=method)
    want_lv = np.asarray(batched_per_file_weights(gb, method="leveled"))
    got_lv = np.asarray(batched_per_file_weights(gb, method="leveled_ell"))
    np.testing.assert_array_equal(got_lv, want_lv)


@pytest.mark.parametrize("kind", ("word_count", "sort", "inverted_index",
                                  "term_vector", "sequence_count",
                                  "ranked_inverted_index"))
def test_all_six_analytics_ell_vs_segment_sum(ragged_gb, kind):
    gb, _ = ragged_gb
    want = run_batched(gb, kind, method="frontier")
    got = run_batched(gb, kind, method="frontier_ell")
    assert len(got) == len(want)
    for w, g in zip(want, got):
        if isinstance(w, tuple):
            for wi, gi in zip(w, g):
                np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
        else:
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
