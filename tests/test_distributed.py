"""Distribution layer: sharding rules (single device) + 8-device subprocess
(sharded==single, gpipe, elastic resharding)."""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import MeshRules, default_rules, spec_for


class FakeMesh:
    """Just enough of a Mesh for spec_for (axis sizes + names)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def test_spec_for_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = MeshRules(rules={"vocab": "model", "embed": "data",
                             "heads": "model"}, batch_axes=("data",))
    # divisible -> sharded
    assert spec_for(("vocab", "embed"), (160, 32), mesh, rules) == \
        P("model", "data")
    # heads=14 not divisible by 16 -> replicated on that dim
    assert spec_for(("embed", "heads", None), (32, 14, 64), mesh, rules) == \
        P("data",)
    # one mesh axis never used twice
    rules2 = MeshRules(rules={"a": "model", "b": "model"},
                       batch_axes=("data",))
    assert spec_for(("a", "b"), (16, 16), mesh, rules2) == P("model")


def test_default_rules_multipod_fsdp():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    r = default_rules(mesh, fsdp_over_pod=True)
    assert r.assign("embed") == ("pod", "data")
    r2 = default_rules(mesh, fsdp_over_pod=False)
    assert r2.assign("embed") == "data"
    assert r2.batch_axes == ("pod", "data")


def test_trailing_nones_trimmed():
    mesh = FakeMesh({"data": 4, "model": 2})
    rules = MeshRules(rules={"embed": "data"}, batch_axes=("data",))
    spec = spec_for((None, "embed", None, None), (3, 8, 5, 7), mesh, rules)
    assert spec == P(None, "data")


@pytest.mark.slow
def test_multidevice_subprocess():
    """sharded==single, gpipe==sequential, elastic dp 4->2 (8 devices)."""
    worker = os.path.join(os.path.dirname(__file__),
                          "_multidevice_worker.py")
    r = subprocess.run([sys.executable, worker], capture_output=True,
                       text=True, timeout=1200)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "MULTIDEVICE ALL OK" in r.stdout
