import os
import sys
import zlib

# Tests run on the real single CPU device — the dry-run (and only the
# dry-run) forces 512 host devices, in its own process.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# A bench run leaves a machine-tuned AUTOTUNE_cache.json in the repo root;
# the suite must not pick it up (tuned routing entries would make dispatch
# assertions depend on whatever was last benchmarked here).  Tests that
# exercise the tuned table point this env var at their own tmp file.
os.environ.setdefault("REPRO_AUTOTUNE_CACHE",
                      os.path.join(os.path.dirname(__file__),
                                   "_no_autotune_cache.json"))

import numpy as np
import pytest


def test_seed(nodeid: str) -> int:
    """Deterministic per-test numpy seed: a stable hash of the test's node
    id, so every test (and every parametrized example) gets its own stream
    yet reruns reproduce it exactly.  ``REPRO_TEST_SEED`` overrides it — set
    it to the seed printed by a failing run to replay that run."""
    env = os.environ.get("REPRO_TEST_SEED")
    if env is not None:
        return int(env)
    return zlib.crc32(nodeid.encode()) & 0x7FFFFFFF


@pytest.fixture(autouse=True)
def _seed_numpy(request):
    """Seed numpy's global RNG per test (differential/fuzz suites draw from
    it via ``seeded_rng``); the seed is attached to the test item and
    printed in the failure report."""
    seed = test_seed(request.node.nodeid)
    request.node._repro_seed = seed
    np.random.seed(seed)
    yield


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    outcome = yield
    rep = outcome.get_result()
    seed = getattr(item, "_repro_seed", None)
    if rep.failed and seed is not None:
        rep.sections.append(
            ("numpy seed",
             f"REPRO_TEST_SEED={seed}  (rerun with this env var to replay)"))


@pytest.fixture
def seeded_rng(request):
    """Fresh Generator derived from the per-test seed (preferred over the
    global stream for new tests: independent of draw order elsewhere)."""
    return np.random.default_rng(test_seed(request.node.nodeid))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_repetitive_files(rng, vocab, n_files=3, motifs=True):
    """Corpus with nested repetition -> deep grammar DAGs."""
    phrase = rng.integers(0, vocab, int(rng.integers(3, 10)))
    motif = np.tile(phrase, int(rng.integers(2, 5)))
    files = []
    for _ in range(n_files):
        parts = []
        for _ in range(int(rng.integers(2, 8))):
            r = rng.random()
            if motifs and r < 0.5:
                parts.append(motif)
            elif r < 0.75:
                parts.append(phrase)
            else:
                parts.append(rng.integers(0, vocab, int(rng.integers(2, 20))))
        files.append(np.concatenate(parts))
    return files
