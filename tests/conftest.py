import os
import sys

# Tests run on the real single CPU device — the dry-run (and only the
# dry-run) forces 512 host devices, in its own process.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_repetitive_files(rng, vocab, n_files=3, motifs=True):
    """Corpus with nested repetition -> deep grammar DAGs."""
    phrase = rng.integers(0, vocab, int(rng.integers(3, 10)))
    motif = np.tile(phrase, int(rng.integers(2, 5)))
    files = []
    for _ in range(n_files):
        parts = []
        for _ in range(int(rng.integers(2, 8))):
            r = rng.random()
            if motifs and r < 0.5:
                parts.append(motif)
            elif r < 0.75:
                parts.append(phrase)
            else:
                parts.append(rng.integers(0, vocab, int(rng.integers(2, 20))))
        files.append(np.concatenate(parts))
    return files
