"""Checkpointing: roundtrip, atomicity, GC, elastic template restore."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.training import AdamW


def _tree():
    return {"a": jnp.arange(5.0), "nested": {"b": jnp.ones((3, 4)),
                                             "c": jnp.zeros((), jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"note": "x"})
    restored, step, extra = restore_checkpoint(str(tmp_path), t)
    assert step == 7 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip_optimizer_state(tmp_path):
    params = {"w": jnp.ones((4, 4))}
    opt = AdamW()
    st = opt.init(params)
    save_checkpoint(str(tmp_path), 1, {"p": params, "o": st})
    restored, _, _ = restore_checkpoint(str(tmp_path),
                                        {"p": params, "o": st})
    assert restored["o"].count == st.count


def test_latest_pointer_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, t, keep=2)
    assert latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2


def test_interrupted_write_invisible(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    # simulate a crashed writer: stale tmp dir must not affect restores
    os.makedirs(str(tmp_path / "step_000000002.tmp"))
    assert latest_step(str(tmp_path)) == 1
    restored, step, _ = restore_checkpoint(str(tmp_path), t)
    assert step == 1


def test_manager_every_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=3)
    t = _tree()
    saved = [s for s in range(1, 10) if mgr.maybe_save(s, t)]
    assert saved == [3, 6, 9]
    assert mgr.restore_or_none(t)[1] == 9


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"), _tree())
