"""Checkpointing: roundtrip, atomicity, GC, elastic template restore —
plus mid-ingest CompressedCorpus snapshots (save_corpus/restore_corpus)."""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, restore_corpus,
                              save_checkpoint, save_corpus)
from repro.data import CompressedCorpus
from repro.training import AdamW


def _tree():
    return {"a": jnp.arange(5.0), "nested": {"b": jnp.ones((3, 4)),
                                             "c": jnp.zeros((), jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"note": "x"})
    restored, step, extra = restore_checkpoint(str(tmp_path), t)
    assert step == 7 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip_optimizer_state(tmp_path):
    params = {"w": jnp.ones((4, 4))}
    opt = AdamW()
    st = opt.init(params)
    save_checkpoint(str(tmp_path), 1, {"p": params, "o": st})
    restored, _, _ = restore_checkpoint(str(tmp_path),
                                        {"p": params, "o": st})
    assert restored["o"].count == st.count


def test_latest_pointer_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, t, keep=2)
    assert latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2


def test_interrupted_write_invisible(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    # simulate a crashed writer: stale tmp dir must not affect restores
    os.makedirs(str(tmp_path / "step_000000002.tmp"))
    assert latest_step(str(tmp_path)) == 1
    restored, step, _ = restore_checkpoint(str(tmp_path), t)
    assert step == 1


def test_manager_every_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=3)
    t = _tree()
    saved = [s for s in range(1, 10) if mgr.maybe_save(s, t)]
    assert saved == [3, 6, 9]
    assert mgr.restore_or_none(t)[1] == 9


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"), _tree())


# -------------------------------------------- mid-ingest corpus snapshots --
def _mk_corpus(rng, n_files=3, vocab=20):
    phrase = rng.integers(0, vocab, 6)
    files = [np.concatenate([np.tile(phrase, int(rng.integers(2, 5))),
                             rng.integers(0, vocab, 15)])
             for _ in range(n_files)]
    return files, CompressedCorpus.build(files, vocab)


def test_corpus_snapshot_roundtrip_mid_ingest(tmp_path, rng):
    """A snapshot taken between appends restores every grammar array, the
    file table, and the exact ingest epoch (exhaustive over the dataclass
    fields — a new array field cannot silently skip the checkpoint)."""
    files, corpus = _mk_corpus(rng)
    tail, _ = _mk_corpus(rng, n_files=1)
    corpus.append_files(tail[0:1])
    assert corpus.epoch == 1
    save_corpus(str(tmp_path), 42, corpus)
    restored, step = restore_corpus(str(tmp_path))
    assert step == 42 and restored.epoch == 1
    for f in dataclasses.fields(type(corpus.ga)):
        np.testing.assert_array_equal(
            np.asarray(getattr(corpus.ga, f.name)),
            np.asarray(getattr(restored.ga, f.name)),
            err_msg=f"GrammarArrays.{f.name} did not round-trip")
    np.testing.assert_array_equal(corpus.file_starts, restored.file_starts)
    np.testing.assert_array_equal(corpus.file_lens, restored.file_lens)


def test_corpus_snapshot_restore_resumes_ingest(tmp_path, rng):
    """Appending after a restore is bit-identical to never checkpointing
    (the live Sequitur state is replayed), and derived memos start empty —
    computed fresh, at the restored epoch."""
    files, corpus = _mk_corpus(rng)
    more, _ = _mk_corpus(rng, n_files=2)
    save_corpus(str(tmp_path), 1, corpus)
    restored, _ = restore_corpus(str(tmp_path))
    assert restored.cached_weight_keys() == ()
    corpus.append_files(more)
    restored.append_files(more)
    assert restored.epoch == corpus.epoch == 1
    for f in dataclasses.fields(type(corpus.ga)):
        np.testing.assert_array_equal(
            np.asarray(getattr(corpus.ga, f.name)),
            np.asarray(getattr(restored.ga, f.name)),
            err_msg=f"GrammarArrays.{f.name} diverged after resume")
    np.testing.assert_array_equal(
        np.asarray(corpus.top_down_weights()),
        np.asarray(restored.top_down_weights()))


def test_corpus_snapshot_wrong_kind_raises(tmp_path, rng):
    save_checkpoint(str(tmp_path), 3, _tree())
    with pytest.raises(ValueError, match="not a corpus snapshot"):
        restore_corpus(str(tmp_path))


def test_corpus_snapshot_keeps_latest(tmp_path, rng):
    _, corpus = _mk_corpus(rng)
    tail, _ = _mk_corpus(rng, n_files=1)
    save_corpus(str(tmp_path), 1, corpus)
    corpus.append_files(tail[0:1])
    save_corpus(str(tmp_path), 2, corpus)
    restored, step = restore_corpus(str(tmp_path))
    assert step == 2 and restored.epoch == 1
    old, step = restore_corpus(str(tmp_path), step=1)
    assert step == 1 and old.epoch == 0
