"""The six TADOC analytics vs direct (decompressed) oracles (+property)."""

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import (compress_files, flatten, word_count, sort_words,
                        term_vector, inverted_index, ranked_inverted_index,
                        sequence_count, term_vector_sparse)
from conftest import make_repetitive_files


def _build(rng, vocab=None, n_files=None):
    vocab = vocab or int(rng.integers(5, 30))
    files = make_repetitive_files(rng, vocab,
                                  n_files=n_files or int(rng.integers(1, 6)))
    g, nf = compress_files(files, vocab)
    return flatten(g, vocab, nf), files, vocab


def test_word_count_and_sort(rng):
    ga, files, V = _build(rng)
    oracle = np.bincount(np.concatenate(files), minlength=V)
    wc = np.asarray(word_count(ga))
    assert np.allclose(wc, oracle)
    wc_pallas = np.asarray(word_count(ga, backend="pallas"))
    assert np.allclose(wc_pallas, oracle)
    order, cnts = sort_words(ga)
    assert np.allclose(np.asarray(cnts), np.sort(oracle)[::-1])
    assert np.allclose(oracle[np.asarray(order)], np.asarray(cnts))


def test_term_vector_and_indexes(rng):
    ga, files, V = _build(rng)
    oracle = np.stack([np.bincount(f, minlength=V) for f in files])
    tv = np.asarray(term_vector(ga))
    assert np.allclose(tv, oracle)
    ii = np.asarray(inverted_index(ga))
    assert (ii == (oracle > 0)).all()
    rank, rcnt = ranked_inverted_index(ga)
    rank, rcnt = np.asarray(rank), np.asarray(rcnt)
    for v in range(V):
        assert np.allclose(rcnt[v], oracle[rank[v], v])
        assert (np.diff(rcnt[v]) <= 1e-6).all()      # descending


def test_term_vector_sparse_path(rng):
    ga, files, V = _build(rng)
    oracle = np.stack([np.bincount(f, minlength=V) for f in files])
    ff, ww, cc = term_vector_sparse(ga)
    sp = np.zeros((len(files), V))
    if len(ff):
        np.add.at(sp, (ff, ww), cc)
    assert np.allclose(sp, oracle)


def test_term_vector_sparse_equals_dense_shared_subrules():
    """COO triplets reassembled must match the dense [F, V] term vector on
    corpora whose files share sub-rules (the same base phrase everywhere —
    rules are referenced from many files, exercising the sparse frontier's
    cross-file weight propagation)."""
    rng = np.random.default_rng(17)
    vocab = 50
    base = rng.integers(0, vocab, 40)
    files = [np.concatenate([base] * int(rng.integers(2, 5)) +
                            [rng.integers(0, vocab, int(rng.integers(5, 30)))])
             for _ in range(6)]
    g, nf = compress_files(files, vocab)
    ga = flatten(g, vocab, nf)
    assert ga.num_rules > 1               # shared phrases made real sub-rules
    dense = np.asarray(term_vector(ga))
    ff, ww, cc = term_vector_sparse(ga)
    sp = np.zeros_like(dense)
    np.add.at(sp, (ff, ww), cc)
    np.testing.assert_allclose(sp, dense, rtol=1e-6)


def _oracle_ngrams(files, l):
    from collections import Counter
    c = Counter()
    for f in files:
        for i in range(len(f) - l + 1):
            c[tuple(int(x) for x in f[i:i + l])] += 1
    return {k: float(v) for k, v in c.items()}


def test_sequence_count_l2_l3_l5(rng):
    ga, files, V = _build(rng)
    for l in (2, 3, 5):
        grams, cnt = sequence_count(ga, l=l)
        got = {tuple(int(x) for x in grams[i]): float(cnt[i])
               for i in range(len(cnt))}
        assert got == _oracle_ngrams(files, l), f"l={l}"


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 100_000))
def test_property_all_apps(seed):
    rng = np.random.default_rng(seed)
    ga, files, V = _build(rng)
    oracle_tv = np.stack([np.bincount(f, minlength=V) for f in files])
    assert np.allclose(np.asarray(word_count(ga)), oracle_tv.sum(0))
    assert np.allclose(np.asarray(term_vector(ga)), oracle_tv)
    grams, cnt = sequence_count(ga, l=3)
    got = {tuple(int(x) for x in grams[i]): float(cnt[i])
           for i in range(len(cnt))}
    assert got == _oracle_ngrams(files, 3)
