"""Differential suite: every analytic vs the decompress-then-scan oracle.

TADOC's validation discipline: whatever the compressed-domain engine
computes must equal a plain scan of the decompressed text.  The oracle
(tests/_oracle.py) expands the grammar via ``Grammar.expand`` /
``expand_range`` and recomputes all six ANALYTICS_KINDS with numpy; these
tests assert bit-exact agreement on randomized grammars across the engine's
execution paths:

* single-corpus (``core.analytics``, frontier + leveled traversals);
* batched segment_sum (``run_batched`` method ``frontier`` / ``leveled``);
* batched ELL (``frontier_ell`` / ``leveled_ell`` — the dense edge plan);
* device-sharded batched (``distributed.shard_batch.run_sharded``) when
  more than one device is visible — CI's multidevice lane forces 8 CPU
  host devices; tests/_shard_worker.py covers it on single-device hosts
  via a subprocess.

The search subsystem (``repro.search``) is held to the same discipline:
BM25/TF-IDF top-k rankings — document ids AND float32 scores — must be
bit-equal to a numpy recomputation from the decompressed stream, on the
single-corpus, batched, and device-sharded paths (the engine keeps its
transcendental prep on host and its device accumulation FMA-free exactly
so this bar is meetable; see repro/search/engine.py).

Runs without hypothesis via tests/_hypothesis_compat (fixed seeded
examples); the ``slow``-marked test rescales the same check to larger
grammars (CI's scheduled lane; ``DIFF_SCALE`` env var controls size).
"""

import os

import numpy as np
import pytest

import jax

from repro.core import (ANALYTICS_KINDS, Grammar, GrammarBatch,
                        compress_files, expand_range, flatten,
                        inverted_index, ranked_inverted_index, run_batched,
                        sequence_count, sort_words, term_vector, word_count)
from repro.distributed.shard_batch import corpus_mesh, run_sharded
from repro.query import query_corpus, run_batched_query
from repro.search import batched_search, search_corpus
from _hypothesis_compat import given, settings, st
from _oracle import (assert_result_equal, full_stream, oracle, oracle_batch,
                     oracle_query, oracle_search, stream_segments)
from conftest import make_repetitive_files

BATCHED_METHODS = ("frontier", "leveled", "frontier_ell", "leveled_ell",
                   "frontier_fused")
SEARCH_SCHEMES = ("bm25", "tfidf")


def _query_terms(rng, gas):
    """Random multi-term query: mostly in-vocab, some duplicated, one
    guaranteed out-of-vocab id (must contribute exactly nothing)."""
    vmax = max(ga.vocab_size for ga in gas)
    nt = int(rng.integers(1, 7))
    terms = [int(t) for t in rng.integers(0, vmax, nt)]
    terms.append(terms[0])                   # duplicate term
    terms.append(vmax + 17)                  # out-of-vocab
    return tuple(terms)


def _random_predicate(rng, gas, depth: int = 0):
    """Random AND/OR tree over term predicates: mostly in-vocab leaves,
    one guaranteed out-of-vocab leaf at the root (count 0 everywhere —
    must behave exactly like the oracle's zero column)."""
    vmax = max(ga.vocab_size for ga in gas)

    def node(d):
        if d >= 2 or rng.random() < 0.4:
            return ("term", int(rng.integers(0, vmax)),
                    int(rng.integers(0, 4)))
        op = "and" if rng.random() < 0.5 else "or"
        return (op, tuple(node(d + 1)
                          for _ in range(int(rng.integers(1, 4)))))

    return ("or", (node(0), ("term", vmax + 23, 1)))


def _random_phrase(rng, gas, streams):
    """Half the time a window actually present in some corpus (nonzero
    counts), half the time a random token tuple (usually count 0)."""
    l = int(rng.integers(2, 5))
    if rng.random() < 0.5:
        ga = gas[0]
        segs = [s for s in stream_segments(ga, streams[0]) if len(s) >= l]
        if segs:
            seg = segs[int(rng.integers(0, len(segs)))]
            start = int(rng.integers(0, len(seg) - l + 1))
            return tuple(int(x) for x in seg[start: start + l])
    vmax = max(ga.vocab_size for ga in gas)
    return tuple(int(t) for t in rng.integers(0, vmax + 3, l))


def _query_cases(rng, gas, streams):
    """One randomized case per query-operator family (agg gets both ops);
    the kwargs feed the engine dispatchers and ``oracle_query`` alike."""
    return [
        ("filter_count", dict(predicate=_random_predicate(rng, gas))),
        ("agg_terms", dict(terms=_query_terms(rng, gas), agg="sum")),
        ("agg_terms", dict(terms=_query_terms(rng, gas), agg="max")),
        ("phrase_count", dict(terms=_random_phrase(rng, gas, streams))),
    ]


def _random_grammar(rng, scale: int = 1):
    vocab = int(rng.integers(8, 30 * scale + 10))
    n_files = int(rng.integers(1, 3 + scale))
    files = make_repetitive_files(rng, vocab, n_files=n_files)
    g, nf = compress_files(files, vocab)
    return flatten(g, vocab, nf), g, files


def _single(ga, kind, l=3, method="frontier"):
    if kind == "word_count":
        return np.asarray(word_count(ga, method=method))
    if kind == "sort":
        o, c = sort_words(ga, method=method)
        return (np.asarray(o), np.asarray(c))
    if kind == "term_vector":
        return np.asarray(term_vector(ga, method=method))
    if kind == "inverted_index":
        return np.asarray(inverted_index(ga, method=method))
    if kind == "ranked_inverted_index":
        r, c = ranked_inverted_index(ga, method=method)
        return (np.asarray(r), np.asarray(c))
    if kind == "sequence_count":
        return sequence_count(ga, l=l, method=method)
    raise ValueError(kind)


def test_expansion_matches_original_corpus(seeded_rng):
    """The oracle's input is itself differential: the decompressed stream
    must reproduce the raw files (words + per-file splitters) and the two
    expansion APIs must agree."""
    ga, g, files = _random_grammar(seeded_rng)
    parts = []
    for i, f in enumerate(files):
        parts.append(np.asarray(f, np.int64))
        parts.append(np.array([ga.vocab_size + i], np.int64))
    raw = np.concatenate(parts)
    np.testing.assert_array_equal(g.expand(0), raw)
    np.testing.assert_array_equal(full_stream(ga), raw)
    # windowed random access agrees with the full expansion
    lo = len(raw) // 3
    np.testing.assert_array_equal(expand_range(ga, lo, len(raw) // 2),
                                  raw[lo: lo + len(raw) // 2])


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 100_000))
def test_single_corpus_paths_match_oracle(seed):
    rng = np.random.default_rng(seed)
    ga, _, _ = _random_grammar(rng)
    stream = full_stream(ga)
    for kind in ANALYTICS_KINDS:
        want = oracle(ga, kind, stream=stream)
        for method in ("frontier", "leveled"):
            assert_result_equal(_single(ga, kind, method=method), want,
                                kind, f"(single, {method}, seed={seed})")


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 100_000))
def test_batched_paths_match_oracle(seed):
    """All six analytics, four batched execution paths (segment_sum COO and
    dense ELL, frontier and leveled), ragged 3-corpus packs."""
    rng = np.random.default_rng(seed)
    gas = [_random_grammar(rng)[0] for _ in range(3)]
    gb = GrammarBatch.build(gas)
    streams = [full_stream(ga) for ga in gas]
    for kind in ANALYTICS_KINDS:
        wants = [oracle(ga, kind, stream=s) for ga, s in zip(gas, streams)]
        for method in BATCHED_METHODS:
            got = run_batched(gb, kind, method=method, l=3)
            for i, (g_i, w_i) in enumerate(zip(got, wants)):
                assert_result_equal(
                    g_i, w_i, kind,
                    f"(batched {method}, corpus {i}, seed={seed})")


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs a multi-device mesh (CI multidevice lane "
                           "forces 8 CPU host devices)")
@settings(max_examples=3, deadline=None)
@given(st.integers(0, 100_000))
def test_sharded_paths_match_oracle(seed):
    """All six analytics through the device-sharded pack — ragged N=5 so
    shard padding (N < devices or N % devices != 0) is always exercised —
    bit-equal to the decompress-then-scan oracle."""
    rng = np.random.default_rng(seed)
    gas = [_random_grammar(rng)[0] for _ in range(5)]
    mesh = corpus_mesh()
    for kind in ANALYTICS_KINDS:
        wants = oracle_batch(gas, kind)
        for method in ("frontier", "leveled_ell", "frontier_fused"):
            got = run_sharded(gas, kind, mesh=mesh, method=method, l=3)
            for i, (g_i, w_i) in enumerate(zip(got, wants)):
                assert_result_equal(
                    g_i, w_i, kind,
                    f"(sharded {method}, corpus {i}, seed={seed})")


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 100_000))
def test_search_rankings_match_oracle(seed):
    """BM25/TF-IDF top-k rankings — doc ids AND float32 scores — bit-equal
    to the numpy decompress-then-scan oracle on the single-corpus and
    batched paths, for random multi-term queries with duplicates and
    out-of-vocab terms, across traversal methods."""
    rng = np.random.default_rng(seed)
    gas = [_random_grammar(rng)[0] for _ in range(3)]
    gb = GrammarBatch.build(gas)
    terms = _query_terms(rng, gas)
    k = int(rng.integers(1, 9))
    for scheme in SEARCH_SCHEMES:
        wants = [oracle_search(ga, terms, k=k, scheme=scheme) for ga in gas]
        for ga, want in zip(gas, wants):
            assert_result_equal(
                search_corpus(ga, terms, k=k, scheme=scheme), want,
                f"search_{scheme}", f"(single, seed={seed})")
        for method in ("frontier", "leveled", "frontier_ell"):
            got = batched_search(gb, terms, k=k, scheme=scheme,
                                 method=method)
            for i, (g_i, w_i) in enumerate(zip(got, wants)):
                assert_result_equal(
                    g_i, w_i, f"search_{scheme}",
                    f"(batched {method}, corpus {i}, seed={seed}, "
                    f"terms={terms}, k={k})")


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs a multi-device mesh (CI multidevice lane "
                           "forces 8 CPU host devices)")
@settings(max_examples=3, deadline=None)
@given(st.integers(0, 100_000))
def test_sharded_search_rankings_match_oracle(seed):
    """Search through the device-sharded pack (per-shard scoring + top-k,
    host merge) — ragged N=5 exercises shard padding — bit-equal to the
    oracle and to the single-device batched path."""
    rng = np.random.default_rng(seed)
    gas = [_random_grammar(rng)[0] for _ in range(5)]
    gb1 = GrammarBatch.build(gas)
    mesh = corpus_mesh()
    terms = _query_terms(rng, gas)
    k = int(rng.integers(1, 6))
    for kind, scheme in (("search_bm25", "bm25"), ("search_tfidf", "tfidf")):
        wants = [oracle_search(ga, terms, k=k, scheme=scheme) for ga in gas]
        got = run_sharded(gas, kind, mesh=mesh, terms=terms, k=k)
        single = batched_search(gb1, terms, k=k, scheme=scheme)
        for i, (g_i, w_i, s_i) in enumerate(zip(got, wants, single)):
            assert_result_equal(g_i, w_i, kind,
                                f"(sharded, corpus {i}, seed={seed})")
            assert_result_equal(g_i, s_i, kind,
                                f"(sharded vs single-device, corpus {i})")


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 100_000))
def test_query_operators_match_oracle(seed):
    """The composable query tier (filter / aggregate / phrase) bit-equal
    to the decompress-then-scan oracle — file-id sets, per-file and total
    float32 aggregates, float32 phrase counts — on the single-corpus and
    batched paths, across traversal methods.  The phrase path runs the
    sequence-support plans (core/sequence.py), never decompression."""
    rng = np.random.default_rng(seed)
    gas = [_random_grammar(rng)[0] for _ in range(3)]
    streams = [full_stream(ga) for ga in gas]
    gb = GrammarBatch.build(gas)
    for kind, kw in _query_cases(rng, gas, streams):
        wants = [oracle_query(ga, kind, stream=s, **kw)
                 for ga, s in zip(gas, streams)]
        for ga, want in zip(gas, wants):
            assert_result_equal(query_corpus(ga, kind, **kw), want, kind,
                                f"(single, seed={seed}, {kw})")
        for method in ("frontier", "leveled", "frontier_ell"):
            got = run_batched_query(gb, kind, method=method, **kw)
            for i, (g_i, w_i) in enumerate(zip(got, wants)):
                assert_result_equal(
                    g_i, w_i, kind,
                    f"(batched {method}, corpus {i}, seed={seed}, {kw})")


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs a multi-device mesh (CI multidevice lane "
                           "forces 8 CPU host devices)")
@settings(max_examples=3, deadline=None)
@given(st.integers(0, 100_000))
def test_sharded_query_operators_match_oracle(seed):
    """Query operators through the device-sharded pack — ragged N=5 so
    shard padding is always exercised — bit-equal to the oracle and to
    the single-device batched path."""
    rng = np.random.default_rng(seed)
    gas = [_random_grammar(rng)[0] for _ in range(5)]
    streams = [full_stream(ga) for ga in gas]
    gb1 = GrammarBatch.build(gas)
    mesh = corpus_mesh()
    for kind, kw in _query_cases(rng, gas, streams):
        wants = [oracle_query(ga, kind, stream=s, **kw)
                 for ga, s in zip(gas, streams)]
        got = run_sharded(gas, kind, mesh=mesh, **kw)
        single = run_batched_query(gb1, kind, **kw)
        for i, (g_i, w_i, s_i) in enumerate(zip(got, wants, single)):
            assert_result_equal(g_i, w_i, kind,
                                f"(sharded, corpus {i}, seed={seed}, {kw})")
            assert_result_equal(g_i, s_i, kind,
                                f"(sharded vs single-device, corpus {i})")


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 100_000))
def test_appended_corpus_matches_rebuilt_and_oracle(seed):
    """Streaming-ingest differential lane: a corpus grown by
    ``append_files`` vs a from-scratch build of the concatenated file
    list.  The grammars are bit-identical (tests/test_ingest.py), and
    here the *engine outputs* are held to the same bar: all six analytics
    and both search rankings (float32 scores included) bit-equal to the
    rebuilt corpus AND to the decompress-then-scan oracle, on the
    single-corpus and batched paths.  Packing appended + rebuilt into one
    GrammarBatch also proves the appended arrays are first-class pack
    citizens (identical padded rows, identical plans)."""
    from repro.data import CompressedCorpus

    rng = np.random.default_rng(seed)
    vocab = int(rng.integers(8, 40))
    base = make_repetitive_files(rng, vocab,
                                 n_files=int(rng.integers(1, 4)))
    tail = make_repetitive_files(rng, vocab,
                                 n_files=int(rng.integers(1, 4)))
    appended = CompressedCorpus.build(base, vocab).append_files(tail)
    rebuilt = CompressedCorpus.build(base + tail, vocab)
    ga_a, ga_r = appended.ga, rebuilt.ga
    stream = full_stream(ga_a)
    gb = GrammarBatch.build([ga_a, ga_r])
    for kind in ANALYTICS_KINDS:
        want = oracle(ga_r, kind, stream=stream)
        assert_result_equal(_single(ga_a, kind), want, kind,
                            f"(appended single, seed={seed})")
        for method in ("frontier", "frontier_ell"):
            got = run_batched(gb, kind, method=method, l=3)
            assert_result_equal(got[0], want, kind,
                                f"(appended batched {method}, seed={seed})")
            assert_result_equal(got[1], want, kind,
                                f"(rebuilt batched {method}, seed={seed})")
    terms = _query_terms(rng, [ga_a])
    k = int(rng.integers(1, 7))
    for scheme in SEARCH_SCHEMES:
        want = oracle_search(ga_r, terms, k=k, scheme=scheme,
                             stream=stream)
        assert_result_equal(
            search_corpus(appended, terms, k=k, scheme=scheme), want,
            f"search_{scheme}", f"(appended single, seed={seed})")
        got = batched_search(gb, terms, k=k, scheme=scheme)
        for i, g_i in enumerate(got):
            assert_result_equal(g_i, want, f"search_{scheme}",
                                f"(appended batched, row {i}, seed={seed})")


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs a multi-device mesh (CI multidevice lane "
                           "forces 8 CPU host devices)")
@settings(max_examples=2, deadline=None)
@given(st.integers(0, 100_000))
def test_appended_corpus_sharded_matches_oracle(seed):
    """The appended corpus through the device-sharded path: a ragged pack
    of appended corpora (N=3 over an 8-way mesh exercises padding) stays
    bit-equal to the oracle on analytics and search."""
    from repro.data import CompressedCorpus

    rng = np.random.default_rng(seed)
    corpora = []
    for _ in range(3):
        vocab = int(rng.integers(8, 30))
        base = make_repetitive_files(rng, vocab, n_files=2)
        tail = make_repetitive_files(rng, vocab,
                                     n_files=int(rng.integers(1, 3)))
        corpora.append(
            CompressedCorpus.build(base, vocab).append_files(tail))
    gas = [c.ga for c in corpora]
    mesh = corpus_mesh()
    for kind in ("word_count", "term_vector"):
        wants = oracle_batch(gas, kind)
        got = run_sharded(gas, kind, mesh=mesh)
        for i, (g_i, w_i) in enumerate(zip(got, wants)):
            assert_result_equal(g_i, w_i, kind,
                                f"(appended sharded, corpus {i})")
    terms = _query_terms(rng, gas)
    for kind, scheme in (("search_bm25", "bm25"), ("search_tfidf", "tfidf")):
        wants = [oracle_search(ga, terms, k=4, scheme=scheme) for ga in gas]
        got = run_sharded(gas, kind, mesh=mesh, terms=terms, k=4)
        for i, (g_i, w_i) in enumerate(zip(got, wants)):
            assert_result_equal(g_i, w_i, kind,
                                f"(appended sharded, corpus {i})")


@settings(max_examples=4, deadline=None)
@given(st.integers(2, 5), st.integers(0, 100_000))
def test_sequence_count_window_lengths_match_oracle(l, seed):
    rng = np.random.default_rng(seed)
    gas = [_random_grammar(rng)[0] for _ in range(2)]
    wants = [oracle(ga, "sequence_count", l=l) for ga in gas]
    for ga, want in zip(gas, wants):
        assert_result_equal(sequence_count(ga, l=l, method="frontier"),
                            want, "sequence_count", f"(single, l={l})")
    gb = GrammarBatch.build(gas)
    for method in ("frontier", "frontier_ell"):
        got = run_batched(gb, "sequence_count", method=method, l=l)
        for g_i, w_i in zip(got, wants):
            assert_result_equal(g_i, w_i, "sequence_count",
                                f"(batched {method}, l={l}, seed={seed})")


@pytest.mark.slow
def test_differential_slow_larger_grammars(seeded_rng):
    """Same oracle check at larger grammar sizes (scheduled CI lane);
    ``DIFF_SCALE`` scales corpus size, default 3."""
    from repro.data.synthetic import CorpusSpec, make_corpus

    scale = int(os.environ.get("DIFF_SCALE", "3"))
    gas = []
    for i in range(3):
        spec = CorpusSpec(f"diff{i}", n_files=2 + scale,
                          tokens_per_file=400 * scale, vocab=120 * scale,
                          phrase_rate=0.55, n_phrases=30, phrase_len=7,
                          seed=int(seeded_rng.integers(1 << 31)))
        files = make_corpus(spec)
        g, nf = compress_files(files, spec.vocab)
        gas.append(flatten(g, spec.vocab, nf))
    gb = GrammarBatch.build(gas)
    streams = [full_stream(ga) for ga in gas]
    for kind in ANALYTICS_KINDS:
        wants = [oracle(ga, kind, stream=s) for ga, s in zip(gas, streams)]
        for ga, want in zip(gas, wants):
            assert_result_equal(_single(ga, kind), want, kind,
                                "(single, slow)")
        for method in ("frontier", "frontier_ell", "leveled_ell"):
            got = run_batched(gb, kind, method=method, l=3)
            for g_i, w_i in zip(got, wants):
                assert_result_equal(g_i, w_i, kind,
                                    f"(batched {method}, slow)")
    terms = _query_terms(seeded_rng, gas)
    for scheme in SEARCH_SCHEMES:
        wants = [oracle_search(ga, terms, k=10, scheme=scheme, stream=s)
                 for ga, s in zip(gas, streams)]
        got = batched_search(gb, terms, k=10, scheme=scheme)
        for ga, w_i, g_i in zip(gas, wants, got):
            assert_result_equal(g_i, w_i, f"search_{scheme}",
                                "(batched, slow)")
            assert_result_equal(
                search_corpus(ga, terms, k=10, scheme=scheme), w_i,
                f"search_{scheme}", "(single, slow)")
    for kind, kw in _query_cases(seeded_rng, gas, streams):
        wants = [oracle_query(ga, kind, stream=s, **kw)
                 for ga, s in zip(gas, streams)]
        got = run_batched_query(gb, kind, **kw)
        for ga, w_i, g_i in zip(gas, wants, got):
            assert_result_equal(g_i, w_i, kind, f"(batched, slow, {kw})")
            assert_result_equal(query_corpus(ga, kind, **kw), w_i, kind,
                                f"(single, slow, {kw})")
