"""Differential suite: every analytic vs the decompress-then-scan oracle.

TADOC's validation discipline: whatever the compressed-domain engine
computes must equal a plain scan of the decompressed text.  The oracle
(tests/_oracle.py) expands the grammar via ``Grammar.expand`` /
``expand_range`` and recomputes all six ANALYTICS_KINDS with numpy; these
tests assert bit-exact agreement on randomized grammars across the engine's
execution paths:

* single-corpus (``core.analytics``, frontier + leveled traversals);
* batched segment_sum (``run_batched`` method ``frontier`` / ``leveled``);
* batched ELL (``frontier_ell`` / ``leveled_ell`` — the dense edge plan);
* device-sharded batched (``distributed.shard_batch.run_sharded``) when
  more than one device is visible — CI's multidevice lane forces 8 CPU
  host devices; tests/_shard_worker.py covers it on single-device hosts
  via a subprocess.

Runs without hypothesis via tests/_hypothesis_compat (fixed seeded
examples); the ``slow``-marked test rescales the same check to larger
grammars (CI's scheduled lane; ``DIFF_SCALE`` env var controls size).
"""

import os

import numpy as np
import pytest

import jax

from repro.core import (ANALYTICS_KINDS, Grammar, GrammarBatch,
                        compress_files, expand_range, flatten,
                        inverted_index, ranked_inverted_index, run_batched,
                        sequence_count, sort_words, term_vector, word_count)
from repro.distributed.shard_batch import corpus_mesh, run_sharded
from _hypothesis_compat import given, settings, st
from _oracle import assert_result_equal, full_stream, oracle, oracle_batch
from conftest import make_repetitive_files

BATCHED_METHODS = ("frontier", "leveled", "frontier_ell", "leveled_ell")


def _random_grammar(rng, scale: int = 1):
    vocab = int(rng.integers(8, 30 * scale + 10))
    n_files = int(rng.integers(1, 3 + scale))
    files = make_repetitive_files(rng, vocab, n_files=n_files)
    g, nf = compress_files(files, vocab)
    return flatten(g, vocab, nf), g, files


def _single(ga, kind, l=3, method="frontier"):
    if kind == "word_count":
        return np.asarray(word_count(ga, method=method))
    if kind == "sort":
        o, c = sort_words(ga, method=method)
        return (np.asarray(o), np.asarray(c))
    if kind == "term_vector":
        return np.asarray(term_vector(ga, method=method))
    if kind == "inverted_index":
        return np.asarray(inverted_index(ga, method=method))
    if kind == "ranked_inverted_index":
        r, c = ranked_inverted_index(ga, method=method)
        return (np.asarray(r), np.asarray(c))
    if kind == "sequence_count":
        return sequence_count(ga, l=l, method=method)
    raise ValueError(kind)


def test_expansion_matches_original_corpus(seeded_rng):
    """The oracle's input is itself differential: the decompressed stream
    must reproduce the raw files (words + per-file splitters) and the two
    expansion APIs must agree."""
    ga, g, files = _random_grammar(seeded_rng)
    parts = []
    for i, f in enumerate(files):
        parts.append(np.asarray(f, np.int64))
        parts.append(np.array([ga.vocab_size + i], np.int64))
    raw = np.concatenate(parts)
    np.testing.assert_array_equal(g.expand(0), raw)
    np.testing.assert_array_equal(full_stream(ga), raw)
    # windowed random access agrees with the full expansion
    lo = len(raw) // 3
    np.testing.assert_array_equal(expand_range(ga, lo, len(raw) // 2),
                                  raw[lo: lo + len(raw) // 2])


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 100_000))
def test_single_corpus_paths_match_oracle(seed):
    rng = np.random.default_rng(seed)
    ga, _, _ = _random_grammar(rng)
    stream = full_stream(ga)
    for kind in ANALYTICS_KINDS:
        want = oracle(ga, kind, stream=stream)
        for method in ("frontier", "leveled"):
            assert_result_equal(_single(ga, kind, method=method), want,
                                kind, f"(single, {method}, seed={seed})")


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 100_000))
def test_batched_paths_match_oracle(seed):
    """All six analytics, four batched execution paths (segment_sum COO and
    dense ELL, frontier and leveled), ragged 3-corpus packs."""
    rng = np.random.default_rng(seed)
    gas = [_random_grammar(rng)[0] for _ in range(3)]
    gb = GrammarBatch.build(gas)
    streams = [full_stream(ga) for ga in gas]
    for kind in ANALYTICS_KINDS:
        wants = [oracle(ga, kind, stream=s) for ga, s in zip(gas, streams)]
        for method in BATCHED_METHODS:
            got = run_batched(gb, kind, method=method, l=3)
            for i, (g_i, w_i) in enumerate(zip(got, wants)):
                assert_result_equal(
                    g_i, w_i, kind,
                    f"(batched {method}, corpus {i}, seed={seed})")


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs a multi-device mesh (CI multidevice lane "
                           "forces 8 CPU host devices)")
@settings(max_examples=3, deadline=None)
@given(st.integers(0, 100_000))
def test_sharded_paths_match_oracle(seed):
    """All six analytics through the device-sharded pack — ragged N=5 so
    shard padding (N < devices or N % devices != 0) is always exercised —
    bit-equal to the decompress-then-scan oracle."""
    rng = np.random.default_rng(seed)
    gas = [_random_grammar(rng)[0] for _ in range(5)]
    mesh = corpus_mesh()
    for kind in ANALYTICS_KINDS:
        wants = oracle_batch(gas, kind)
        for method in ("frontier", "leveled_ell"):
            got = run_sharded(gas, kind, mesh=mesh, method=method, l=3)
            for i, (g_i, w_i) in enumerate(zip(got, wants)):
                assert_result_equal(
                    g_i, w_i, kind,
                    f"(sharded {method}, corpus {i}, seed={seed})")


@settings(max_examples=4, deadline=None)
@given(st.integers(2, 5), st.integers(0, 100_000))
def test_sequence_count_window_lengths_match_oracle(l, seed):
    rng = np.random.default_rng(seed)
    gas = [_random_grammar(rng)[0] for _ in range(2)]
    wants = [oracle(ga, "sequence_count", l=l) for ga in gas]
    for ga, want in zip(gas, wants):
        assert_result_equal(sequence_count(ga, l=l, method="frontier"),
                            want, "sequence_count", f"(single, l={l})")
    gb = GrammarBatch.build(gas)
    for method in ("frontier", "frontier_ell"):
        got = run_batched(gb, "sequence_count", method=method, l=l)
        for g_i, w_i in zip(got, wants):
            assert_result_equal(g_i, w_i, "sequence_count",
                                f"(batched {method}, l={l}, seed={seed})")


@pytest.mark.slow
def test_differential_slow_larger_grammars(seeded_rng):
    """Same oracle check at larger grammar sizes (scheduled CI lane);
    ``DIFF_SCALE`` scales corpus size, default 3."""
    from repro.data.synthetic import CorpusSpec, make_corpus

    scale = int(os.environ.get("DIFF_SCALE", "3"))
    gas = []
    for i in range(3):
        spec = CorpusSpec(f"diff{i}", n_files=2 + scale,
                          tokens_per_file=400 * scale, vocab=120 * scale,
                          phrase_rate=0.55, n_phrases=30, phrase_len=7,
                          seed=int(seeded_rng.integers(1 << 31)))
        files = make_corpus(spec)
        g, nf = compress_files(files, spec.vocab)
        gas.append(flatten(g, spec.vocab, nf))
    gb = GrammarBatch.build(gas)
    streams = [full_stream(ga) for ga in gas]
    for kind in ANALYTICS_KINDS:
        wants = [oracle(ga, kind, stream=s) for ga, s in zip(gas, streams)]
        for ga, want in zip(gas, wants):
            assert_result_equal(_single(ga, kind), want, kind,
                                "(single, slow)")
        for method in ("frontier", "frontier_ell", "leveled_ell"):
            got = run_batched(gb, kind, method=method, l=3)
            for g_i, w_i in zip(got, wants):
                assert_result_equal(g_i, w_i, kind,
                                    f"(batched {method}, slow)")
