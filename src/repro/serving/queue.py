"""Async deadline-aware request queue over the batched analytics engine.

The synchronous :class:`AnalyticsServer` batches whatever one caller hands
it in a single ``run``.  Under serving load, queries arrive one at a time
from many callers — batching opportunities exist *across* submissions, not
within them.  :class:`AsyncAnalyticsServer` exposes ``submit(query,
deadline=...) -> Future`` and holds queries in a pending queue, grouped by
:meth:`Query.group_key` (kind + normalized l) and by grammar-size bucket
(power-of-two rule count, so a flush packs corpora of similar size onto one
compiled program).  A group is flushed — one call into the shared engine
core (:meth:`AnalyticsServer.run_group`) — when any of:

``max_batch``  the group reaches one flush's worth of distinct corpora —
               ``max_batch`` on one device, ``max_batch * target_shards``
               when a corpus mesh is available (a full pack, nothing to
               wait for; checked on every submit);
``deadline``   the earliest deadline in the group is within one estimated
               batch latency (the per-signature EWMA tracked by
               ``ServerStats.observe_latency``) of *now* — waiting longer
               would miss it;
``idle``       no new query joined the group for ``idle_timeout`` seconds —
               traffic has moved on, stop holding the stragglers;
``max_wait``   the OLDEST query in the group has waited ``max_wait``
               seconds — a sustained same-corpus stream resets idleness on
               every arrival and never fills a pack, so waiting is bounded
               by submission age too;
``drain``      an explicit :meth:`drain` / :meth:`close`.

Search queries (kinds ``search_bm25`` / ``search_tfidf``) and the query
operators (``filter_count`` / ``agg_terms`` / ``phrase_count``) ride the
same machinery: their normalized parameters (query terms, top-k, the
filter predicate, the aggregation op) are part of
:meth:`Query.group_key`, so two distinct queries can never share a
batched chunk, while identical queries against many corpora batch (and
shard) exactly like the six analytics.

Backpressure: ``max_pending`` bounds the queue depth.  A submit that
would exceed it raises :class:`QueueFull` (and counts
``stats.rejected``), or — with ``block=True`` — waits until a flush frees
space (requires something else to drive flushes: the background thread,
or another thread calling :meth:`poll`/:meth:`drain`).  The depth
high-water mark is ``ServerStats.max_queue_depth``.

Deadline shedding: a query whose deadline has *already passed* when its
group flushes can no longer produce a useful answer — executing it would
burn an engine slot for a result nobody can use.  Such queries are shed
at flush time: their futures get a :class:`DeadlineExceeded` exception
instead of a result, ``stats.shed`` counts them, and each
:class:`FlushEvent` records its group's shed count (``n_shed``).
Shedding composes with ``max_pending`` backpressure into the overload
contract the load harness (benchmarks/bench_load.py) tests end to end:
under sustained overload the server sheds and rejects but never crashes,
and every non-shed result stays bit-identical to the sync path.

Because flushes call the same ``run_group`` / ``execute_chunk`` core as the
sync path, results are bit-identical to a one-shot ``AnalyticsServer.run``
of the same queries (tests/test_queue.py fuzzes exactly that).

Ingest freshness: a query can sit in the pending queue while its
registered :class:`~repro.data.store.CompressedCorpus` absorbs appended
files (``append_files`` bumps the store epoch).  Flushes stay fresh
because ``execute_chunk`` re-snapshots every mutated corpus at flush time
(``AnalyticsServer.refresh``, the re-registration path) before packing —
so a submit-append-drain sequence serves post-append data, never the
grammar that was current at submit time (tests/test_ingest.py).

Device-sharded flushes: ``target_shards`` > 1 asks the engine to split
large flushes row-wise across the corpus mesh instead of serializing
``max_batch``-sized chunks — one flush of up to ``max_batch *
target_shards`` corpora executes as one program spanning that many devices
(``AnalyticsServer.chunk_capacity`` / ``GrammarBatch.shard``).  The knob
is a *target*: with fewer devices (or one), capacity degrades gracefully
to the plain per-device flush, and results stay bit-identical throughout.

Time is injectable (``clock=``): the flush-policy tests drive a simulated
clock through :meth:`poll`, deterministically.  For real deployments,
:meth:`start` runs a small daemon thread that polls at ``poll_interval``;
``submit`` is thread-safe and flushes triggered by a full group execute on
the submitting thread.
"""

from __future__ import annotations

import threading
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.obs import BoundedLog
from repro.obs import tracing as _trace

from .analytics_server import (DEFAULT_LATENCY_ESTIMATE, AnalyticsServer,
                               Query)


class QueueFull(RuntimeError):
    """submit() would push the pending-query depth past ``max_pending``."""


class DeadlineExceeded(RuntimeError):
    """The query's deadline had already passed when its group flushed, so
    it was shed instead of executed (``stats.shed``); the future carries
    this exception instead of a result."""


@dataclass
class _Pending:
    query: Query
    deadline: Optional[float]       # absolute, in the server's clock domain
    future: Future
    submitted_at: float
    # root Span opened at submit time (registry enabled only).  Carried
    # here rather than read back off query.trace: the same Query object
    # may be submitted repeatedly, and each submission is its own tree.
    span: Optional[_trace.Span] = None


@dataclass
class _Group:
    kind: str
    l: Optional[int]                # normalized (None unless sequence_count)
    terms: Optional[Tuple[int, ...]] = None  # normalized (search/agg/phrase)
    k: Optional[int] = None                  # normalized (search kinds only)
    predicate: Optional[Tuple] = None        # normalized (filter_count only)
    agg: Optional[str] = None                # normalized (agg_terms only)
    items: List[_Pending] = field(default_factory=list)
    last_arrival: float = 0.0
    # distinct corpora in arrival order (dict-as-ordered-set: submit must
    # stay O(1), not rescan items, while holding the queue lock)
    corpora_seen: Dict[str, None] = field(default_factory=dict)

    def add(self, p: _Pending) -> None:
        self.items.append(p)
        self.last_arrival = p.submitted_at
        self.corpora_seen.setdefault(p.query.corpus)

    def corpora(self) -> List[str]:
        return list(self.corpora_seen)

    def earliest_deadline(self) -> Optional[float]:
        ds = [p.deadline for p in self.items if p.deadline is not None]
        return min(ds) if ds else None


@dataclass(frozen=True)
class FlushEvent:
    """One flush, as observed by tests/benchmarks (``flush_log``).

    ``reason`` is the transition that fired the flush — exactly one of
    ``max_batch`` / ``deadline`` / ``idle`` / ``max_wait`` / ``drain``.
    ``n_shed`` is orthogonal to the reason: however the flush fired, the
    group members whose deadline had already passed were shed
    (:class:`DeadlineExceeded`) instead of executed, and ``n_queries``
    counts only the queries actually answered by the engine call.
    """
    reason: str         # max_batch | deadline | idle | max_wait | drain
    kind: str
    l: Optional[int]
    n_queries: int
    n_corpora: int
    at: float                       # clock time the flush fired
    n_shed: int = 0                 # group members shed (expired deadline)
    terms: Optional[Tuple[int, ...]] = None  # search/agg_terms/phrase_count
    k: Optional[int] = None                  # search kinds only
    predicate: Optional[Tuple] = None        # filter_count only
    agg: Optional[str] = None                # agg_terms only
    # the flush's Span (chunk/pack_build/execute children below it),
    # present when the engine registry is enabled; compare=False so event
    # equality stays about the flush facts
    span: Optional[_trace.Span] = field(default=None, compare=False,
                                        repr=False)


class AsyncAnalyticsServer:
    """Deadline-aware submission queue wrapping an :class:`AnalyticsServer`.

    Parameters
    ----------
    server:        the engine; its ``max_batch``/``method``/pack cache and
                   its ``stats`` (flush counters, latency EWMA) are shared.
    idle_timeout:  seconds a group may sit without new arrivals before it is
                   flushed anyway (condition ``idle``).
    max_wait:      hard bound on how long any single query may sit queued
                   (condition ``max_wait``); defaults to ``10 *
                   idle_timeout``.
    default_latency: batch-latency estimate used for a kind that has never
                   executed (seeds the ``deadline`` condition before the
                   EWMA has observations).
    clock:         monotonic-time source; defaults to the engine's
                   injectable ``server.clock`` so the whole serving stack
                   shares one time domain, and is separately injectable
                   for simulated-clock tests.  Deadlines passed to
                   :meth:`submit` are absolute values in this clock's
                   domain.
    poll_interval: sleep granularity of the background thread
                   (:meth:`start`); also the staleness bound on the
                   ``deadline``/``idle`` conditions when threaded.
    target_shards: how many devices one flush should aim to span.  Raises
                   the ``max_batch`` fill condition to the engine's
                   ``chunk_capacity(target_shards)`` and forwards the
                   target to ``run_group`` so a large flush executes as
                   one device-sharded program instead of sequential
                   ``max_batch`` chunks.  Clamped by the devices actually
                   in the engine's mesh; 1 (default) preserves the
                   original single-device flush policy exactly.
    max_pending:   queue-depth bound (backpressure).  ``None`` (default):
                   unbounded, the original behaviour.  With a bound, a
                   submit that would exceed it raises :class:`QueueFull`
                   unless ``block=True``, which instead waits for a flush
                   to free space.  ``ServerStats.max_queue_depth`` records
                   the observed high-water mark, ``stats.rejected`` the
                   refused submissions.
    """

    def __init__(self, server: AnalyticsServer, *,
                 idle_timeout: float = 0.005,
                 max_wait: Optional[float] = None,
                 default_latency: float = DEFAULT_LATENCY_ESTIMATE,
                 clock: Optional[Callable[[], float]] = None,
                 poll_interval: float = 0.001,
                 target_shards: int = 1,
                 max_pending: Optional[int] = None):
        if idle_timeout < 0:
            raise ValueError("idle_timeout must be >= 0")
        if poll_interval <= 0:
            raise ValueError("poll_interval must be > 0")
        if target_shards < 1:
            raise ValueError("target_shards must be >= 1")
        if max_pending is not None and max_pending < 1:
            raise ValueError("max_pending must be >= 1 (or None)")
        self._engine = server
        self.target_shards = target_shards
        self.max_pending = max_pending
        self.idle_timeout = float(idle_timeout)
        self.max_wait = (10.0 * self.idle_timeout if max_wait is None
                         else float(max_wait))
        if self.max_wait < self.idle_timeout:
            raise ValueError("max_wait must be >= idle_timeout")
        self.default_latency = float(default_latency)
        self.poll_interval = float(poll_interval)
        # one clock domain for the whole stack by default: the engine's
        # injectable clock (satellite of the same PR that added it there).
        # An explicit clock= stays queue-local so simulated-clock tests
        # keep driving the flush policy alone.
        self._now = clock if clock is not None else server.clock
        self._pending: Dict[Tuple, _Group] = {}
        self._depth = 0                      # total pending queries, O(1)
        self._lock = threading.RLock()
        # signalled whenever _pop lowers the depth (or the queue closes):
        # wakes submits blocked on the max_pending bound
        self._space = threading.Condition(self._lock)
        self._exec_lock = threading.Lock()   # one engine call at a time
        # bounded observability ring (long-lived servers must not leak);
        # evictions are counted and exposed as a gauge, never silent
        self.flush_log: BoundedLog = BoundedLog(
            4096, gauge=server.registry.gauge(
                "repro_queue_flush_log_dropped_events",
                "FlushEvents evicted from the bounded flush_log ring"))
        self._depth_gauge = server.registry.gauge(
            "repro_queue_depth", "pending queries in the async queue")
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._closed = False

    # ------------------------------------------------------------- state --
    @property
    def stats(self):
        return self._engine.stats

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return self._depth

    # ------------------------------------------------------------ submit --
    def submit(self, query: Query, deadline: Optional[float] = None,
               block: bool = False) -> Future:
        """Enqueue one query; returns a future resolving to exactly what
        ``AnalyticsServer.run([query])[0]`` would.  ``deadline`` is an
        absolute time in the server's clock domain (``None``: flushed by
        ``max_batch`` or ``idle`` only).  Invalid queries raise here, not on
        the future.

        With ``max_pending`` set, a submit into a full queue raises
        :class:`QueueFull` — or, when ``block=True``, waits until a flush
        frees space (something else must drive flushes: the background
        thread, or another thread polling/draining).  A close() while
        blocked raises ``RuntimeError`` like any post-close submit."""
        self._engine.validate(query)
        to_flush: Optional[_Group] = None
        fut: Future = Future()
        with self._lock:
            while True:
                if self._closed:
                    raise RuntimeError("queue is closed")
                if (self.max_pending is None
                        or self._depth < self.max_pending):
                    break
                if not block:
                    self.stats.rejected += 1
                    raise QueueFull(
                        f"queue depth {self._depth} at max_pending="
                        f"{self.max_pending}")
                self._space.wait()
            now = self._now()
            gk = query.group_key()
            key = (gk, self._engine.size_bucket(query.corpus))
            g = self._pending.get(key)
            if g is None:
                kind, l, terms, k, predicate, agg = gk
                g = self._pending[key] = _Group(kind=kind, l=l, terms=terms,
                                                k=k, predicate=predicate,
                                                agg=agg)
            root = None
            if self._engine.registry.enabled:
                root = _trace.Span("query", now,
                                   attrs={"corpus": query.corpus,
                                          "kind": query.kind,
                                          "path": "async"})
                object.__setattr__(query, "trace", root)
            g.add(_Pending(query, deadline, fut, now, span=root))
            self.stats.submitted += 1
            self._depth += 1
            self._depth_gauge.set(float(self._depth))
            self.stats.max_queue_depth = max(self.stats.max_queue_depth,
                                             self._depth)
            if len(g.corpora_seen) >= self._engine.chunk_capacity(
                    self.target_shards):
                to_flush = self._pop(key)
        if to_flush is not None:
            self._flush_group(to_flush, "max_batch", self._now())
        self._kick()
        return fut

    # -------------------------------------------------------------- poll --
    def poll(self, now: Optional[float] = None) -> Optional[float]:
        """Fire every due flush condition; returns the next time a condition
        could trigger (for the serve loop's sleep), or ``None`` if the queue
        is empty.  Simulated-clock tests call this directly with ``now``."""
        if now is None:
            now = self._now()
        due: List[Tuple[_Group, str]] = []
        with self._lock:
            for key in list(self._pending):
                g = self._pending[key]
                reason = self._due_reason(g, now)
                if reason is not None:
                    due.append((self._pop(key), reason))
        for g, reason in due:
            self._flush_group(g, reason, now)
        with self._lock:
            wakes = [self._next_trigger(g) for g in self._pending.values()]
        return min(wakes) if wakes else None

    def _due_reason(self, g: _Group, now: float) -> Optional[str]:
        ed = g.earliest_deadline()
        if ed is not None:
            est = self.stats.estimate_latency(g.kind,
                                              default=self.default_latency)
            if ed - now <= est:
                return "deadline"
        if now - g.last_arrival >= self.idle_timeout:
            return "idle"
        # steady same-group arrivals reset idleness forever — bound the
        # oldest query's wait regardless
        if now - g.items[0].submitted_at >= self.max_wait:
            return "max_wait"
        return None

    def _next_trigger(self, g: _Group) -> float:
        t = min(g.last_arrival + self.idle_timeout,
                g.items[0].submitted_at + self.max_wait)
        ed = g.earliest_deadline()
        if ed is not None:
            est = self.stats.estimate_latency(g.kind,
                                              default=self.default_latency)
            t = min(t, ed - est)
        return t

    # ------------------------------------------------------------- drain --
    def drain(self) -> None:
        """Flush everything pending, regardless of deadlines/timeouts."""
        with self._lock:
            groups = [self._pop(key) for key in list(self._pending)]
        now = self._now()
        for g in groups:
            self._flush_group(g, "drain", now)

    def _pop(self, key: Tuple) -> _Group:
        """Remove a group from the queue (lock held by caller); wakes any
        submit blocked on the ``max_pending`` bound."""
        g = self._pending.pop(key)
        self._depth -= len(g.items)
        self._depth_gauge.set(float(self._depth))
        self._space.notify_all()
        return g

    # ------------------------------------------------------------- flush --
    def _flush_group(self, g: _Group, reason: str, now: float) -> None:
        tracing = self._engine.registry.enabled
        # claim each future (running state): callers may have cancel()ed a
        # pending one — set_result on it would raise InvalidStateError,
        # starving the rest of the group and killing the serve loop
        claimed = [p for p in g.items
                   if p.future.set_running_or_notify_cancel()]
        if tracing:
            # every claimed query waited submit -> flush, shed or not
            wait_hist = self.stats.stage_seconds.labels("queue_wait")
            for p in claimed:
                wait_hist.observe(now - p.submitted_at)
                if p.span is not None:
                    p.span.children.append(_trace.Span(
                        "queue_wait", p.submitted_at).finish(now))
        # shed the expired: a deadline already in the past cannot be met by
        # any execution, so the engine slot goes to queries that can still
        # use it.  Fail the futures before the engine call — their callers
        # unblock immediately instead of waiting out a batch they are not in.
        shed = [p for p in claimed
                if p.deadline is not None and now > p.deadline]
        for p in shed:
            p.future.set_exception(DeadlineExceeded(
                f"deadline {p.deadline:.6f} passed before flush at "
                f"{now:.6f} (queued {now - p.submitted_at:.6f}s)"))
            if p.span is not None:
                p.span.attrs["outcome"] = "shed"
                self._engine.trace_log.append(p.span.finish(now))
        live = [p for p in claimed
                if p.deadline is None or now <= p.deadline]
        names: List[str] = []
        for p in live:
            if p.query.corpus not in names:
                names.append(p.query.corpus)
        # ONE flush span shared by every query the flush answers — the
        # chunk/pack_build/execute children hang off it via the ambient
        # context inside run_group
        fspan = _trace.Span("flush", now,
                            attrs={"reason": reason, "kind": g.kind,
                                   "n_queries": len(live),
                                   "n_corpora": len(names),
                                   "n_shed": len(shed)}) if tracing else None
        err: Optional[Exception] = None
        if live:
            try:
                # run_group -> execute_chunk refreshes every name against
                # its store's current epoch before packing, so queries that
                # queued before an append_files still serve fresh data
                with self._exec_lock:
                    if fspan is not None:
                        with _trace.activate(fspan, self._now):
                            by_corpus = self._engine.run_group(
                                g.kind, names, l=g.l, terms=g.terms,
                                k=g.k, predicate=g.predicate, agg=g.agg,
                                target_shards=self.target_shards)
                    else:
                        by_corpus = self._engine.run_group(
                            g.kind, names, l=g.l, terms=g.terms, k=g.k,
                            predicate=g.predicate, agg=g.agg,
                            target_shards=self.target_shards)
            except Exception as e:              # noqa: BLE001 — fanned out
                err = e
                for p in live:
                    p.future.set_exception(e)
            else:
                for p in live:
                    p.future.set_result(by_corpus[p.query.corpus])
        if fspan is not None:
            if err is not None:
                fspan.attrs["error"] = type(err).__name__
            fspan.finish(self._now())
            done = fspan.t1
            for p in live:
                if p.span is not None:
                    p.span.children.append(fspan)
                    p.span.attrs["outcome"] = ("error" if err is not None
                                               else "ok")
                    self._engine.trace_log.append(p.span.finish(done))
        with self._lock:                 # concurrent flushes race the stats
            self.stats.count_flush(reason)
            self.stats.shed += len(shed)
            self.flush_log.append(FlushEvent(
                reason=reason, kind=g.kind, l=g.l, n_queries=len(live),
                n_corpora=len(names), at=now, n_shed=len(shed),
                terms=g.terms, k=g.k, predicate=g.predicate, agg=g.agg,
                span=fspan))

    # ---------------------------------------------------------- threaded --
    def start(self) -> "AsyncAnalyticsServer":
        """Run the flush policy on a background daemon thread."""
        with self._lock:
            if self._closed:
                raise RuntimeError("queue is closed")
            if self._thread is not None:
                raise RuntimeError("serve thread already running")
            self._stop.clear()
            self._wake.clear()
            self._thread = threading.Thread(target=self._serve_loop,
                                            name="analytics-queue",
                                            daemon=True)
            self._thread.start()
        return self

    def close(self) -> None:
        """Stop accepting queries, stop the serve thread (if any), and
        drain the rest — a submit racing close either drains here or
        raises, never hangs.  Idempotent; the queue stays closed."""
        t = None
        with self._lock:
            self._closed = True
            t, self._thread = self._thread, None
            self._space.notify_all()     # blocked submits must fail, not hang
        if t is not None:
            self._stop.set()
            self._wake.set()
            t.join()
        self.drain()

    def _serve_loop(self) -> None:
        while not self._stop.is_set():
            nxt = self.poll()
            now = self._now()
            delay = self.poll_interval
            if nxt is not None:
                delay = min(delay, max(nxt - now, 0.0))
            self._wake.wait(delay)
            self._wake.clear()

    def _kick(self) -> None:
        if self._thread is not None:
            self._wake.set()

    def __enter__(self) -> "AsyncAnalyticsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()
