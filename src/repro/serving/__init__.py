"""Serving: batched KV-cache decode on top of models.decode_step."""

from .decode import make_serve_step, make_prefill_step, greedy_generate

__all__ = ["make_serve_step", "make_prefill_step", "greedy_generate"]
