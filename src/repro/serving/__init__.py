"""Serving: batched KV-cache decode on top of models.decode_step, plus the
query-dispatch layer for the batched multi-corpus analytics engine and its
async deadline-aware submission queue."""

from .decode import make_serve_step, make_prefill_step, greedy_generate
from .analytics_server import AnalyticsServer, Query, ServerStats, \
    SERVED_KINDS
from .queue import (AsyncAnalyticsServer, DeadlineExceeded, FlushEvent,
                    QueueFull)

__all__ = ["make_serve_step", "make_prefill_step", "greedy_generate",
           "AnalyticsServer", "Query", "ServerStats", "SERVED_KINDS",
           "AsyncAnalyticsServer", "DeadlineExceeded", "FlushEvent",
           "QueueFull"]
