"""Query dispatch for the batched analytics engine.

Serving shape of the workload: many registered compressed corpora, a stream
of (corpus, analytics-kind) queries.  Running each query alone wastes the
device (one dispatch + one compilation per corpus shape).  The server:

1. groups incoming queries by analytics kind (and params, e.g. the l of
   sequence_count);
2. within a group, dedups corpora and orders them by grammar size so that
   each chunk of ``max_batch`` packs corpora of similar size (minimal
   padding waste — the bucketed :class:`GrammarBatch` dims round up to
   powers of two, so similar sizes collapse onto one compiled program);
3. executes ONE jitted batched call per chunk (``core.batch.run_batched``);
4. answers duplicate queries for the same corpus from the chunk result, and
   single-corpus chunks from the per-corpus path reusing the traversal
   weights memoized on :class:`repro.data.CompressedCorpus`.

``GrammarBatch`` packs are cached by corpus-id tuple, so a steady query mix
pays the host-side packing once.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple, Union

import numpy as np

from repro.core import GrammarArrays, analytics as _analytics
from repro.core.batch import ANALYTICS_KINDS, GrammarBatch, run_batched
from repro.data.store import CompressedCorpus


@dataclass(frozen=True)
class Query:
    """One analytics request against a registered corpus."""
    corpus: str
    kind: str                  # one of ANALYTICS_KINDS
    l: int = 3                 # sequence_count only

    def group_key(self) -> Tuple:
        return (self.kind, self.l if self.kind == "sequence_count" else None)


@dataclass
class ServerStats:
    queries: int = 0
    groups: int = 0            # (kind, params) groups seen
    batched_calls: int = 0     # jitted batched executions
    single_calls: int = 0      # per-corpus executions (memoized weights)
    batch_cache_hits: int = 0  # GrammarBatch packs reused
    # distinct pad signatures -> batched-call count (bounded by the number
    # of distinct bucket shapes, not by traffic volume)
    signatures: Dict[Tuple[int, ...], int] = field(default_factory=dict)


class AnalyticsServer:
    """Groups (corpus, query) requests and runs them as batched programs."""

    # methods every execution path (single and batched) supports; the
    # *_ell variants run the batched traversal on the dense ELL edge plan
    # (core/batch.py DESIGN note) and "auto" lets the occupancy dispatch in
    # kernels.ops pick ELL vs segment_sum per pack.
    METHODS = ("frontier", "leveled", "frontier_ell", "leveled_ell", "auto")
    # per-corpus traversal used when a chunk degenerates to one corpus
    # ("auto" resolves per pack; singles take the plain frontier)
    _SINGLE_METHOD = {"auto": "frontier"}

    def __init__(self, max_batch: int = 16, bucket: bool = True,
                 method: str = "frontier", max_cached_batches: int = 32):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch
        self.bucket = bucket
        if method not in self.METHODS:
            raise ValueError(f"method must be one of {self.METHODS}, "
                             f"got {method!r}")
        self.method = method
        if max_cached_batches < 1:
            raise ValueError("max_cached_batches must be >= 1")
        self.max_cached_batches = max_cached_batches
        self._corpora: Dict[str, GrammarArrays] = {}
        self._stores: Dict[str, CompressedCorpus] = {}
        self._batches: Dict[Tuple[str, ...], GrammarBatch] = {}
        self.stats = ServerStats()

    # ---------------------------------------------------------- registry --
    def register(self, name: str,
                 corpus: Union[GrammarArrays, CompressedCorpus]) -> None:
        """Register a compressed corpus under ``name``.  A
        :class:`CompressedCorpus` additionally contributes its memoized
        traversal weights to single-corpus execution."""
        if not isinstance(corpus, (CompressedCorpus, GrammarArrays)):
            raise TypeError(f"cannot register {type(corpus).__name__}")
        # drop any previous registration: a stale store would hand its
        # memoized weights to a different grammar
        self._stores.pop(name, None)
        if isinstance(corpus, CompressedCorpus):
            self._stores[name] = corpus
            self._corpora[name] = corpus.ga
        else:
            self._corpora[name] = corpus
        # packs that contained an older corpus under this name are stale
        self._batches = {k: v for k, v in self._batches.items()
                         if name not in k}

    def corpora(self) -> Tuple[str, ...]:
        return tuple(self._corpora)

    # ----------------------------------------------------------- serving --
    def run(self, queries: Sequence[Query]) -> List:
        """Execute all queries; results align with the input order and are
        identical to calling the single-corpus analytics per query."""
        for q in queries:
            if q.kind not in ANALYTICS_KINDS:
                raise ValueError(f"unknown analytics kind {q.kind!r}")
            if q.corpus not in self._corpora:
                raise KeyError(f"corpus {q.corpus!r} not registered")
        self.stats.queries += len(queries)

        # group by (kind, params), preserving first-seen order
        groups: Dict[Tuple, List[int]] = {}
        for i, q in enumerate(queries):
            groups.setdefault(q.group_key(), []).append(i)

        results: List = [None] * len(queries)
        for key, idxs in groups.items():
            self.stats.groups += 1
            kind, l = key
            names: List[str] = []
            for i in idxs:
                if queries[i].corpus not in names:
                    names.append(queries[i].corpus)
            by_corpus = self._run_group(kind, 3 if l is None else l, names)
            for i in idxs:
                results[i] = by_corpus[queries[i].corpus]
        return results

    # ---------------------------------------------------------- internals --
    def _run_group(self, kind: str, l: int, names: List[str]) -> Dict:
        # chunk corpora of similar grammar size together: padding in each
        # pack is bounded by the size spread within the chunk.  Name is the
        # tie-break so the chunking (and thus the pack-cache key) is
        # canonical for a given corpus set regardless of query order.
        order = sorted(names, key=lambda n: (self._corpora[n].num_rules, n))
        out: Dict = {}
        for s in range(0, len(order), self.max_batch):
            chunk = order[s: s + self.max_batch]
            if len(chunk) == 1:
                out[chunk[0]] = self._run_single(kind, l, chunk[0])
            else:
                gb = self._get_batch(chunk)
                vals = run_batched(gb, kind, method=self.method, l=l)
                self.stats.batched_calls += 1
                self.stats.signatures[gb.signature] = \
                    self.stats.signatures.get(gb.signature, 0) + 1
                out.update(zip(chunk, vals))
        return out

    def _get_batch(self, names: Sequence[str]) -> GrammarBatch:
        key = tuple(names)
        gb = self._batches.get(key)
        if gb is not None:
            self.stats.batch_cache_hits += 1
            return gb
        gb = GrammarBatch.build([self._corpora[n] for n in names],
                                bucket=self.bucket)
        while len(self._batches) >= self.max_cached_batches:
            self._batches.pop(next(iter(self._batches)))   # FIFO eviction
        self._batches[key] = gb
        return gb

    def _run_single(self, kind: str, l: int, name: str):
        """Per-corpus path: reuses weights memoized on the corpus store."""
        ga = self._corpora[name]
        store = self._stores.get(name)
        self.stats.single_calls += 1
        m = self._SINGLE_METHOD.get(self.method, self.method)
        # only run (and memoize) the traversal the query actually needs
        w = wf = None
        if store is not None:
            if kind in ("word_count", "sort", "sequence_count"):
                w = store.top_down_weights(m)
            elif kind in ("term_vector", "inverted_index",
                          "ranked_inverted_index"):
                wf = store.per_file_weights(m)
        if kind == "word_count":
            return np.asarray(_analytics.word_count(ga, method=m, weights=w))
        if kind == "sort":
            o, c = _analytics.sort_words(ga, method=m, weights=w)
            return (np.asarray(o), np.asarray(c))
        if kind == "term_vector":
            return np.asarray(_analytics.term_vector(ga, method=m,
                                                     file_weights=wf))
        if kind == "inverted_index":
            return np.asarray(_analytics.inverted_index(ga, method=m,
                                                        file_weights=wf))
        if kind == "ranked_inverted_index":
            r, c = _analytics.ranked_inverted_index(ga, method=m,
                                                    file_weights=wf)
            return (np.asarray(r), np.asarray(c))
        if kind == "sequence_count":
            return _analytics.sequence_count(ga, l=l, method=m, weights=w)
        raise ValueError(f"unknown analytics kind {kind!r}")
