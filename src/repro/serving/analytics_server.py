"""Query dispatch for the batched analytics engine.

Serving shape of the workload: many registered compressed corpora, a stream
of (corpus, analytics-kind) queries.  Running each query alone wastes the
device (one dispatch + one compilation per corpus shape).  The server:

1. groups incoming queries by analytics kind (and params, e.g. the l of
   sequence_count);
2. within a group, dedups corpora and orders them by grammar size so that
   each chunk of ``max_batch`` packs corpora of similar size (minimal
   padding waste — the bucketed :class:`GrammarBatch` dims round up to
   powers of two, so similar sizes collapse onto one compiled program);
3. executes ONE jitted batched call per chunk (``core.batch.run_batched``);
4. answers duplicate queries for the same corpus from the chunk result;
   single-corpus chunks take the per-corpus path reusing the traversal
   weights memoized on :class:`repro.data.CompressedCorpus`, or a cached
   size-1 pack (compiled programs + sequence plans reused) for bare
   :class:`GrammarArrays` registrations.

``GrammarBatch`` packs are cached by corpus-id tuple, so a steady query mix
pays the host-side packing once.

The engine core is split so the synchronous :meth:`AnalyticsServer.run` and
the async queue (:mod:`repro.serving.queue`) execute the exact same code:

* :meth:`AnalyticsServer.plan_groups` — validate + group a query list;
* :meth:`AnalyticsServer.run_group`   — canonical size-sorted chunking of
  one (kind, l) group;
* :meth:`AnalyticsServer.execute_chunk` — ONE batched (or memoized
  single-corpus) execution, with the observed latency folded into the
  per-signature EWMA on :class:`ServerStats` (the async flush policy reads
  those estimates to decide when a group's earliest deadline is "one batch
  away").

Device-sharded execution: when more than one device is visible the server
holds a 1-D corpus mesh (``mesh="auto"`` ->
:func:`repro.distributed.shard_batch.corpus_mesh`) and
:meth:`execute_chunk` selects a sharded pack by group size — chunks of at
least ``shard_min_corpora`` corpora (default: the device count) split
row-wise across the mesh and run as one program spanning all devices
(:meth:`GrammarBatch.shard`).  Chunk capacity grows accordingly:
:meth:`chunk_capacity` allows up to ``max_batch * devices`` corpora per
sharded chunk, which the async queue exploits through its
``target_shards`` knob.  On a single device everything transparently
degrades to the original per-device path — results are bit-identical
either way.
"""

from __future__ import annotations

import time
from collections.abc import MutableMapping
from contextlib import contextmanager, nullcontext
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.obs import BoundedLog, MetricsRegistry
from repro.obs import tracing as _trace

from repro.core import GrammarArrays, analytics as _analytics
from repro.core.batch import (ANALYTICS_KINDS, PER_FILE_KINDS, GrammarBatch,
                              is_segment_sum_fallback, resolve_batch_method,
                              run_batched, _round_up_pow2)
from repro.core.traversal import resolve_single_method
from repro.data.store import CompressedCorpus
from repro.distributed.shard_batch import (corpus_mesh, mesh_size,
                                           shard_batch)
from repro.query.engine import (QUERY_KINDS, query_corpus,
                                run_batched_query)
from repro.query.ops import (normalize_agg, normalize_phrase,
                             normalize_predicate)
from repro.search.engine import batched_search, search_corpus
from repro.search.index import base_method
from repro.search.scoring import (DEFAULT_TOP_K, KIND_SCHEME, SEARCH_KINDS,
                                  normalize_terms)

#: Everything the server accepts: the six analytics + ranked retrieval +
#: the composable query operators (filter / aggregate / phrase).
SERVED_KINDS = ANALYTICS_KINDS + SEARCH_KINDS + QUERY_KINDS

#: Query-tier kinds whose ``terms`` field is live (the agg term set, the
#: phrase token sequence).
_TERM_QUERY_KINDS = ("agg_terms", "phrase_count")


@dataclass(frozen=True)
class Query:
    """One analytics / search / query-operator request against a
    registered corpus."""
    corpus: str
    kind: str                  # one of SERVED_KINDS
    l: int = 3                 # sequence_count only
    terms: Optional[Tuple[int, ...]] = None   # search/agg_terms/phrase_count
    k: Optional[int] = None                   # search kinds only (top-k)
    predicate: Optional[Tuple] = None         # filter_count only
    agg: Optional[str] = None                 # agg_terms only (sum/max)
    # root Span of this query's lifecycle, set by the serving layer when
    # its registry is enabled (obs/tracing.py).  compare=False keeps it
    # out of eq/hash, so group keys and dataclass equality are untouched.
    trace: Optional[object] = field(default=None, compare=False,
                                    repr=False)

    def __post_init__(self):
        # keep the frozen dataclass hashable / group-keyable when callers
        # pass a list of term ids or a list-shaped predicate tree
        if self.terms is not None and not isinstance(self.terms, tuple):
            object.__setattr__(self, "terms",
                               tuple(int(t) for t in self.terms))
        if self.predicate is not None:
            object.__setattr__(self, "predicate",
                               normalize_predicate(self.predicate))

    def effective_l(self) -> Optional[int]:
        """``l`` is a sequence_count parameter ONLY: for every other kind it
        is normalized to ``None`` so it can neither split a group (two
        word_count queries with different ``l`` share one batched call) nor
        mis-share one (a sequence_count group always carries its real
        ``l``).  phrase_count's window length is the phrase itself, so even
        there ``l`` stays None."""
        return self.l if self.kind == "sequence_count" else None

    def effective_terms(self) -> Optional[Tuple[int, ...]]:
        """Query terms are live for the search kinds, ``agg_terms`` (the
        aggregation term set) and ``phrase_count`` (the phrase tokens) —
        normalized to ``None`` everywhere else (same contract as
        :meth:`effective_l`: a stray ``terms`` on word_count can neither
        split nor mis-share a group).  Term-carrying kinds always keep
        their real terms, so two distinct queries never share a chunk."""
        if self.kind in SEARCH_KINDS or self.kind in _TERM_QUERY_KINDS:
            return self.terms
        return None

    def effective_k(self) -> Optional[int]:
        """Top-k is a search parameter ONLY; search queries that omit it
        get :data:`repro.search.DEFAULT_TOP_K` so explicit-default and
        omitted-k queries share one group."""
        if self.kind not in SEARCH_KINDS:
            return None
        return DEFAULT_TOP_K if self.k is None else int(self.k)

    def effective_predicate(self) -> Optional[Tuple]:
        """The filter predicate is a ``filter_count`` parameter ONLY
        (canonicalized in ``__post_init__``); ``None`` off that kind so a
        stray predicate can never split an unrelated group, and two
        distinct predicates never share a chunk."""
        return self.predicate if self.kind == "filter_count" else None

    def effective_agg(self) -> Optional[str]:
        """The aggregation op is an ``agg_terms`` parameter ONLY; queries
        that omit it get the canonical default (``sum``) so explicit-
        default and omitted-op queries share one group."""
        if self.kind != "agg_terms":
            return None
        return normalize_agg(self.agg)

    def group_key(self) -> Tuple:
        return (self.kind, self.effective_l(), self.effective_terms(),
                self.effective_k(), self.effective_predicate(),
                self.effective_agg())


#: Flush/latency signature of the single-corpus execution path (no pack).
SINGLE_SIGNATURE: Tuple = ("single",)

#: Seconds assumed for a (kind, signature) pair never executed before; the
#: async queue uses this until real observations feed the EWMA.
DEFAULT_LATENCY_ESTIMATE = 0.02


def _encode_label(key) -> str:
    """Stable label rendering for dict-view keys: pack-signature tuples
    become ``8x16x...``, plain strings pass through."""
    if isinstance(key, tuple):
        return "x".join(str(v) for v in key)
    return str(key)


class _MetricDict(MutableMapping):
    """Dict-shaped view over one labeled counter family.

    Keys keep their original Python type (a flush reason string, the pack
    signature tuple) and values read back as ints, so the pre-registry
    call sites — ``stats.flushes.get("drain", 0)``,
    ``stats.signatures[sig] = ... + 1``, ``stats.method_fallbacks ==
    {...}`` — behave exactly as they did on a plain dict while every
    update lands in the registry."""

    def __init__(self, family, encode: Callable[[object], str] = str):
        self._family = family
        self._encode = encode
        self._children: Dict = {}

    def __getitem__(self, key):
        child = self._children.get(key)
        if child is None:
            raise KeyError(key)
        return int(child.value)

    def __setitem__(self, key, value) -> None:
        child = self._children.get(key)
        if child is None:
            child = self._children[key] = \
                self._family.labels(self._encode(key))
        child.set(float(value))

    def __delitem__(self, key) -> None:
        del self._children[key]
        self._family.remove(self._encode(key))

    def __iter__(self):
        return iter(self._children)

    def __len__(self) -> int:
        return len(self._children)

    def __eq__(self, other) -> bool:
        if isinstance(other, _MetricDict):
            other = dict(other)
        return dict(self) == other

    __hash__ = None

    def __repr__(self) -> str:
        return repr(dict(self))


class ServerStats:
    """Serving counters, all backed by a :class:`~repro.obs.MetricsRegistry`
    (the attribute API is a thin view: ``stats.queries += 1`` reads and
    writes the registered counter, the dict-shaped fields are
    :class:`_MetricDict` views over labeled families — so the same numbers
    show up in ``registry.snapshot()`` / ``render_prometheus()`` without
    any call-site churn).

    Scalar counters:

    * ``queries`` / ``groups`` — requests accepted, (kind, params) groups;
    * ``batched_calls`` / ``sharded_calls`` / ``single_calls`` — jitted
      batched executions, of which device-sharded, and per-corpus ones;
    * ``batch_cache_hits`` — GrammarBatch packs reused;
    * ``epoch_invalidations`` — packs dropped / corpora re-snapshotted
      because a registered store's epoch moved (append_files): each count
      is one "stale grammar could NOT be served" event;
    * ``submitted`` / ``rejected`` / ``shed`` — async queue accounting
      (entered through submit, refused by max_pending, expired at flush);
    * ``max_queue_depth`` — pending-query high-water mark (a gauge).

    Dict views (labeled counter families):

    * ``signatures`` — pad signature -> batched-call count (bounded by the
      number of distinct bucket shapes, not traffic volume);
    * ``method_fallbacks`` — "requested->resolved" counts of explicit
      ELL-family requests that degraded to their segment_sum base
      (core.batch.is_segment_sum_fallback);
    * ``flushes`` — flush reason -> count (written by serving/queue.py).

    The latency estimator state (``latency_ewma`` / ``latency_obs``) stays
    plain host dicts: it is flush-*policy* control state keyed by tuples,
    not a metric — the per-stage histograms carry the observable side.
    """

    _SCALARS = {
        "queries": ("repro_server_queries_total",
                    "queries accepted by run()/submit()"),
        "groups": ("repro_server_groups_total",
                   "(kind, params) query groups executed"),
        "batched_calls": ("repro_server_batched_calls_total",
                          "jitted batched executions"),
        "sharded_calls": ("repro_server_sharded_calls_total",
                          "batched executions that spanned a device mesh"),
        "single_calls": ("repro_server_single_calls_total",
                         "per-corpus executions (memoized weights)"),
        "batch_cache_hits": ("repro_server_batch_cache_hits_total",
                             "GrammarBatch packs reused from the cache"),
        "epoch_invalidations": ("repro_server_epoch_invalidations_total",
                                "stale packs/corpora dropped on an epoch "
                                "bump (ingest appends)"),
        "submitted": ("repro_queue_submitted_total",
                      "queries entered through the async queue"),
        "rejected": ("repro_queue_rejected_total",
                     "submits refused by the max_pending bound"),
        "shed": ("repro_queue_shed_total",
                 "queries shed at flush time (deadline already passed)"),
    }

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        r = self.registry
        self._scalars = {attr: r.counter(name, help_)
                         for attr, (name, help_) in self._SCALARS.items()}
        self._depth_high = r.gauge(
            "repro_queue_depth_high_water",
            "pending-query depth high-water mark")
        self.flushes = _MetricDict(r.counter(
            "repro_queue_flushes_total",
            "async queue flushes by firing condition", ("reason",)))
        self.signatures = _MetricDict(r.counter(
            "repro_server_pack_signatures_total",
            "batched calls by pack pad signature", ("signature",)),
            _encode_label)
        self.method_fallbacks = _MetricDict(r.counter(
            "repro_server_method_fallbacks_total",
            "explicit ELL-family requests degraded to a segment_sum base",
            ("transition",)))
        # submit-to-result decomposition: pack_build / compile / execute /
        # queue_wait (docs/observability.md has the stage model)
        self.stage_seconds = r.histogram(
            "repro_server_stage_seconds",
            "per-stage latency of query execution", ("stage",))
        # ----- latency estimator (plain host state, see class docstring):
        # EWMA of observed chunk latencies keyed by (kind, signature) —
        # GrammarBatch pad signature for batched chunks, SINGLE_SIGNATURE
        # for the per-corpus path.  Bounded by the number of distinct
        # (kind, bucket-shape) pairs, not by traffic volume.
        self.latency_ewma: Dict[Tuple, float] = {}
        self.latency_obs: Dict[Tuple, int] = {}
        self.ewma_alpha: float = 0.25

    @property
    def max_queue_depth(self) -> int:
        return int(self._depth_high.value)

    @max_queue_depth.setter
    def max_queue_depth(self, v: int) -> None:
        self._depth_high.set(float(v))

    def __repr__(self) -> str:
        scalars = ", ".join(f"{a}={getattr(self, a)}"
                            for a in self._SCALARS)
        return (f"ServerStats({scalars}, "
                f"max_queue_depth={self.max_queue_depth}, "
                f"flushes={dict(self.flushes)}, "
                f"signatures={dict(self.signatures)}, "
                f"method_fallbacks={dict(self.method_fallbacks)})")

    def observe_latency(self, kind: str, signature: Tuple,
                        seconds: float) -> None:
        key = (kind, signature)
        n = self.latency_obs.get(key, 0)
        self.latency_obs[key] = n + 1
        if n == 0:
            # a key's first execution pays jit compilation (possibly
            # seconds) that recurring traffic never sees again; adopting it
            # would inflate the deadline-flush estimate and collapse
            # deadline-carrying groups into near-singleton flushes
            return
        prev = self.latency_ewma.get(key)
        self.latency_ewma[key] = (
            seconds if prev is None
            else self.ewma_alpha * seconds + (1.0 - self.ewma_alpha) * prev)

    def estimate_latency(self, kind: Optional[str] = None,
                         default: float = DEFAULT_LATENCY_ESTIMATE) -> float:
        """Expected seconds for one batched call of ``kind``.

        Takes the MAX over that kind's per-signature EWMAs (falling back to
        all kinds, then ``default``): a pending group's pack signature is
        unknown until it is chunked, and averaging in the cheap
        single-corpus path would make the deadline flush fire too late for
        batched groups — overestimating only flushes a little early."""
        vals = [v for (k, _sig), v in self.latency_ewma.items()
                if kind is None or k == kind]
        if not vals:
            vals = list(self.latency_ewma.values())
        if not vals:
            return default
        return max(vals)

    def count_flush(self, reason: str) -> None:
        self.flushes[reason] = self.flushes.get(reason, 0) + 1

    def count_fallback(self, requested: str, resolved: str) -> None:
        key = f"{requested}->{resolved}"
        self.method_fallbacks[key] = self.method_fallbacks.get(key, 0) + 1


def _scalar_property(attr: str) -> property:
    """int-reading, registry-writing property so ``stats.x += 1`` keeps
    working on counter-backed attributes."""
    def _get(self) -> int:
        return int(self._scalars[attr].value)

    def _set(self, v) -> None:
        self._scalars[attr].set(float(v))

    return property(_get, _set)


for _attr in ServerStats._SCALARS:
    setattr(ServerStats, _attr, _scalar_property(_attr))
del _attr


class AnalyticsServer:
    """Groups (corpus, query) requests and runs them as batched programs."""

    # methods every execution path (single and batched) supports; the
    # *_ell variants run the batched traversal on the dense ELL edge plan
    # (core/batch.py DESIGN note), "frontier_fused" runs the whole frontier
    # loop in one kernel launch (kernels/propagate_fused.py; per-file and
    # search traversals take its per-round ELL base), and "auto" lets the
    # occupancy dispatch in kernels.ops pick the engine per pack.
    METHODS = ("frontier", "leveled", "frontier_ell", "leveled_ell",
               "frontier_fused", "auto")
    # per-corpus traversal used when a chunk degenerates to one corpus
    # ("auto" resolves per pack; singles take the plain frontier)
    _SINGLE_METHOD = {"auto": "frontier"}

    def __init__(self, max_batch: int = 16, bucket: bool = True,
                 method: str = "frontier", max_cached_batches: int = 32,
                 mesh: object = "auto",
                 shard_min_corpora: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic,
                 registry: Optional[MetricsRegistry] = None,
                 trace_log_size: int = 1024):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch
        self.bucket = bucket
        if method not in self.METHODS:
            raise ValueError(f"method must be one of {self.METHODS}, "
                             f"got {method!r}")
        self.method = method
        if max_cached_batches < 1:
            raise ValueError("max_cached_batches must be >= 1")
        self.max_cached_batches = max_cached_batches
        # device-sharded execution: "auto" -> 1-D mesh over the local
        # devices (None when only one is visible — the transparent
        # single-device fallback); None -> never shard; or a caller mesh.
        self.mesh = corpus_mesh() if mesh == "auto" else mesh
        if shard_min_corpora is not None and shard_min_corpora < 1:
            raise ValueError("shard_min_corpora must be >= 1")
        # default: shard once a chunk has at least one corpus per device
        self.shard_min_corpora = (mesh_size(self.mesh)
                                  if shard_min_corpora is None
                                  else shard_min_corpora)
        self._corpora: Dict[str, GrammarArrays] = {}
        self._stores: Dict[str, CompressedCorpus] = {}
        # epoch each corpus's arrays snapshot was taken at (0 for bare
        # GrammarArrays registrations, which are immutable)
        self._epochs: Dict[str, int] = {}
        self._batches: Dict[Tuple, GrammarBatch] = {}
        # one injectable clock for the whole serving stack: chunk timing
        # here, flush policy in the async queue (which defaults to this
        # clock), span timestamps — so latency tests never sleep
        self.clock = clock
        self.registry = registry if registry is not None \
            else MetricsRegistry(clock=clock)
        self.stats = ServerStats(self.registry)
        # bounded ring of completed root spans (query/chunk trees); the
        # drop gauge makes eviction visible, like the queue's flush_log
        self.trace_log = BoundedLog(trace_log_size, gauge=self.registry.gauge(
            "repro_server_trace_log_dropped_spans",
            "root spans evicted from the bounded trace ring"))

    # ---------------------------------------------------------- registry --
    def register(self, name: str,
                 corpus: Union[GrammarArrays, CompressedCorpus]) -> None:
        """Register a compressed corpus under ``name``.  A
        :class:`CompressedCorpus` additionally contributes its memoized
        traversal weights to single-corpus execution."""
        if not isinstance(corpus, (CompressedCorpus, GrammarArrays)):
            raise TypeError(f"cannot register {type(corpus).__name__}")
        # drop any previous registration: a stale store would hand its
        # memoized weights to a different grammar
        self._stores.pop(name, None)
        if isinstance(corpus, CompressedCorpus):
            self._stores[name] = corpus
            self._corpora[name] = corpus.ga
            self._epochs[name] = int(corpus.epoch)
        else:
            self._corpora[name] = corpus
            self._epochs[name] = 0
        # packs that contained an older corpus under this name are stale
        # (cache keys are (names_tuple, shards))
        self._batches = {k: v for k, v in self._batches.items()
                         if name not in k[0]}

    def corpora(self) -> Tuple[str, ...]:
        return tuple(self._corpora)

    def refresh(self, name: str) -> bool:
        """Re-snapshot ``name``'s arrays if its registered store mutated
        (``CompressedCorpus.append_files`` bumped the epoch) since the last
        snapshot; purges every cached pack containing the corpus.  Returns
        True when a refresh happened.  Called on every validate and at the
        top of every :meth:`execute_chunk` — an epoch-cheap int compare —
        so neither the sync path nor an async flush whose corpus was
        appended to *between submit and flush* can serve pre-append data
        (the re-registration path: tests/test_ingest.py).
        """
        store = self._stores.get(name)
        if store is None or store.epoch == self._epochs.get(name):
            return False
        self._corpora[name] = store.ga
        self._epochs[name] = int(store.epoch)
        self._batches = {key: gb for key, gb in self._batches.items()
                         if name not in key[0]}
        self.stats.epoch_invalidations += 1
        return True

    def validate(self, q: Query) -> None:
        if q.kind not in SERVED_KINDS:
            raise ValueError(f"unknown analytics kind {q.kind!r}; "
                             f"expected one of {SERVED_KINDS}")
        if q.kind in SEARCH_KINDS:
            normalize_terms(q.terms)         # raises on None/empty/negative
            if q.k is not None and q.k < 1:
                raise ValueError(f"search top-k must be >= 1, got {q.k}")
        if q.kind == "filter_count" and q.predicate is None:
            raise ValueError("filter_count queries need a predicate")
        if q.kind == "agg_terms":
            normalize_terms(q.terms)         # raises on None/empty/negative
            normalize_agg(q.agg)             # raises on unknown ops
        if q.kind == "phrase_count":
            normalize_phrase(q.terms)        # raises unless >= 2 valid ids
        if q.corpus not in self._corpora:
            raise KeyError(f"corpus {q.corpus!r} not registered")
        self.refresh(q.corpus)

    def size_bucket(self, name: str) -> int:
        """Grammar-size bucket of a registered corpus (power-of-two rule
        count, matching the :class:`GrammarBatch` pad bucketing) — the async
        queue groups pending queries by it so a flush packs corpora of
        similar size onto one compiled program."""
        return _round_up_pow2(self._corpora[name].num_rules)

    # ----------------------------------------------------------- serving --
    def plan_groups(self, queries: Sequence[Query]
                    ) -> List[Tuple[Tuple, List[int]]]:
        """Validate ``queries`` and group them by :meth:`Query.group_key`.

        Returns ``[(group_key, idxs)]`` in first-seen order; the key is the
        normalized ``(kind, l, terms, k, predicate, agg)`` tuple — ``l`` is
        None for every kind but sequence_count, ``terms`` is None off the
        search / agg_terms / phrase_count kinds, ``k`` off the search
        kinds, ``predicate`` off filter_count and ``agg`` off agg_terms
        (see the ``effective_*`` normalizers on :class:`Query`).
        """
        for q in queries:
            self.validate(q)
        groups: Dict[Tuple, List[int]] = {}
        for i, q in enumerate(queries):
            groups.setdefault(q.group_key(), []).append(i)
        return list(groups.items())

    def run(self, queries: Sequence[Query]) -> List:
        """Execute all queries; results align with the input order and are
        identical to calling the single-corpus analytics per query.

        With the registry enabled, every query gets a root span on
        ``q.trace``: the group's ``run_group`` span (shared across the
        queries it answered — that sharing IS the batching) hangs under
        each root, with chunk/pack_build/plan/execute children below it.
        """
        plans = self.plan_groups(queries)
        self.stats.queries += len(queries)
        tracing = self.registry.enabled
        roots: List[Optional[_trace.Span]] = []
        if tracing:
            now = self.clock()
            for q in queries:
                root = _trace.Span("query", now,
                                   attrs={"corpus": q.corpus,
                                          "kind": q.kind, "path": "sync"})
                object.__setattr__(q, "trace", root)
                roots.append(root)

        results: List = [None] * len(queries)
        for (kind, l, terms, k, predicate, agg), idxs in plans:
            self.stats.groups += 1
            names: List[str] = []
            for i in idxs:
                if queries[i].corpus not in names:
                    names.append(queries[i].corpus)
            if tracing:
                g = _trace.Span("run_group", self.clock(),
                                attrs={"kind": kind,
                                       "n_queries": len(idxs),
                                       "n_corpora": len(names)})
                with _trace.activate(g, self.clock):
                    by_corpus = self.run_group(kind, names, l=l,
                                               terms=terms, k=k,
                                               predicate=predicate, agg=agg)
                g.finish(self.clock())
                for i in idxs:
                    roots[i].children.append(g)
            else:
                by_corpus = self.run_group(kind, names, l=l, terms=terms,
                                           k=k, predicate=predicate,
                                           agg=agg)
            for i in idxs:
                results[i] = by_corpus[queries[i].corpus]
        if tracing:
            end = self.clock()
            for root in roots:
                root.finish(end)
                self.trace_log.append(root)
        return results

    # ------------------------------------------------------- engine core --
    def shard_count(self, n_corpora: int) -> int:
        """Devices a chunk of ``n_corpora`` would span: the mesh size once
        the chunk reaches ``shard_min_corpora`` (or outgrows a
        single-device pack), else 1."""
        if self.mesh is None:
            return 1
        if n_corpora >= self.shard_min_corpora or n_corpora > self.max_batch:
            return mesh_size(self.mesh)
        return 1

    def chunk_capacity(self, target_shards: int = 1) -> int:
        """Corpora one :meth:`execute_chunk` call may carry.

        ``max_batch`` stays the per-device pack bound; with a mesh and
        ``target_shards`` > 1 a chunk may span that many devices, so the
        capacity scales to ``max_batch * min(target_shards, devices)`` —
        the async queue's ``target_shards`` knob feeds this so large
        flushes split across devices instead of serializing
        ``max_batch``-sized chunks."""
        if target_shards < 1:
            raise ValueError("target_shards must be >= 1")
        return self.max_batch * min(target_shards, mesh_size(self.mesh))

    def run_group(self, kind: str, names: Sequence[str],
                  l: Optional[int] = None,
                  terms: Optional[Tuple[int, ...]] = None,
                  k: Optional[int] = None,
                  predicate: Optional[Tuple] = None,
                  agg: Optional[str] = None,
                  target_shards: int = 1) -> Dict:
        """Execute one normalized-parameter group over deduped ``names``.

        Chunks corpora of similar grammar size together: padding in each
        pack is bounded by the size spread within the chunk.  Name is the
        tie-break so the chunking (and thus the pack-cache key) is canonical
        for a given corpus set regardless of query order.  Both the sync
        :meth:`run` and the async queue flush land here;
        ``target_shards`` > 1 widens each chunk to span that many devices
        (:meth:`chunk_capacity`).
        """
        cap = self.chunk_capacity(target_shards)
        order = sorted(names, key=lambda n: (self._corpora[n].num_rules, n))
        out: Dict = {}
        for s in range(0, len(order), cap):
            out.update(self.execute_chunk(kind, order[s: s + cap], l=l,
                                          terms=terms, k=k,
                                          predicate=predicate, agg=agg))
        return out

    def _check_chunk_params(self, kind: str, l: Optional[int],
                            terms: Optional[Tuple[int, ...]],
                            k: Optional[int],
                            predicate: Optional[Tuple] = None,
                            agg: Optional[str] = None) -> None:
        """Group parameters must arrive normalized (``Query.effective_*``):
        required for the kinds that consume them, ``None`` everywhere else —
        a stray parameter can therefore never split or mis-share a group,
        and a missing one fails loudly instead of silently defaulting."""
        if kind == "sequence_count":
            if l is None:
                raise ValueError("sequence_count chunk needs an explicit l")
        elif l is not None:
            raise ValueError(
                f"l={l!r} is meaningless for kind {kind!r}; group keys "
                f"normalize it to None (Query.effective_l)")
        if kind in SEARCH_KINDS:
            normalize_terms(terms)
            if k is None or k < 1:
                raise ValueError(f"search chunk needs an explicit k >= 1, "
                                 f"got {k!r}")
        elif kind == "agg_terms":
            normalize_terms(terms)
        elif kind == "phrase_count":
            normalize_phrase(terms)
        elif terms is not None:
            raise ValueError(
                f"terms={terms!r} are meaningless for kind {kind!r}; group "
                f"keys normalize them to None (Query.effective_terms)")
        if kind not in SEARCH_KINDS and k is not None:
            raise ValueError(
                f"k={k!r} is meaningless for kind {kind!r}; group keys "
                f"normalize it to None (Query.effective_k)")
        if kind == "filter_count":
            normalize_predicate(predicate)   # raises on None/malformed
        elif predicate is not None:
            raise ValueError(
                f"predicate={predicate!r} is meaningless for kind "
                f"{kind!r}; group keys normalize it to None "
                f"(Query.effective_predicate)")
        if kind == "agg_terms":
            if agg not in ("sum", "max"):
                raise ValueError(f"agg_terms chunk needs an explicit "
                                 f"sum/max op, got {agg!r}")
        elif agg is not None:
            raise ValueError(
                f"agg={agg!r} is meaningless for kind {kind!r}; group "
                f"keys normalize it to None (Query.effective_agg)")

    def _count_fallback(self, kind: str, gb: Optional[GrammarBatch] = None,
                        ga: Optional[GrammarArrays] = None) -> None:
        """Predict the engine's traversal routing for this execution and
        count explicit-ELL requests that degrade to a segment_sum base
        (``stats.method_fallbacks``).  Uses the same resolution the engines
        dispatch on (core.batch.resolve_batch_method / the single-corpus
        analogue), so the counter mirrors what actually runs without the
        engines having to report back through the jitted paths."""
        per_file = (kind in PER_FILE_KINDS or kind in SEARCH_KINDS
                    or kind in ("filter_count", "agg_terms"))
        requested = self.method
        if gb is None:
            requested = self._SINGLE_METHOD.get(requested, requested)
        if kind in SEARCH_KINDS or kind in ("filter_count", "agg_terms"):
            # search statistics (and the query tier's filter/agg counts,
            # which share them) run the per-file base of the requested
            # method (search/index.py base_method)
            requested = base_method(requested)
        if gb is not None:
            resolved = resolve_batch_method(gb, requested, per_file=per_file)
        else:
            resolved = resolve_single_method(ga, requested,
                                             per_file=per_file)
        if is_segment_sum_fallback(requested, resolved):
            self.stats.count_fallback(requested, resolved)

    def _execute_batched(self, gb: GrammarBatch, kind: str,
                         l: Optional[int], terms: Optional[Tuple[int, ...]],
                         k: Optional[int],
                         predicate: Optional[Tuple] = None,
                         agg: Optional[str] = None) -> List:
        """One batched program over a pack: the six analytics via
        ``run_batched``, the search kinds via the retrieval engine (which
        memoizes its tf/df/dl statistics on the same pack), the query
        operators via the query engine (filter/agg share those memoized
        statistics; phrase reuses the pack's sequence plans)."""
        if kind in SEARCH_KINDS:
            return batched_search(gb, terms, k=k, scheme=KIND_SCHEME[kind],
                                  method=self.method)
        if kind in QUERY_KINDS:
            return run_batched_query(gb, kind, predicate=predicate,
                                     terms=terms, agg=agg,
                                     method=self.method)
        return run_batched(gb, kind, method=self.method,
                           l=3 if l is None else l)

    def execute_chunk(self, kind: str, chunk: Sequence[str],
                      l: Optional[int] = None,
                      terms: Optional[Tuple[int, ...]] = None,
                      k: Optional[int] = None,
                      predicate: Optional[Tuple] = None,
                      agg: Optional[str] = None) -> Dict:
        """ONE execution: a jitted batched call for a multi-corpus chunk, or
        the per-corpus path (memoized weights) when the chunk degenerates to
        one corpus.  Records the observed wall latency into the
        per-signature EWMA (``stats.latency_ewma``) that the async flush
        policy uses as its batch-latency estimate.

        ``l``/``terms``/``k``/``predicate``/``agg`` must be the
        group-normalized parameters: real values for the kinds that consume
        them (sequence_count's window length; the search kinds' query terms
        and top-k; filter_count's predicate; agg_terms'/phrase_count's term
        set and op), ``None`` for every other kind (enforced in
        :meth:`_check_chunk_params` so a stray ``Query`` field can never
        split or mis-share a group).

        Sharded mode (:meth:`shard_count` > 1): the pack splits row-wise
        across the corpus mesh and one program spans all devices — results
        remain bit-identical to the single-device pack.
        """
        self._check_chunk_params(kind, l, terms, k, predicate=predicate,
                                 agg=agg)
        # flush-time freshness: a store appended to after its queries were
        # validated/grouped must still be served post-append data
        for name in chunk:
            self.refresh(name)
        shards = self.shard_count(len(chunk))
        if len(chunk) > self.max_batch * max(shards, 1):
            raise ValueError(f"chunk of {len(chunk)} exceeds "
                             f"max_batch={self.max_batch} x {shards} shards")
        tracing = self.registry.enabled
        top_level = tracing and _trace.current() is None
        t0 = self.clock()
        hits0 = self.stats.batch_cache_hits
        cm = (_trace.span("chunk", clock=self.clock,
                          attrs={"kind": kind, "n_corpora": len(chunk),
                                 "shards": shards})
              if tracing else nullcontext())
        with cm as chunk_span:
            if len(chunk) == 1 and shards == 1:
                name = chunk[0]
                if name in self._stores:
                    # CompressedCorpus: the per-corpus path reuses the
                    # traversal weights (and search index) memoized on the
                    # store
                    sig = SINGLE_SIGNATURE
                    with self._obs_stage("pack_build", tracing,
                                         path="store_memo"):
                        self._count_fallback(kind, ga=self._corpora[name])
                    with self._obs_exec(kind, sig, tracing):
                        out = {name: self._run_single(kind, name, l=l,
                                                      terms=terms, k=k,
                                                      predicate=predicate,
                                                      agg=agg)}
                else:
                    # bare GrammarArrays: a cached size-1 pack keeps
                    # compiled programs and host plans (sequence_count
                    # windows, search statistics) across calls — repeat
                    # single-corpus traffic costs one dispatch, not one
                    # re-plan + re-compile
                    with self._obs_stage("pack_build", tracing):
                        gb = self._get_batch([name])
                        self._count_fallback(kind, gb=gb)
                    sig = gb.signature
                    with self._obs_exec(kind, sig, tracing):
                        vals = self._execute_batched(gb, kind, l, terms, k,
                                                     predicate=predicate,
                                                     agg=agg)
                    out = {name: vals[0]}
                self.stats.single_calls += 1
            else:
                with self._obs_stage("pack_build", tracing):
                    gb = self._get_batch(list(chunk), shards=shards)
                    self._count_fallback(kind, gb=gb)
                sig = gb.signature
                with self._obs_exec(kind, sig, tracing):
                    vals = self._execute_batched(gb, kind, l, terms, k,
                                                 predicate=predicate,
                                                 agg=agg)
                self.stats.batched_calls += 1
                if shards > 1:
                    self.stats.sharded_calls += 1
                self.stats.signatures[gb.signature] = \
                    self.stats.signatures.get(gb.signature, 0) + 1
                out = dict(zip(chunk, vals))
            if chunk_span is not None:
                chunk_span.attrs["signature"] = _encode_label(sig)
                chunk_span.attrs["cache_hit"] = \
                    self.stats.batch_cache_hits > hits0
        self.stats.observe_latency(kind, sig, self.clock() - t0)
        if top_level:
            # a chunk reached outside any query/flush span (direct
            # execute_chunk / run_group callers): log its tree standalone
            self.trace_log.append(chunk_span)
        return out

    @contextmanager
    def _obs_stage(self, stage: str, tracing: bool, **attrs):
        """One stage span under the ambient chunk span + the per-stage
        histogram; collapses to nothing when the registry is disabled."""
        if not tracing:
            yield None
            return
        with _trace.span(stage, clock=self.clock, attrs=attrs) as s:
            yield s
        self.stats.stage_seconds.labels(stage).observe(s.duration)

    def _obs_exec(self, kind: str, sig: Tuple, tracing: bool):
        """The device-execution stage.  Named ``compile`` on the first
        execution of a (kind, signature) pair — that call pays jit
        compilation, the same first-call the latency EWMA skips
        (``observe_latency``) — and ``execute`` on every later one."""
        if not tracing:
            return nullcontext()
        first = (kind, sig) not in self.stats.latency_obs
        return self._obs_stage("compile" if first else "execute", True,
                               first_call=first)

    # ---------------------------------------------------------- internals --
    def _get_batch(self, names: Sequence[str],
                   shards: int = 1) -> GrammarBatch:
        key = (tuple(names), shards)
        epochs = tuple(self._epochs.get(n, 0) for n in names)
        gb = self._batches.get(key)
        if gb is not None:
            # belt-and-braces: refresh() already purges packs when a store
            # mutates, but an epoch-stamped hit is re-verified anyway so a
            # stale pack cannot serve even if a future code path forgets
            # the refresh (the raising guard is GrammarBatch.check_epochs;
            # tests monkeypatch refresh away to prove this layer fires)
            if gb.epochs == epochs or gb.epochs is None:
                self.stats.batch_cache_hits += 1
                return gb
            del self._batches[key]
            self.stats.epoch_invalidations += 1
        gas = [self._corpora[n] for n in names]
        if shards > 1:
            # shards > 1 implies shards == mesh_size(self.mesh): the pad +
            # build + shard recipe is the library's, in one place
            gb = shard_batch(gas, self.mesh, bucket=self.bucket,
                             epochs=epochs)
        else:
            gb = GrammarBatch.build(gas, bucket=self.bucket, epochs=epochs)
        while len(self._batches) >= self.max_cached_batches:
            self._batches.pop(next(iter(self._batches)))   # FIFO eviction
        self._batches[key] = gb
        return gb

    def _run_single(self, kind: str, name: str, l: Optional[int] = None,
                    terms: Optional[Tuple[int, ...]] = None,
                    k: Optional[int] = None,
                    predicate: Optional[Tuple] = None,
                    agg: Optional[str] = None):
        """Per-corpus path: reuses weights memoized on the corpus store."""
        ga = self._corpora[name]
        store = self._stores.get(name)
        m = self._SINGLE_METHOD.get(self.method, self.method)
        if kind in QUERY_KINDS:
            # query_corpus duck-types the store: filter/agg reuse the
            # memoized per-file traversal weights, phrase the memoized
            # top-down weights
            return query_corpus(store if store is not None else ga, kind,
                                predicate=predicate, terms=terms, agg=agg,
                                method=m)
        if kind in SEARCH_KINDS:
            # search_corpus reuses the SearchIndex memoized on the store
            # (and, through it, the memoized per-file traversal weights)
            return search_corpus(store if store is not None else ga,
                                 terms, k=k, scheme=KIND_SCHEME[kind],
                                 method=m)
        # only run (and memoize) the traversal the query actually needs
        w = wf = None
        if store is not None:
            if kind in ("word_count", "sort", "sequence_count"):
                w = store.top_down_weights(m)
            elif kind in ("term_vector", "inverted_index",
                          "ranked_inverted_index"):
                wf = store.per_file_weights(m)
        if kind == "word_count":
            return np.asarray(_analytics.word_count(ga, method=m, weights=w))
        if kind == "sort":
            o, c = _analytics.sort_words(ga, method=m, weights=w)
            return (np.asarray(o), np.asarray(c))
        if kind == "term_vector":
            return np.asarray(_analytics.term_vector(ga, method=m,
                                                     file_weights=wf))
        if kind == "inverted_index":
            return np.asarray(_analytics.inverted_index(ga, method=m,
                                                        file_weights=wf))
        if kind == "ranked_inverted_index":
            r, c = _analytics.ranked_inverted_index(ga, method=m,
                                                    file_weights=wf)
            return (np.asarray(r), np.asarray(c))
        if kind == "sequence_count":
            return _analytics.sequence_count(ga, l=l, method=m, weights=w)
        raise ValueError(f"unknown analytics kind {kind!r}")
