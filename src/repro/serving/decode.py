"""Batched serving: prefill + decode step factories (pure, pjit-ready).

``serve_step`` is what the dry-run lowers for the ``decode_*`` /
``long_500k`` cells: one new token for the whole batch against a
pre-allocated cache of ``seq_len`` (KV rings for attention layers, O(1)
SSD state for mamba layers — the long_500k cells exist precisely because
the SSM/hybrid archs keep this constant-size).
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import apply_lm, decode_step, init_cache
from repro.models.config import ModelConfig


def make_serve_step(cfg: ModelConfig, sample: str = "greedy",
                    temperature: float = 1.0,
                    unroll: bool = False) -> Callable:
    def serve_step(params, cache, tokens, rng=None):
        logits, cache = decode_step(cfg, params, cache, tokens,
                                    unroll=unroll)
        if sample == "greedy":
            nxt = jnp.argmax(logits[:, -1, :], axis=-1)
        else:
            nxt = jax.random.categorical(rng, logits[:, -1, :] / temperature)
        return nxt.astype(jnp.int32)[:, None], cache, logits
    return serve_step


def make_prefill_step(cfg: ModelConfig, unroll: bool = False) -> Callable:
    """Prefill: run the full prompt, return last-position logits.
    (Cache writing during prefill is decode-loop based for attention archs
    at test scale; production prefill uses the parallel path + cache scatter
    — the dry-run prefill cells lower the parallel path.)"""
    def prefill(params, tokens, extra_embeds=None):
        logits, _ = apply_lm(cfg, params, tokens, extra_embeds=extra_embeds,
                             remat=False, unroll=unroll)
        return logits
    return prefill


def greedy_generate(cfg: ModelConfig, params, prompt: jnp.ndarray,
                    steps: int, max_len: Optional[int] = None,
                    extra_embeds=None) -> jnp.ndarray:
    """Host loop: feed prompt token-by-token, then generate ``steps`` more.
    Returns [B, steps] generated ids.  Test/demo scale."""
    from repro.models import prefill_cross
    B, P = prompt.shape
    max_len = max_len or (P + steps)
    cache = init_cache(cfg, B, max_len)
    if cfg.family == "encdec":
        cache = prefill_cross(cfg, params, cache, extra_embeds)
    step = jax.jit(make_serve_step(cfg))
    tok = None
    for t in range(P):
        tok, cache, _ = step(params, cache, prompt[:, t:t + 1])
    out = []
    for _ in range(steps):
        out.append(tok)
        tok, cache, _ = step(params, cache, tok)
    return jnp.concatenate(out, axis=1)
