"""GPipe pipeline parallelism over a "stage" mesh axis.

shard_map + lax.ppermute implementation: each device along the stage axis
holds one stage's parameters; microbatches stream through with the classic
(M + S - 1)-tick schedule; activations hop stages via collective_permute
(point-to-point on the ICI torus, overlappable with compute by XLA's async
collective pass).

This is the optional PP mode of DESIGN.md §4: the assigned models fit on
the 256-chip pod with DP x TP x FSDP, so the 40-cell dry-run does not use
PP; the module exists for deeper-than-memory models and is exercised by a
multi-device subprocess test (tests/test_distributed.py) on 8 host devices.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
try:
    from jax import shard_map  # jax >= 0.8 canonical location
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map


def gpipe(stage_fn: Callable, mesh: Mesh, n_stages: int,
          stage_axis: str = "stage") -> Callable:
    """Build a pipelined forward.

    ``stage_fn(params_slice, x) -> y`` is one stage's compute; all stages
    must share input/output activation shape (classic GPipe).

    Returns ``run(stacked_params, microbatches)`` where ``stacked_params``
    leaves have leading dim ``n_stages`` and ``microbatches`` is
    [M, mb, ...]; output is [M, mb, ...] after the last stage.
    """

    def run(stacked_params, microbatches):
        M = microbatches.shape[0]

        def per_device(params, mb):
            # params: [1, ...] my stage's slice; mb: [M, ...] (replicated in)
            params = jax.tree.map(lambda x: x[0], params)
            idx = jax.lax.axis_index(stage_axis)
            # jax.lax.axis_size is not available on older jax; psum of ones
            # is the portable spelling.
            S = jax.lax.psum(1, stage_axis)
            ticks = M + S - 1

            def tick(carry, t):
                buf, outs = carry
                # stage 0 injects microbatch t (if in range); others use buf
                inject = jnp.where(t < M, t, M - 1)
                x0 = mb[inject]
                x = jnp.where(idx == 0, x0, buf)
                y = stage_fn(params, x)
                # shift y to the next stage; last stage's y is the output
                nxt = jax.lax.ppermute(
                    y, stage_axis,
                    perm=[(i, i + 1) for i in range(S - 1)])
                out_t = t - (S - 1)
                take = (idx == S - 1) & (out_t >= 0) & (out_t < M)
                outs = jnp.where(
                    take,
                    jax.lax.dynamic_update_index_in_dim(
                        outs, y, jnp.clip(out_t, 0, M - 1), 0),
                    outs)
                return (nxt, outs), None

            # carries are stage-varying; the initial values come from the
            # replicated microbatch buffer -> promote explicitly (jax>=0.8
            # varying-manual-axes typing)
            _pvary = getattr(jax.lax, "pvary", None)
            if _pvary is None and hasattr(jax.lax, "pcast"):
                def _pvary(x, axes):                 # pragma: no cover
                    return jax.lax.pcast(x, axes, to="varying")
            if _pvary is None:
                # pre-varying-typing jax: replicated values are accepted as
                # scan carries directly, no promotion needed
                def _pvary(x, axes):
                    return x
            buf0 = _pvary(jnp.zeros_like(mb[0]), (stage_axis,))
            outs0 = _pvary(jnp.zeros_like(mb), (stage_axis,))
            (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                        jnp.arange(ticks))
            return outs[None]      # re-add the stage dim for the out spec

        fn = shard_map(
            per_device, mesh=mesh,
            in_specs=(P(stage_axis), P()),
            out_specs=P(stage_axis))
        outs = fn(stacked_params, microbatches)
        # every stage produced an [M,...] buffer; only the last is real
        return outs[-1]

    return run


def make_pp_mesh(n_stages: int):
    devs = jax.devices()[:n_stages]
    import numpy as np
    return Mesh(np.array(devs), ("stage",))
