"""Distribution: sharding policy, pipeline stages, elastic re-mesh."""

from .sharding import (MeshRules, default_rules, spec_for, param_shardings,
                       batch_shardings, batch_spec, cache_shardings,
                       replicated)
from .elastic import reshard_tree, elastic_pipeline

__all__ = ["MeshRules", "default_rules", "spec_for", "param_shardings",
           "batch_shardings", "batch_spec", "cache_shardings", "replicated",
           "reshard_tree", "elastic_pipeline"]
