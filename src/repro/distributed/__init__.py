"""Distribution: sharding policy, pipeline stages, elastic re-mesh, and
device-sharded execution of the batched analytics engine (shard_batch)."""

from .sharding import (MeshRules, default_rules, spec_for, param_shardings,
                       batch_shardings, batch_spec, cache_shardings,
                       replicated)
from .elastic import reshard_tree, elastic_pipeline
from .shard_batch import (CORPUS_AXIS, corpus_mesh, mesh_size, pad_corpora,
                          shard_batch, run_sharded)

__all__ = ["MeshRules", "default_rules", "spec_for", "param_shardings",
           "batch_shardings", "batch_spec", "cache_shardings", "replicated",
           "reshard_tree", "elastic_pipeline",
           "CORPUS_AXIS", "corpus_mesh", "mesh_size", "pad_corpora",
           "shard_batch", "run_sharded"]
