"""Device-sharded execution of :class:`~repro.core.batch.GrammarBatch`.

G-TADOC's scaling argument is that compressed-domain analytics saturate
massively parallel hardware once the dependent work is partitioned across
execution units.  Within one device the batched engine already does this
(vmapped traversals over the packed corpus axis N); this module is the next
rung: split the SAME packed arrays row-wise across every local device, so
one jitted program spans the whole mesh and the batch dimension — not the
model — is what scales.

How it works
------------
* :func:`corpus_mesh` builds a 1-D :class:`jax.sharding.Mesh` over the
  local devices with axis ``CORPUS_AXIS`` (``"corpus"``).  Fewer than two
  devices -> ``None``, and every entry point below falls back to the
  plain single-device pack — callers never branch on device count.
* :func:`pad_corpora` pads a corpus list up to a multiple of the shard
  count by repeating the smallest grammar in the list.  Reusing a real
  grammar keeps every padded dim (R_pad, E_pad, ...) unchanged, so any
  two sharded packs whose corpora land in the same buckets share one
  signature (and therefore one compiled program) regardless of how much
  padding each needed; the padding rows' results are computed and
  discarded (``GrammarBatch.n_real``).
* :func:`shard_batch` = pad + :meth:`GrammarBatch.build` +
  :meth:`GrammarBatch.shard`: the packed ``[N, ...]`` arrays are placed
  with ``NamedSharding(mesh, P(CORPUS_AXIS, ...))`` and the traversal
  engines in :mod:`repro.core.batch` notice ``gb.mesh`` and run through
  ``shard_map`` — each device's frontier ``while_loop`` stops when its own
  corpora finish, with no cross-device synchronization per round.
* :func:`run_sharded` is the one-call convenience: corpora in, per-corpus
  results out, bit-identical to ``run_batched`` on one device (asserted
  against the decompress-then-scan oracle in tests/_shard_worker.py).
  The retrieval kinds (``search_bm25`` / ``search_tfidf``) run through the
  same path: per-shard scoring + top-k, host merge, bit-identical
  rankings (repro/search/engine.py).

Why bit-identical is cheap to promise: corpus rows never interact in any
of the six analytics, each shard executes the very program a single device
would run on its row slice, and all counts are integers far below 2**24 —
float32 arithmetic is exact regardless of partitioning.

The serving layer (:mod:`repro.serving.analytics_server`) selects sharded
packs by group size (``shard_min_corpora``), and the async queue's
``target_shards`` knob lets large flushes split across devices instead of
serializing ``max_batch``-sized chunks.

CPU CI exercises real multi-device semantics via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (see
.github/workflows/ci.yml, job ``multidevice``).
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.batch import CORPUS_AXIS, GrammarBatch, run_batched
from repro.core.grammar import GrammarArrays

__all__ = ["CORPUS_AXIS", "corpus_mesh", "mesh_size", "pad_corpora",
           "shard_batch", "run_sharded"]


def corpus_mesh(devices: Optional[Sequence] = None,
                max_shards: Optional[int] = None) -> Optional[Mesh]:
    """1-D mesh over the local devices, axis ``CORPUS_AXIS``.

    Returns ``None`` when fewer than two devices are visible (the
    single-device fallback: callers treat ``mesh=None`` as "run the plain
    pack"), so importing this module never changes behaviour on a laptop
    or a single-chip host.  ``max_shards`` caps how many devices join the
    mesh (benchmarks use it to scale shard count).
    """
    devices = list(jax.devices() if devices is None else devices)
    if max_shards is not None:
        if max_shards < 1:
            raise ValueError("max_shards must be >= 1")
        devices = devices[:max_shards]
    if len(devices) < 2:
        return None
    return Mesh(np.array(devices), (CORPUS_AXIS,))


def mesh_size(mesh: Optional[Mesh]) -> int:
    """Device count of a corpus mesh (1 for the ``None`` fallback)."""
    return 1 if mesh is None else int(mesh.size)


def pad_corpora(gas: Sequence[GrammarArrays], multiple: int
                ) -> Tuple[List[GrammarArrays], int]:
    """Pad ``gas`` to a length divisible by ``multiple``.

    Padding repeats the smallest grammar (by rule count) already in the
    list: no padded dim grows (every max over the batch is unchanged), so
    sharded packs of same-bucket corpus compositions share a signature and
    a compiled program no matter how much padding each needed — and the
    padding rows are the cheapest rows any shard could traverse.  Returns
    ``(padded_list, n_real)``.
    """
    gas = list(gas)
    if not gas:
        raise ValueError("pad_corpora needs at least one corpus")
    if multiple < 1:
        raise ValueError("multiple must be >= 1")
    n_real = len(gas)
    if n_real % multiple:
        pad = min(gas, key=lambda ga: ga.num_rules)
        gas.extend([pad] * (multiple - n_real % multiple))
    return gas, n_real


def shard_batch(gas: Sequence[GrammarArrays], mesh: Optional[Mesh] = None,
                bucket: bool = True,
                epochs: Optional[Sequence[int]] = None) -> GrammarBatch:
    """Pack ``gas`` and shard the pack row-wise across ``mesh``.

    ``mesh=None`` auto-detects (:func:`corpus_mesh`); if that still yields
    no mesh (single device) the result is a plain unsharded pack — the
    transparent fallback the serving layer relies on.  N is padded to a
    mesh multiple (:func:`pad_corpora`); ragged shard counts (N not
    divisible by devices) and N < devices are both handled by that
    padding.

    ``epochs`` (one per corpus in ``gas``) stamps the pack for the ingest
    tier's staleness guard (:meth:`GrammarBatch.check_epochs`); padding
    rows inherit the epoch of the real grammar they duplicate.
    """
    gas = list(gas)
    if epochs is not None and len(epochs) != len(gas):
        raise ValueError(f"epochs stamps {len(epochs)} corpora but "
                         f"{len(gas)} were passed")
    if mesh is None:
        mesh = corpus_mesh()
    if mesh is None:
        return GrammarBatch.build(gas, bucket=bucket, epochs=epochs)
    padded, n_real = pad_corpora(gas, mesh_size(mesh))
    if epochs is not None:
        # padding repeats a grammar object from gas; match by identity
        # (GrammarArrays __eq__ compares arrays elementwise and would raise)
        epochs = tuple(epochs) + tuple(
            next(e for g, e in zip(gas, epochs) if g is pad)
            for pad in padded[n_real:])
    gb = GrammarBatch.build(padded, bucket=bucket, epochs=epochs)
    return gb.shard(mesh, n_real=n_real)


def run_sharded(gas: Sequence[GrammarArrays], kind: str,
                mesh: Optional[Mesh] = None, method: str = "frontier",
                backend: str = "jnp", l: int = 3,
                bucket: bool = True, terms=None, k: int = 10,
                predicate=None, agg=None) -> List:
    """One-call sharded analytics: pad, pack, shard, run, unpad.

    Results align with ``gas`` and are bit-identical to
    ``run_batched(GrammarBatch.build(gas), ...)`` on a single device.
    Besides the six analytics this also serves the retrieval kinds
    (``search_bm25`` / ``search_tfidf``, parameterized by ``terms``/``k``)
    through :func:`repro.search.engine.batched_search` — each shard ranks
    its own corpus rows and the top-k merge happens on host — and the
    query-operator kinds (``filter_count`` / ``agg_terms`` /
    ``phrase_count``, parameterized by ``predicate``/``terms``/``agg``)
    through :func:`repro.query.engine.run_batched_query`.  For recurring
    traffic prefer building the pack once via :func:`shard_batch` (or the
    serving layer's pack cache) — this convenience re-packs per call.
    """
    gb = shard_batch(gas, mesh=mesh, bucket=bucket)
    if kind in ("search_bm25", "search_tfidf"):
        # lazy import: repro.search sits above this module in the layering
        from repro.search.engine import batched_search
        from repro.search.scoring import KIND_SCHEME
        return batched_search(gb, terms, k=k, scheme=KIND_SCHEME[kind],
                              method=method)
    if kind in ("filter_count", "agg_terms", "phrase_count"):
        # lazy import: repro.query sits above this module in the layering
        from repro.query.engine import run_batched_query
        return run_batched_query(gb, kind, predicate=predicate,
                                 terms=terms, agg=agg, method=method)
    return run_batched(gb, kind, method=method, backend=backend, l=l)
