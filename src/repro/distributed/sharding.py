"""Logical-axis -> mesh sharding policy (DP / FSDP / TP / EP / SP).

Model code tags every parameter dim with a logical axis name
(models/layers.py Boxed).  This module maps those names onto the production
mesh:

  * TP   — "heads"/"kv_heads"/"ffn"/"vocab"/"expert"/"ssm_*" -> "model"
  * FSDP — "embed" (the d_model dim every matrix has) -> fsdp axes
           ("data", or ("pod","data") for cross-pod ZeRO-3)
  * DP   — batch dims of activations/inputs -> ("pod","data")
  * SP   — decode caches: kv-heads -> "model" when divisible, otherwise the
           *sequence* dim shards over "model" (context parallelism; the
           attention reduction over KV becomes a psum GSPMD inserts)

Every mapping is divisibility-checked against the mesh; a dim that does not
divide falls back to replication (never a compile error).  One mesh axis is
never assigned twice in a single spec (first logical dim wins).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisAssign = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """logical axis name -> mesh axis (or tuple of mesh axes)."""
    rules: Dict[str, AxisAssign]
    batch_axes: Tuple[str, ...] = ("pod", "data")

    def assign(self, name: Optional[str]) -> AxisAssign:
        if name is None:
            return None
        return self.rules.get(name)


def default_rules(mesh: Mesh, fsdp_over_pod: bool = False) -> MeshRules:
    has_pod = "pod" in mesh.axis_names
    fsdp: AxisAssign = (("pod", "data") if (fsdp_over_pod and has_pod)
                        else "data")
    batch = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return MeshRules(rules={
        "vocab": "model",
        "ffn": "model",
        "heads": "model",
        "kv_heads": "model",
        "expert": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "embed": fsdp,
        "layers": None,
        "head_dim": None,
    }, batch_axes=batch)


def _axis_size(mesh: Mesh, assign: AxisAssign) -> int:
    if assign is None:
        return 1
    if isinstance(assign, str):
        return mesh.shape[assign]
    return int(np.prod([mesh.shape[a] for a in assign]))


def spec_for(axes: Sequence[Optional[str]], shape: Sequence[int],
             mesh: Mesh, rules: MeshRules) -> P:
    """PartitionSpec for one array given its logical axes + shape."""
    used: set = set()
    parts = []
    for name, dim in zip(axes, shape):
        assign = rules.assign(name)
        if assign is None:
            parts.append(None)
            continue
        mesh_axes = (assign,) if isinstance(assign, str) else tuple(assign)
        if any(a in used for a in mesh_axes):
            parts.append(None)
            continue
        size = _axis_size(mesh, assign)
        if size <= 1 or dim % size != 0:
            parts.append(None)
            continue
        used.update(mesh_axes)
        parts.append(assign)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_shardings(axes_tree, shape_tree, mesh: Mesh, rules: MeshRules):
    """NamedSharding tree for a param pytree.

    ``axes_tree``: logical axes per leaf (from unbox); ``shape_tree``:
    matching arrays / ShapeDtypeStructs."""
    def one(axes, arr):
        return NamedSharding(mesh, spec_for(axes, arr.shape, mesh, rules))
    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def batch_spec(rules: MeshRules, ndim: int = 2) -> P:
    """[B, S, ...] activations/inputs: batch over (pod, data)."""
    ba = rules.batch_axes
    assign = ba[0] if len(ba) == 1 else tuple(ba)
    return P(assign, *([None] * (ndim - 1)))


def batch_shardings(batch_tree, mesh: Mesh, rules: MeshRules):
    def one(arr):
        b = arr.shape[0]
        size = _axis_size(mesh, tuple(rules.batch_axes)
                          if len(rules.batch_axes) > 1 else rules.batch_axes[0])
        if size > 1 and b % size == 0:
            return NamedSharding(mesh, batch_spec(rules, arr.ndim))
        return NamedSharding(mesh, P())
    return jax.tree.map(one, batch_tree)


# ----------------------------------------------------------- decode cache --
def cache_shardings(cfg, cache_tree, mesh: Mesh, rules: MeshRules):
    """Sharding for the decode cache pytree (models.init_cache layout).

    KV entries  [repeats, B, maxlen, Hkv, hd]:
        B -> batch axes; Hkv -> model if divisible, else maxlen -> model
        (and for batch==1, maxlen spreads over *all* non-used axes: the
        long-context single-stream case).
    SSM state h [repeats, B, H, P, N]: B -> batch, H -> model.
    conv state  [repeats, B, K-1, conv_dim]: B -> batch, conv_dim -> model.
    cross K/V   [layers, B, T_enc, Hkv, hd]: like KV.
    """
    model_sz = mesh.shape.get("model", 1)
    batch_assign = (tuple(rules.batch_axes) if len(rules.batch_axes) > 1
                    else rules.batch_axes[0])
    batch_sz = _axis_size(mesh, batch_assign)

    def kv_spec(shape):
        _, B, L, Hkv, _ = shape
        b_ax = batch_assign if (batch_sz > 1 and B % batch_sz == 0) else None
        if Hkv % model_sz == 0:
            return P(None, b_ax, None, "model", None)
        if B == 1 and b_ax is not None:
            # single stream: spread sequence over everything available
            all_ax = (tuple(rules.batch_axes) + ("model",))
            if L % _axis_size(mesh, all_ax) == 0:
                return P(None, None, all_ax, None, None)
        if L % model_sz == 0:
            return P(None, b_ax, "model", None, None)
        return P(None, b_ax)

    def one(path, arr):
        keys = [str(getattr(p, "key", "")) for p in path]
        shape = arr.shape
        if "pos" in keys:
            return NamedSharding(mesh, P())
        if keys and keys[-1] in ("k", "v") or "cross_k" in keys or \
                "cross_v" in keys:
            return NamedSharding(mesh, kv_spec(shape))
        if keys and keys[-1] == "h":                 # [rep, B, H, P, N]
            _, B, H, _, _ = shape
            b_ax = batch_assign if (batch_sz > 1 and B % batch_sz == 0) else None
            m_ax = "model" if H % model_sz == 0 else None
            return NamedSharding(mesh, P(None, b_ax, m_ax, None, None))
        if keys and keys[-1] == "conv":              # [rep, B, K-1, convd]
            _, B, _, cd = shape
            b_ax = batch_assign if (batch_sz > 1 and B % batch_sz == 0) else None
            m_ax = "model" if cd % model_sz == 0 else None
            return NamedSharding(mesh, P(None, b_ax, None, m_ax))
        return NamedSharding(mesh, P())

    flat, treedef = jax.tree.flatten_with_path(cache_tree)
    return jax.tree.unflatten(treedef, [one(p, a) for p, a in flat])


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
