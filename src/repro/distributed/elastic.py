"""Elastic scaling: resume a job on a different device count.

Two pieces make the framework elastic:

1. **State re-sharding** — checkpoints are topology-free (full arrays +
   manifest, checkpoint/ckpt.py), so resuming on a new mesh is just
   ``device_put`` with the new rules: ``reshard_tree`` below.
2. **Data re-partitioning** — the pipeline is stateless-deterministic in
   (seed, step) and takes (shard, num_shards) at construction
   (data/pipeline.py), so a new data-parallel degree re-partitions the same
   global stream with no drift: ``elastic_pipeline``.

The only constraint is divisibility (global_batch % new_dp == 0); the
driver validates and refuses otherwise (a fleet controller would pick the
nearest valid degree).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh

from repro.data import BatchPipeline, CompressedCorpus
from .sharding import MeshRules, param_shardings, default_rules


def reshard_tree(tree: Any, axes_tree: Any, mesh: Mesh,
                 rules: Optional[MeshRules] = None) -> Any:
    """Place a (restored) pytree onto a new mesh under the sharding rules."""
    rules = rules or default_rules(mesh)
    sh = param_shardings(axes_tree, tree, mesh, rules)
    return jax.tree.map(jax.device_put, tree, sh)


def elastic_pipeline(corpus: CompressedCorpus, *, global_batch: int,
                     seq_len: int, seed: int, resume_step: int,
                     shard: int, num_shards: int) -> BatchPipeline:
    if global_batch % num_shards:
        raise ValueError(
            f"elastic resize invalid: global_batch {global_batch} "
            f"not divisible by new dp degree {num_shards}")
    return BatchPipeline(corpus, global_batch=global_batch, seq_len=seq_len,
                         seed=seed, shard=shard, num_shards=num_shards,
                         start_step=resume_step, prefetch=0)
