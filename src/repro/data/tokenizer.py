"""Word-level tokenizer (TADOC's dictionary conversion, paper §II-A Fig 1b).

TADOC encodes words as integers via a dictionary before grammar inference.
This tokenizer is that dictionary: split on whitespace/punctuation, map each
distinct word to an id.  ``from_tadoc_counts`` builds a frequency-ordered
vocab from counts produced by the compressed-domain ``word_count`` — the
framework's "vocab from compressed data" path.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List

import numpy as np

_SPLIT = re.compile(r"\w+|[^\w\s]", re.UNICODE)

UNK = 0


@dataclass
class Tokenizer:
    word_to_id: Dict[str, int] = field(default_factory=lambda: {"<unk>": UNK})
    id_to_word: List[str] = field(default_factory=lambda: ["<unk>"])
    frozen: bool = False

    @property
    def vocab_size(self) -> int:
        return len(self.id_to_word)

    def add(self, word: str) -> int:
        i = self.word_to_id.get(word)
        if i is None:
            if self.frozen:
                return UNK
            i = len(self.id_to_word)
            self.word_to_id[word] = i
            self.id_to_word.append(word)
        return i

    def encode(self, text: str) -> np.ndarray:
        return np.array([self.add(w) for w in _SPLIT.findall(text)],
                        dtype=np.int64)

    def decode(self, ids: Iterable[int]) -> str:
        return " ".join(self.id_to_word[int(i)] for i in ids)

    # ------------------------------------------------------------------ --
    @classmethod
    def build(cls, texts: Iterable[str]) -> "Tokenizer":
        tok = cls()
        for t in texts:
            tok.encode(t)
        tok.frozen = True
        return tok

    @classmethod
    def from_tadoc_counts(cls, words: List[str], counts: np.ndarray,
                          max_vocab: int | None = None) -> "Tokenizer":
        """Frequency-ordered vocab from compressed-domain word counts."""
        order = np.argsort(-np.asarray(counts), kind="stable")
        if max_vocab is not None:
            order = order[: max_vocab - 1]
        tok = cls()
        for i in order:
            tok.add(words[int(i)])
        tok.frozen = True
        return tok

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"words": self.id_to_word}, f)

    @classmethod
    def load(cls, path: str) -> "Tokenizer":
        with open(path) as f:
            words = json.load(f)["words"]
        tok = cls(word_to_id={w: i for i, w in enumerate(words)},
                  id_to_word=list(words), frozen=True)
        return tok
