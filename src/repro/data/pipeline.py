"""Deterministic sharded batch pipeline over the compressed store.

Design constraints for 1000+-node fleets:

* **Stateless sampling** — the content of batch ``step`` is a pure function
  of ``(seed, step, shard)``.  Restart after a failure resumes *exactly*
  (no data-order drift), and elastic re-sharding (changing data-parallel
  degree) re-partitions the same global stream deterministically.
* **No decompression** — windows are expanded straight out of the grammar
  (``expand_range``); the raw corpus never materializes.
* **Host prefetch** — a background thread keeps ``prefetch`` batches ahead,
  overlapping grammar expansion with device compute.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np

from .store import CompressedCorpus


@dataclass(frozen=True)
class PipelineState:
    """Everything needed to resume the stream: goes into checkpoints."""
    seed: int
    step: int
    global_batch: int
    seq_len: int

    def advance(self, n: int = 1) -> "PipelineState":
        return PipelineState(self.seed, self.step + n, self.global_batch,
                             self.seq_len)


class BatchPipeline:
    """Yields (tokens, labels) int32 [local_batch, seq_len] shards.

    ``shard``/``num_shards`` split the global batch across data-parallel
    hosts; every shard draws from the same deterministic global stream.
    """

    def __init__(self, corpus: CompressedCorpus, *, global_batch: int,
                 seq_len: int, seed: int = 0, shard: int = 0,
                 num_shards: int = 1, start_step: int = 0,
                 prefetch: int = 2) -> None:
        if global_batch % num_shards:
            raise ValueError("global_batch must divide by num_shards")
        self.corpus = corpus
        self.state = PipelineState(seed, start_step, global_batch, seq_len)
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = global_batch // num_shards
        self.prefetch = prefetch
        self._q: "queue.Queue[Tuple[int, np.ndarray, np.ndarray]]" = \
            queue.Queue(maxsize=max(prefetch, 1))
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # --------------------------------------------------------- sampling --
    def _sample_batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        st = self.state
        rng = np.random.default_rng(
            np.random.SeedSequence([st.seed, step]))
        total = self.corpus.total_tokens
        need = st.seq_len + 1
        # global sample offsets for the WHOLE batch; take our shard's rows
        # (identical across shards -> no communication needed to agree)
        n_files = len(self.corpus.file_lens)
        probs = self.corpus.file_lens / max(total, 1)
        files = rng.choice(n_files, size=st.global_batch, p=probs)
        toks = np.zeros((st.global_batch, need), np.int64)
        for i, f in enumerate(files):
            flen = int(self.corpus.file_lens[f])
            if flen <= need:
                w = self.corpus.window(int(f), 0, flen)
                reps = int(np.ceil(need / max(len(w), 1)))
                toks[i] = np.tile(w, reps)[:need]
            else:
                off = int(rng.integers(0, flen - need))
                toks[i] = self.corpus.window(int(f), off, need)
        lo = self.shard * self.local_batch
        hi = lo + self.local_batch
        x = toks[lo:hi, :-1].astype(np.int32)
        y = toks[lo:hi, 1:].astype(np.int32)
        return x, y

    # --------------------------------------------------------- iterator --
    def _worker(self) -> None:
        step = self.state.step
        while not self._stop.is_set():
            batch = self._sample_batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, *batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        if self.prefetch > 0:
            self._stop.clear()
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
            try:
                while True:
                    step, x, y = self._q.get()
                    self.state = PipelineState(
                        self.state.seed, step + 1, self.state.global_batch,
                        self.state.seq_len)
                    yield x, y
            finally:
                self._stop.set()
        else:
            while True:
                x, y = self._sample_batch(self.state.step)
                self.state = self.state.advance()
                yield x, y

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """Pure access for tests / exact-resume verification."""
        return self._sample_batch(step)

    def close(self) -> None:
        self._stop.set()
