"""Synthetic corpora shaped like the paper's Table II datasets.

Real text compresses under Sequitur because of repeated phrases (boilerplate
headers, quoted passages, templated markup).  The generators here draw
Zipfian words and inject repeated phrases/motifs at controllable rates so
compression ratio, rule count and DAG depth land in realistic ranges.

``TABLE2`` mirrors the paper's datasets A–E *scaled down* (CPU container):
same file-count/size relationships, 1e3–1e5 tokens instead of GBs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass(frozen=True)
class CorpusSpec:
    name: str
    n_files: int
    tokens_per_file: int
    vocab: int
    phrase_rate: float        # fraction of text drawn from repeated phrases
    n_phrases: int
    phrase_len: int
    seed: int = 0


# Scaled-down analogues of Table II (A: many small files; B: few big files;
# C: large; D: tiny single file; E: one big file).
TABLE2 = {
    "A": CorpusSpec("A", n_files=96, tokens_per_file=220, vocab=1200,
                    phrase_rate=0.55, n_phrases=40, phrase_len=8),
    "B": CorpusSpec("B", n_files=4, tokens_per_file=6000, vocab=2500,
                    phrase_rate=0.6, n_phrases=60, phrase_len=10),
    "C": CorpusSpec("C", n_files=24, tokens_per_file=4000, vocab=4000,
                    phrase_rate=0.6, n_phrases=80, phrase_len=10),
    "D": CorpusSpec("D", n_files=1, tokens_per_file=1500, vocab=400,
                    phrase_rate=0.5, n_phrases=20, phrase_len=6),
    "E": CorpusSpec("E", n_files=1, tokens_per_file=12000, vocab=3000,
                    phrase_rate=0.6, n_phrases=70, phrase_len=10),
}


def zipf_words(rng: np.random.Generator, n: int, vocab: int,
               a: float = 1.3) -> np.ndarray:
    """Zipf-distributed word ids clipped to the vocab."""
    w = rng.zipf(a, size=n)
    return np.minimum(w - 1, vocab - 1).astype(np.int64)


def make_corpus(spec: CorpusSpec) -> List[np.ndarray]:
    rng = np.random.default_rng(spec.seed)
    phrases = [zipf_words(rng, spec.phrase_len, spec.vocab)
               for _ in range(spec.n_phrases)]
    files: List[np.ndarray] = []
    for _ in range(spec.n_files):
        parts: List[np.ndarray] = []
        total = 0
        while total < spec.tokens_per_file:
            if rng.random() < spec.phrase_rate:
                p = phrases[int(rng.integers(spec.n_phrases))]
                # occasionally a multi-phrase motif (nested repetition)
                if rng.random() < 0.3:
                    p = np.concatenate(
                        [p, phrases[int(rng.integers(spec.n_phrases))]])
            else:
                p = zipf_words(rng, int(rng.integers(3, 15)), spec.vocab)
            parts.append(p)
            total += len(p)
        files.append(np.concatenate(parts)[: spec.tokens_per_file])
    return files


def make_table2_corpus(name: str) -> List[np.ndarray]:
    return make_corpus(TABLE2[name])
