"""On-disk compressed corpus: grammar arrays + metadata, single .npz.

The corpus is stored *compressed* (the grammar), never as raw tokens.  The
training pipeline and the analytics engine both read this store; analytics
never decompress, batches are produced by window expansion (grammar.py
``expand_range``).

Ingestion tier: a corpus is *mutable* through :meth:`CompressedCorpus.
append_files` — Sequitur is online, so appended files extend the live
grammar without recompressing what is already stored, and the result is
bit-identical to a from-scratch build of the concatenated file list
(tests/test_ingest.py).  Every mutation bumps the monotonically-increasing
``epoch``; every derived memo on the store (traversal weights, the search
index) is stamped with the epoch it was computed at and self-invalidates
on mismatch, and downstream pack caches (serving/analytics_server.py,
core/batch.py ``GrammarBatch.check_epochs``) use the same stamp so a stale
grammar can never be served.
"""

from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import GrammarArrays, IncrementalSequitur, flatten
from repro.core.grammar import StaleGrammarError, expand_range
from repro.core.traversal import per_file_weights as _per_file_weights
from repro.core.traversal import top_down_weights as _top_down_weights
from repro.obs import global_registry

__all__ = ["CompressedCorpus", "StaleGrammarError"]


def _count_memo(result: str) -> None:
    """Memo traffic on the epoch-stamped derived-artifact cache: ``hit``
    (stamp current), ``stale`` (entry predates an append — recomputed, the
    belt-and-braces invalidation firing), ``miss`` (first build)."""
    global_registry().counter(
        "repro_store_memo_lookups_total",
        "epoch-stamped memo lookups on CompressedCorpus (weights, "
        "search index) by result", ("result",)).labels(result).inc()


_META_FIELDS = ("vocab_size", "num_files", "num_rules", "num_levels")
# Every GrammarArrays field that is not scalar metadata is a numpy array.
# Selecting by exclusion is robust where the old string comparison
# (``f.type == "np.ndarray"``) was not: under `from __future__ import
# annotations` styles, aliased imports, or real type objects the textual
# form changes and arrays would silently vanish from save/load
# (tests/test_data.py round-trips every field to keep this honest).
_ARRAY_FIELDS = tuple(f.name for f in dataclasses.fields(GrammarArrays)
                      if f.name not in _META_FIELDS)


@dataclass
class CompressedCorpus:
    ga: GrammarArrays
    file_starts: np.ndarray     # [F] global terminal offset of each file
    file_lens: np.ndarray       # [F]
    # ingest-tier mutation counter: bumped by every append_files.  All
    # derived memos — the weight/index cache below, server pack caches,
    # GrammarBatch plans — carry the epoch they were computed at; a
    # mismatch means the grammar changed underneath them.
    epoch: int = 0
    # memoized traversal weights, entries stored as (epoch, value): the
    # serving layer reuses one traversal across any number of queries, and
    # the epoch stamp makes a post-append stale hit structurally
    # impossible (checked on every read, not just cleared on append)
    _weights_cache: Dict = field(default_factory=dict, repr=False,
                                 compare=False)
    # live Sequitur state backing append_files.  build() keeps it; a
    # corpus loaded from disk reconstructs it lazily on first append by
    # replaying the stored stream (Sequitur is online, so the replayed
    # state is bit-identical to the one the original build held).
    _sq: Optional[IncrementalSequitur] = field(default=None, repr=False,
                                               compare=False)

    # ------------------------------------------------------------ build --
    @classmethod
    def build(cls, files: List[np.ndarray], vocab_size: int
              ) -> "CompressedCorpus":
        inc = IncrementalSequitur(vocab_size)
        inc.append_files(files)
        ga = flatten(inc.export(), vocab_size, inc.n_files)
        lens = np.array([len(f) for f in files], np.int64)
        # +1 per preceding splitter
        starts = np.zeros(inc.n_files, np.int64)
        np.cumsum(lens[:-1] + 1, out=starts[1:])
        return cls(ga=ga, file_starts=starts, file_lens=lens, _sq=inc)

    # ----------------------------------------------------------- ingest --
    def _live_sequitur(self) -> IncrementalSequitur:
        """The live compressor state.  After :meth:`load` (no state on
        disk) it is rebuilt by replaying every stored file through a fresh
        :class:`IncrementalSequitur` — the same operation sequence the
        original build ran, so the reconstructed state (and any grammar
        appended onto it) stays bit-identical to never having snapshotted
        at all.  Cost: one full decompression + recompression; paid once,
        only by stores that resume ingesting after a restore."""
        if self._sq is None:
            inc = IncrementalSequitur(int(self.ga.vocab_size))
            for fid in range(len(self.file_lens)):
                inc.append_file(self.window(fid, 0,
                                            int(self.file_lens[fid])))
            self._sq = inc
        return self._sq

    def append_files(self, files: Sequence[np.ndarray]
                     ) -> "CompressedCorpus":
        """Absorb ``files`` into the live grammar (incremental Sequitur).

        New files are appended to the root rule behind fresh unique
        splitter symbols; digram uniqueness and rule utility are
        maintained online by the same machinery the from-scratch build
        runs, so the re-exported arrays are bit-identical to
        ``CompressedCorpus.build(old_files + files)``.  Bumps ``epoch``
        (invalidating every derived memo) and returns ``self``.  An empty
        ``files`` list is a no-op and does NOT bump the epoch.
        """
        files = [np.asarray(f, np.int64) for f in files]
        if not files:
            return self
        inc = self._live_sequitur()
        inc.append_files(files)
        self.ga = flatten(inc.export(), inc.vocab_size, inc.n_files)
        lens = np.array([len(f) for f in files], np.int64)
        prev_end = (int(self.file_starts[-1]) + int(self.file_lens[-1]) + 1
                    if len(self.file_lens) else 0)
        starts = prev_end + np.concatenate(
            [np.zeros(1, np.int64), np.cumsum(lens[:-1] + 1)])
        self.file_starts = np.concatenate(
            [self.file_starts.astype(np.int64), starts])
        self.file_lens = np.concatenate(
            [self.file_lens.astype(np.int64), lens])
        self.epoch += 1
        self._weights_cache.clear()
        reg = global_registry()
        reg.counter("repro_store_appends_total",
                    "append_files epoch bumps").inc()
        reg.counter("repro_store_append_files_total",
                    "files absorbed by append_files").inc(len(files))
        return self

    def check_epoch(self, epoch: int) -> None:
        """Raise :class:`StaleGrammarError` unless ``epoch`` is current —
        the guard derived artifacts (packs, plans, external indexes) call
        before serving on behalf of this corpus."""
        if int(epoch) != self.epoch:
            raise StaleGrammarError(
                f"corpus is at epoch {self.epoch} but the derived artifact "
                f"was built at epoch {int(epoch)} — rebuild it "
                f"(append_files mutated the grammar)")

    # --------------------------------------------------------------- io --
    def save(self, path: str) -> None:
        arrays = {name: getattr(self.ga, name) for name in _ARRAY_FIELDS}
        arrays["file_starts"] = self.file_starts
        arrays["file_lens"] = self.file_lens
        meta = {name: int(getattr(self.ga, name)) for name in _META_FIELDS}
        # corpus-level (non-GrammarArrays) metadata rides the same JSON
        # blob under a reserved key: a snapshot taken mid-ingest restores
        # at the same epoch, so artifacts derived pre-snapshot stay
        # distinguishable from post-restore ones
        meta["_corpus_epoch"] = int(self.epoch)
        tmp = path + ".tmp.npz"
        np.savez_compressed(tmp, _meta=json.dumps(meta), **arrays)
        os.replace(tmp, path)  # atomic publish (checkpointing convention)

    @classmethod
    def load(cls, path: str) -> "CompressedCorpus":
        z = np.load(path, allow_pickle=False)
        meta = json.loads(str(z["_meta"]))
        epoch = int(meta.pop("_corpus_epoch", 0))   # pre-ingest snapshots
        kw = {name: z[name] for name in _ARRAY_FIELDS}
        kw.update(meta)
        ga = GrammarArrays(**kw)
        return cls(ga=ga, file_starts=z["file_starts"],
                   file_lens=z["file_lens"], epoch=epoch)

    # ------------------------------------------------------------ reads --
    @property
    def total_tokens(self) -> int:
        return int(self.file_lens.sum())

    def window(self, file_id: int, offset: int, length: int) -> np.ndarray:
        """Expand `length` word tokens of file `file_id` from `offset`,
        clamped to the file end (no decompression outside the window).

        ``offset`` must lie inside the file (``0 <= offset <= file_len``;
        the == edge yields an empty window).  A negative offset would
        silently expand the *previous* file's tokens and one past the end
        would compute a negative length — both raise instead.
        """
        if not 0 <= int(file_id) < len(self.file_lens):
            raise IndexError(f"file_id {file_id} out of range "
                             f"[0, {len(self.file_lens)})")
        offset, length = int(offset), int(length)
        if length < 0:
            raise ValueError(f"window length must be >= 0, got {length}")
        flen = int(self.file_lens[file_id])
        if not 0 <= offset <= flen:
            raise ValueError(f"offset {offset} outside file {file_id} "
                             f"(length {flen})")
        start = int(self.file_starts[file_id]) + offset
        return expand_range(self.ga, start, min(length, flen - offset))

    def global_window(self, offset: int, length: int) -> np.ndarray:
        """Expand from the concatenated corpus stream (splitters included —
        callers use them as document separators).  ``offset`` must lie
        inside the stream; ``length`` is clamped to the stream end."""
        offset, length = int(offset), int(length)
        if length < 0:
            raise ValueError(f"window length must be >= 0, got {length}")
        total = int(self.ga.exp_len[0])     # root expansion: whole stream
        if not 0 <= offset <= total:
            raise ValueError(f"offset {offset} outside the corpus stream "
                             f"(length {total})")
        return expand_range(self.ga, offset, min(length, total - offset))

    # ------------------------------------------------- memoized traversal --
    def _memo(self, key, build: Callable[[], object]):
        """Epoch-stamped memo: entries are ``(epoch, value)`` and a hit
        only counts when its stamp matches the current epoch.  A stale
        entry (the grammar absorbed appended files after it was computed)
        is recomputed in place — it can never be returned, even if a bug
        elsewhere forgot to clear the cache on append
        (tests/test_ingest.py plants a poisoned stale entry to prove it)."""
        hit = self._weights_cache.get(key)
        if hit is not None and hit[0] == self.epoch:
            _count_memo("hit")
            return hit[1]
        _count_memo("stale" if hit is not None else "miss")
        value = build()
        self._weights_cache[key] = (self.epoch, value)
        return value

    def top_down_weights(self, method: str = "frontier"):
        """Per-rule occurrence weights, memoized (analytics reuse them)."""
        return self._memo(("top_down", method),
                          lambda: _top_down_weights(self.ga, method=method))

    def per_file_weights(self, method: str = "frontier"):
        """Per-(rule, file) occurrence weights, memoized."""
        return self._memo(("per_file", method),
                          lambda: _per_file_weights(self.ga, method=method))

    def search_index(self, method: str = "frontier"):
        """Per-corpus retrieval index (tf / doc lengths / doc frequencies /
        BM25 normalizer), memoized like the traversal weights — it shares
        the memoized per-file traversal with the per-file analytics.  Lazy
        import: the search package sits above the store in the layering."""
        from repro.search.index import base_method, build_search_index
        return self._memo(("search_index", base_method(method)),
                          lambda: build_search_index(self, method=method))

    def cached_weight_keys(self):
        return tuple(sorted(self._weights_cache))

    def clear_weight_cache(self) -> None:
        self._weights_cache.clear()

    def stats(self) -> dict:
        return {
            "epoch": int(self.epoch),
            "files": int(self.ga.num_files),
            "rules": int(self.ga.num_rules),
            "vocab": int(self.ga.vocab_size),
            "tokens": self.total_tokens,
            "grammar_symbols": int(self.ga.body.shape[0]),
            "compression_ratio": float(self.ga.compression_ratio()),
            "dag_depth": int(self.ga.num_levels),
        }
