"""On-disk compressed corpus: grammar arrays + metadata, single .npz.

The corpus is stored *compressed* (the grammar), never as raw tokens.  The
training pipeline and the analytics engine both read this store; analytics
never decompress, batches are produced by window expansion (grammar.py
``expand_range``).
"""

from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core import GrammarArrays, compress_files, flatten
from repro.core.grammar import expand_range
from repro.core.traversal import per_file_weights as _per_file_weights
from repro.core.traversal import top_down_weights as _top_down_weights


_META_FIELDS = ("vocab_size", "num_files", "num_rules", "num_levels")
# Every GrammarArrays field that is not scalar metadata is a numpy array.
# Selecting by exclusion is robust where the old string comparison
# (``f.type == "np.ndarray"``) was not: under `from __future__ import
# annotations` styles, aliased imports, or real type objects the textual
# form changes and arrays would silently vanish from save/load
# (tests/test_data.py round-trips every field to keep this honest).
_ARRAY_FIELDS = tuple(f.name for f in dataclasses.fields(GrammarArrays)
                      if f.name not in _META_FIELDS)


@dataclass
class CompressedCorpus:
    ga: GrammarArrays
    file_starts: np.ndarray     # [F] global terminal offset of each file
    file_lens: np.ndarray       # [F]
    # memoized traversal weights: corpora are immutable once built, so the
    # serving layer reuses one traversal across any number of queries
    _weights_cache: Dict = field(default_factory=dict, repr=False,
                                 compare=False)

    # ------------------------------------------------------------ build --
    @classmethod
    def build(cls, files: List[np.ndarray], vocab_size: int
              ) -> "CompressedCorpus":
        g, nf = compress_files(files, vocab_size)
        ga = flatten(g, vocab_size, nf)
        lens = np.array([len(f) for f in files], np.int64)
        # +1 per preceding splitter
        starts = np.zeros(nf, np.int64)
        np.cumsum(lens[:-1] + 1, out=starts[1:])
        return cls(ga=ga, file_starts=starts, file_lens=lens)

    # --------------------------------------------------------------- io --
    def save(self, path: str) -> None:
        arrays = {name: getattr(self.ga, name) for name in _ARRAY_FIELDS}
        arrays["file_starts"] = self.file_starts
        arrays["file_lens"] = self.file_lens
        meta = {name: int(getattr(self.ga, name)) for name in _META_FIELDS}
        tmp = path + ".tmp.npz"
        np.savez_compressed(tmp, _meta=json.dumps(meta), **arrays)
        os.replace(tmp, path)  # atomic publish (checkpointing convention)

    @classmethod
    def load(cls, path: str) -> "CompressedCorpus":
        z = np.load(path, allow_pickle=False)
        meta = json.loads(str(z["_meta"]))
        kw = {name: z[name] for name in _ARRAY_FIELDS}
        kw.update(meta)
        ga = GrammarArrays(**kw)
        return cls(ga=ga, file_starts=z["file_starts"],
                   file_lens=z["file_lens"])

    # ------------------------------------------------------------ reads --
    @property
    def total_tokens(self) -> int:
        return int(self.file_lens.sum())

    def window(self, file_id: int, offset: int, length: int) -> np.ndarray:
        """Expand `length` word tokens of file `file_id` from `offset`,
        clamped to the file end (no decompression outside the window).

        ``offset`` must lie inside the file (``0 <= offset <= file_len``;
        the == edge yields an empty window).  A negative offset would
        silently expand the *previous* file's tokens and one past the end
        would compute a negative length — both raise instead.
        """
        if not 0 <= int(file_id) < len(self.file_lens):
            raise IndexError(f"file_id {file_id} out of range "
                             f"[0, {len(self.file_lens)})")
        offset, length = int(offset), int(length)
        if length < 0:
            raise ValueError(f"window length must be >= 0, got {length}")
        flen = int(self.file_lens[file_id])
        if not 0 <= offset <= flen:
            raise ValueError(f"offset {offset} outside file {file_id} "
                             f"(length {flen})")
        start = int(self.file_starts[file_id]) + offset
        return expand_range(self.ga, start, min(length, flen - offset))

    def global_window(self, offset: int, length: int) -> np.ndarray:
        """Expand from the concatenated corpus stream (splitters included —
        callers use them as document separators).  ``offset`` must lie
        inside the stream; ``length`` is clamped to the stream end."""
        offset, length = int(offset), int(length)
        if length < 0:
            raise ValueError(f"window length must be >= 0, got {length}")
        total = int(self.ga.exp_len[0])     # root expansion: whole stream
        if not 0 <= offset <= total:
            raise ValueError(f"offset {offset} outside the corpus stream "
                             f"(length {total})")
        return expand_range(self.ga, offset, min(length, total - offset))

    # ------------------------------------------------- memoized traversal --
    def top_down_weights(self, method: str = "frontier"):
        """Per-rule occurrence weights, memoized (analytics reuse them)."""
        key = ("top_down", method)
        if key not in self._weights_cache:
            self._weights_cache[key] = _top_down_weights(self.ga,
                                                         method=method)
        return self._weights_cache[key]

    def per_file_weights(self, method: str = "frontier"):
        """Per-(rule, file) occurrence weights, memoized."""
        key = ("per_file", method)
        if key not in self._weights_cache:
            self._weights_cache[key] = _per_file_weights(self.ga,
                                                         method=method)
        return self._weights_cache[key]

    def search_index(self, method: str = "frontier"):
        """Per-corpus retrieval index (tf / doc lengths / doc frequencies /
        BM25 normalizer), memoized like the traversal weights — it shares
        the memoized per-file traversal with the per-file analytics.  Lazy
        import: the search package sits above the store in the layering."""
        from repro.search.index import base_method, build_search_index
        key = ("search_index", base_method(method))
        if key not in self._weights_cache:
            self._weights_cache[key] = build_search_index(self,
                                                          method=method)
        return self._weights_cache[key]

    def cached_weight_keys(self):
        return tuple(sorted(self._weights_cache))

    def clear_weight_cache(self) -> None:
        self._weights_cache.clear()

    def stats(self) -> dict:
        return {
            "files": int(self.ga.num_files),
            "rules": int(self.ga.num_rules),
            "vocab": int(self.ga.vocab_size),
            "tokens": self.total_tokens,
            "grammar_symbols": int(self.ga.body.shape[0]),
            "compression_ratio": float(self.ga.compression_ratio()),
            "dag_depth": int(self.ga.num_levels),
        }
