"""Data plane: TADOC-compressed corpora feeding the training stack.

tokenizer.py — word-level tokenizer + vocab (vocab stats come from TADOC
word_count, i.e. computed on the *compressed* corpus).
synthetic.py — corpus generators shaped like the paper's Table II datasets.
store.py     — on-disk compressed corpus (grammar arrays + vocab).
pipeline.py  — deterministic sharded batch iterator over the compressed
store using random-access window expansion (no decompression of the
corpus as a whole, paper [3]).
"""

from .tokenizer import Tokenizer
from .store import CompressedCorpus
from .pipeline import BatchPipeline, PipelineState
from . import synthetic

__all__ = ["Tokenizer", "CompressedCorpus", "BatchPipeline", "PipelineState",
           "synthetic"]
