from . import hlo_analysis  # noqa: F401
