"""Parse compiled HLO for roofline inputs.

``cost_analysis()`` supplies per-device FLOPs and bytes accessed.
Collective bytes are NOT in cost_analysis: we parse the optimized HLO text
and sum *operand* sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (per assignment instructions).  The HLO is
the per-device SPMD program, so sums are per-chip quantities.
"""

from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce.5 = (f32[128,256]{1,0}, f32[64]{0}) all-reduce(
_OP_RE = re.compile(
    r"=\s*(?P<out>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """kind -> {count, bytes} summed over the module (per device).

    Uses the *result* shape of each collective op as the operand-size proxy
    (for all-reduce/permute they are equal; for all-gather the result is the
    gathered size = bytes received; for reduce-scatter the operand is larger
    than the result — we use the operand side when visible via the `-start`
    form, else the result; consistent, slightly conservative).
    """
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES}
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        kind = m.group("kind")
        # avoid double counting async pairs: the '-done' op repeats the shape
        prefix = hlo_text[max(0, m.start() - 160):m.end()]
        if f"{kind}-done" in prefix:
            continue
        b = _shape_bytes(m.group("out"))
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
    return out


def total_collective_bytes(hlo_text: str) -> float:
    return sum(v["bytes"] for v in parse_collectives(hlo_text).values())


def op_histogram(hlo_text: str, ops=("dot", "reshape", "transpose",
                                     "fusion", "while", "custom-call")
                 ) -> Dict[str, int]:
    """Count interesting op kinds — the §Perf 'profile' for a compiled
    module (redundant reshapes/transposes between sharded ops are the
    layout-mismatch smell the perf loop hunts)."""
    out = {}
    for op in ops:
        out[op] = len(re.findall(rf"\b{op}\(", hlo_text))
    return out
