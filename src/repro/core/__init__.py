"""TADOC core: the paper's contribution — text analytics directly on
Sequitur-compressed data, as composable JAX modules.

Pipeline: ``sequitur.compress_files`` (offline, host) ->
``grammar.flatten`` (static layout) -> ``traversal`` / ``analytics`` /
``sequence`` (JAX, TPU-targeted) with ``memory`` planning the static arenas
and ``selector`` choosing the traversal strategy.
"""

from .sequitur import (Grammar, IncrementalSequitur, compress,
                       compress_files)
from .grammar import GrammarArrays, StaleGrammarError, flatten, expand_range
from .traversal import (top_down_weights, per_file_weights, bottom_up_tables,
                        bottom_up_bounds, traversal_rounds)
from .analytics import (word_count, sort_words, inverted_index, term_vector,
                        ranked_inverted_index, sequence_count,
                        term_vector_sparse)
from .selector import select_direction, estimate_costs
from .memory import (ArenaPlan, plan_local_tables, plan_streams,
                     head_tail_upper_limit, stream_upper_limit)
from .batch import (GrammarBatch, batched_top_down_weights,
                    batched_per_file_weights, batched_word_count,
                    batched_sort_words, batched_term_vector,
                    batched_inverted_index, batched_ranked_inverted_index,
                    batched_sequence_count, run_batched, unbatch,
                    ANALYTICS_KINDS)

__all__ = [
    "Grammar", "IncrementalSequitur", "compress", "compress_files",
    "GrammarArrays", "StaleGrammarError", "flatten", "expand_range",
    "top_down_weights", "per_file_weights", "bottom_up_tables",
    "bottom_up_bounds", "traversal_rounds",
    "word_count", "sort_words", "inverted_index", "term_vector",
    "ranked_inverted_index", "sequence_count", "term_vector_sparse",
    "select_direction", "estimate_costs",
    "ArenaPlan", "plan_local_tables", "plan_streams",
    "head_tail_upper_limit", "stream_upper_limit",
    "GrammarBatch", "batched_top_down_weights", "batched_per_file_weights",
    "batched_word_count", "batched_sort_words", "batched_term_vector",
    "batched_inverted_index", "batched_ranked_inverted_index",
    "batched_sequence_count", "run_batched", "unbatch", "ANALYTICS_KINDS",
]
