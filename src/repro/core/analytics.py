"""The six TADOC analytics applications (paper §V: the CompressDirect set).

All six operate *directly on the compressed grammar* — no decompression.
Interfaces mirror the CD library: word count, sort, inverted index, term
vector, sequence count, ranked inverted index.

Global reductions ("the paper's reduceResultKernel / thread-safe global hash
table") go through :func:`repro.kernels.ops.weighted_bincount` — the Pallas
MXU histogram kernel — when ``backend="pallas"``, or its jnp oracle
otherwise (identical results; tests assert allclose).

Per-file analytics use the batched per-file top-down weights.  The dense
``[F, V]`` intermediates are fine at the assignment's scale; for corpora with
1e5+ files the store keeps the per-file CSR produced by
:func:`term_vector_sparse` (host path, same math, sparse layout).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .grammar import GrammarArrays
from .traversal import per_file_weights, top_down_weights
from . import sequence as _sequence


def _global_reduce(ids: jnp.ndarray, vals: jnp.ndarray, nbins: int,
                   backend: str) -> jnp.ndarray:
    if backend == "pallas":
        from repro.kernels import ops as kops
        return kops.weighted_bincount(ids, vals, nbins)
    return jax.ops.segment_sum(vals, ids, num_segments=nbins)


# ------------------------------------------------------------------ apps --
def word_count(ga: GrammarArrays, method: str = "auto",
               backend: str = "jnp",
               weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """counts[v] = occurrences of word v in the whole corpus.

    ``weights`` lets callers reuse a memoized traversal (the store caches
    per-corpus weights for the serving layer) — it must equal
    ``top_down_weights(ga)``.
    """
    if weights is None:
        weights = top_down_weights(ga, method=_pick(ga, method))
    vals = jnp.asarray(ga.tw_cnt, jnp.float32) * \
        weights[jnp.asarray(ga.tw_rule)]
    return _global_reduce(jnp.asarray(ga.tw_word), vals, ga.vocab_size, backend)


def sort_words(ga: GrammarArrays, method: str = "auto", backend: str = "jnp",
               weights: jnp.ndarray | None = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Words sorted by frequency (desc). Returns (word_ids, counts)."""
    counts = word_count(ga, method=method, backend=backend, weights=weights)
    order = jnp.argsort(-counts, stable=True)
    return order, counts[order]


def term_vector(ga: GrammarArrays, method: str = "auto",
                file_weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """tv[f, v] = occurrences of word v in file f.  Dense [F, V].

    ``file_weights`` lets callers reuse a memoized per-file traversal; it
    must equal ``per_file_weights(ga)``.
    """
    if file_weights is None:
        Wf = per_file_weights(ga, method=_pick(ga, method))  # [R, F]
    else:
        Wf = file_weights
    contrib = Wf[jnp.asarray(ga.tw_rule), :] * \
        jnp.asarray(ga.tw_cnt, jnp.float32)[:, None]   # [T, F]
    tv = jax.ops.segment_sum(contrib, jnp.asarray(ga.tw_word),
                             num_segments=ga.vocab_size)  # [V, F]
    tv = tv.T
    tv = tv.at[ga.fword_file, ga.fword_word].add(
        ga.fword_cnt.astype(np.float32))
    return tv


def inverted_index(ga: GrammarArrays, method: str = "auto",
                   file_weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """ii[f, v] = True iff word v occurs in file f."""
    return term_vector(ga, method=method, file_weights=file_weights) > 0


def ranked_inverted_index(ga: GrammarArrays, method: str = "auto",
                          file_weights: jnp.ndarray | None = None
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """For each word: files ranked by frequency (desc), with counts.

    Returns (ranking [V, F] of file ids, counts [V, F] aligned to ranking).
    """
    tv = term_vector(ga, method=method, file_weights=file_weights)  # [F, V]
    order = jnp.argsort(-tv, axis=0, stable=True)      # [F, V]
    ranked = jnp.take_along_axis(tv, order, axis=0)    # [F, V]
    return order.T, ranked.T


def sequence_count(ga: GrammarArrays, l: int = 3, method: str = "auto",
                   weights: jnp.ndarray | None = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Distinct l-gram counts (paper §IV-D).  See core/sequence.py."""
    return _sequence.sequence_count(ga, l=l, method=_pick(ga, method),
                                    weights=weights)


# ---------------------------------------------------------------- helpers --
def _pick(ga: GrammarArrays, method: str) -> str:
    if method != "auto":
        return method
    from .selector import select_traversal
    return select_traversal(ga)


def term_vector_sparse(ga: GrammarArrays) -> Tuple[np.ndarray, np.ndarray,
                                                   np.ndarray]:
    """Host sparse per-file counts: returns COO (file, word, count).

    Frontier propagation of (file, rule, weight) triplets with per-level
    dedup — the scalable path for 1e5+-file corpora where dense [F, V] is
    not materializable.  Same math as :func:`term_vector`.
    """
    R = ga.num_rules
    # per-file rule weights, propagated sparsely level by level
    from collections import defaultdict
    Wf: defaultdict = defaultdict(float)       # (rule, file) -> weight
    for c, f, q in zip(ga.fedge_child, ga.fedge_file, ga.fedge_freq):
        Wf[(int(c), int(f))] += float(q)
    by_level = [[] for _ in range(ga.num_levels)]
    for e in range(ga.num_edges):
        p = int(ga.edge_parent[e])
        if p != 0:
            by_level[int(ga.level[p])].append(e)
    for lv in range(ga.num_levels):
        for e in by_level[lv]:
            p, c, q = (int(ga.edge_parent[e]), int(ga.edge_child[e]),
                       float(ga.edge_freq[e]))
            for (r, f), w in list(Wf.items()):
                if r == p:
                    Wf[(c, f)] += q * w
    out: defaultdict = defaultdict(float)      # (file, word) -> count
    tw_by_rule = defaultdict(list)
    for r, w, c in zip(ga.tw_rule, ga.tw_word, ga.tw_cnt):
        tw_by_rule[int(r)].append((int(w), float(c)))
    for (r, f), wt in Wf.items():
        for (w, c) in tw_by_rule.get(r, ()):
            out[(f, w)] += wt * c
    for f, w, c in zip(ga.fword_file, ga.fword_word, ga.fword_cnt):
        out[(int(f), int(w))] += float(c)
    if not out:
        return (np.zeros(0, np.int32),) * 3
    items = sorted(out.items())
    ff = np.array([k[0] for k, _ in items], np.int32)
    ww = np.array([k[1] for k, _ in items], np.int32)
    cc = np.array([v for _, v in items], np.float32)
    return ff, ww, cc
