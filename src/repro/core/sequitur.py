"""Sequitur grammar inference (host side, numpy/python).

TADOC extends Sequitur [Nevill-Manning & Witten 1997] as its compression
algorithm (paper §II-A).  This is the classic online algorithm with the two
invariants:

  * digram uniqueness — no pair of adjacent symbols appears more than once
    in the grammar;
  * rule utility      — every rule (except the root) is referenced >= 2
    times.

Symbols are integers.  Terminals are ``0 .. num_terminals-1`` (this includes
the per-file splitter symbols TADOC inserts at file boundaries — splitters
are *unique*, so they never form repeated digrams and thus never end up
inside a rule).  Nonterminals are returned as ``num_terminals + rule_index``
in the exported grammar (root is rule 0).

This module is deliberately host-side: grammar inference is the *offline
compression* step of TADOC; the analytics (the paper's contribution) operate
on the flattened arrays produced by :mod:`repro.core.grammar`.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.obs import global_registry

# Node storage: parallel lists (struct-of-arrays linked list).  A node is an
# index into these lists.  ``val`` >= 0 is a terminal; ``val`` < 0 encodes
# nonterminal rule ``-(val + 1)``; guards have ``val == GUARD`` and carry the
# owning rule id in ``guard_rule``.
GUARD = -(1 << 60)


def _rule_sym(rule_id: int) -> int:
    return -(rule_id + 1)


def _sym_rule(val: int) -> int:
    return -val - 1


def _is_rule(val: int) -> bool:
    # Guards use val <= GUARD (rule id encoded below GUARD); rule symbols are
    # small negatives strictly above GUARD.
    return val < 0 and val > GUARD


@dataclass
class Grammar:
    """Inferred grammar: ``rules[i]`` is the body of rule i (root == 0).

    Body symbols: ``0 <= s < num_terminals`` are terminals, otherwise
    ``s - num_terminals`` is a rule index.
    """

    num_terminals: int
    rules: List[np.ndarray] = field(default_factory=list)

    @property
    def num_rules(self) -> int:
        return len(self.rules)

    def expand(self, rule_id: int = 0, _memo: Dict[int, np.ndarray] | None = None) -> np.ndarray:
        """Decompress a rule to its terminal sequence (oracle for tests).

        Explicit-stack iterative: a chain grammar R0 -> R1 -> ... -> Rn is
        only log-deep when Sequitur built it, but nothing stops a caller
        (or a future parallel constructor) from handing this a chain deeper
        than Python's recursion limit — the recursive form died there.
        """
        if _memo is None:
            _memo = {}
        nt = self.num_terminals
        stack: List[int] = [rule_id]
        while stack:
            r = stack[-1]
            if r in _memo:
                stack.pop()
                continue
            missing = [int(s) - nt for s in self.rules[r]
                       if int(s) >= nt and (int(s) - nt) not in _memo]
            if missing:
                stack.extend(missing)
                continue
            out: List[np.ndarray] = []
            for s in self.rules[r]:
                s = int(s)
                if s < nt:
                    out.append(np.array([s], dtype=np.int64))
                else:
                    out.append(_memo[s - nt])
            _memo[r] = (np.concatenate(out) if out
                        else np.zeros(0, dtype=np.int64))
            stack.pop()
        return _memo[rule_id]


class _Sequitur:
    __slots__ = (
        "nxt", "prv", "val", "free",
        "digrams", "rule_guard", "rule_ref", "n_rules",
    )

    def __init__(self) -> None:
        self.nxt: List[int] = []
        self.prv: List[int] = []
        self.val: List[int] = []
        self.free: List[int] = []
        self.digrams: Dict[Tuple[int, int], int] = {}
        self.rule_guard: Dict[int, int] = {}
        self.rule_ref: Dict[int, int] = {}
        self.n_rules = 0

    # ------------------------------------------------------------- nodes --
    def _new_node(self, v: int) -> int:
        if self.free:
            n = self.free.pop()
            self.val[n] = v
            return n
        self.nxt.append(-1)
        self.prv.append(-1)
        self.val.append(v)
        return len(self.val) - 1

    def _free_node(self, n: int) -> None:
        self.free.append(n)

    def _is_guard(self, n: int) -> bool:
        return self.val[n] == GUARD or self.val[n] <= GUARD

    # ------------------------------------------------------------- rules --
    def new_rule(self) -> int:
        rid = self.n_rules
        self.n_rules += 1
        g = self._new_node(GUARD - (rid + 1))  # encode rule id in guard val
        self.nxt[g] = g
        self.prv[g] = g
        self.rule_guard[rid] = g
        self.rule_ref[rid] = 0
        return rid

    def _guard_rule(self, g: int) -> int:
        return -(self.val[g] - GUARD) - 1

    # ----------------------------------------------------------- digrams --
    def _digram_of(self, n: int) -> Tuple[int, int]:
        return (self.val[n], self.val[self.nxt[n]])

    def _remove_digram(self, n: int) -> None:
        """Remove the digram starting at n from the index, if n owns it."""
        m = self.nxt[n]
        if self._is_guard(n) or self._is_guard(m):
            return
        d = self._digram_of(n)
        if self.digrams.get(d) == n:
            del self.digrams[d]

    # ------------------------------------------------------------ splice --
    def _insert_after(self, pos: int, v: int) -> int:
        n = self._new_node(v)
        nn = self.nxt[pos]
        self.nxt[pos] = n
        self.prv[n] = pos
        self.nxt[n] = nn
        self.prv[nn] = n
        if _is_rule(v):
            self.rule_ref[_sym_rule(v)] += 1
        return n

    def _unlink(self, n: int) -> None:
        p, q = self.prv[n], self.nxt[n]
        self.nxt[p] = q
        self.prv[q] = p
        v = self.val[n]
        if _is_rule(v):
            self.rule_ref[_sym_rule(v)] -= 1
        self._free_node(n)

    # -------------------------------------------------------------- core --
    def append(self, rule_id: int, v: int) -> None:
        g = self.rule_guard[rule_id]
        last = self.prv[g]
        n = self._insert_after(last, v)
        self._check(self.prv[n])

    def _check(self, n: int) -> bool:
        """Enforce digram uniqueness for the digram starting at node n."""
        if n < 0 or self._is_guard(n):
            return False
        m = self.nxt[n]
        if self._is_guard(m):
            return False
        d = self._digram_of(n)
        other = self.digrams.get(d)
        if other is None:
            self.digrams[d] = n
            return False
        if other == n:
            return False
        # Overlapping occurrence (e.g. "aaa"): do nothing.
        if self.nxt[other] == n or self.nxt[n] == other:
            return False
        self._match(n, other)
        return True

    def _match(self, n: int, other: int) -> None:
        """Digram at n repeats the indexed digram at `other`."""
        og = self.prv[other]
        # Is `other` exactly a whole rule body of length 2?
        if (self._is_guard(self.prv[other])
                and self._is_guard(self.nxt[self.nxt[other]])):
            rid = self._guard_rule(self.prv[other])
            self._substitute(n, rid)
        else:
            rid = self.new_rule()
            a, b = self._digram_of(other)
            g = self.rule_guard[rid]
            n1 = self._insert_after(g, a)
            n2 = self._insert_after(n1, b)
            self.digrams[self._digram_of(n1)] = n1
            # Substitute the *indexed* occurrence first, then ours.
            self._substitute(other, rid)
            self._substitute(n, rid)

    def _substitute(self, n: int, rid: int) -> None:
        """Replace the digram starting at n with nonterminal `rid`."""
        m = self.nxt[n]
        prev = self.prv[n]
        # Remove index entries for digrams destroyed by the splice.
        self._remove_digram(prev)
        self._remove_digram(n)
        self._remove_digram(m)
        self._unlink(m)
        self._unlink(n)
        s = self._insert_after(prev, _rule_sym(rid))
        # Rule utility: a refcount may have dropped to 1 here.  We enforce
        # utility lazily — single-use rules are inlined once, at export()
        # (grammar stays semantically identical; canonical Sequitur inlines
        # eagerly, which only changes *which* equal-size grammar you get).
        if not self._check(prev):
            self._check(s)

    # ------------------------------------------------------------ export --
    def export(self, num_terminals: int) -> Grammar:
        """Inline single-use rules, renumber, and export flat bodies."""
        ref = dict(self.rule_ref)
        # root (rule 0) is always kept
        keep = [rid for rid in range(self.n_rules) if rid == 0 or ref.get(rid, 0) >= 2]
        single = {rid for rid in range(self.n_rules) if rid != 0 and ref.get(rid, 0) < 2}

        bodies: Dict[int, List[int]] = {}

        def raw_body(rid: int) -> List[int]:
            out: List[int] = []
            g = self.rule_guard[rid]
            n = self.nxt[g]
            while not self._is_guard(n):
                out.append(self.val[n])
                n = self.nxt[n]
            return out

        def body_of(rid: int) -> List[int]:
            """Body with single-use rules inlined (iterative: deeply nested
            single-use chains appear in highly repetitive corpora)."""
            if rid in bodies:
                return bodies[rid]
            # iterative post-order (two-phase stack) over the inline DAG
            stack = [(rid, 0)]
            opened = set()
            while stack:
                r, phase = stack.pop()
                if r in bodies:
                    continue
                if phase == 0:
                    if r in opened:
                        continue
                    opened.add(r)
                    stack.append((r, 1))
                    for v in raw_body(r):
                        if _is_rule(v) and _sym_rule(v) in single:
                            stack.append((_sym_rule(v), 0))
                else:
                    out: List[int] = []
                    for v in raw_body(r):
                        if _is_rule(v):
                            sub = _sym_rule(v)
                            if sub in single:
                                out.extend(bodies[sub])
                            else:
                                out.append(_rule_sym(sub))
                        else:
                            out.append(v)
                    bodies[r] = out
            return bodies[rid]

        renum = {rid: i for i, rid in enumerate(keep)}
        rules: List[np.ndarray] = []
        for rid in keep:
            b = body_of(rid)
            arr = np.array(
                [s if s >= 0 else num_terminals + renum[_sym_rule(s)] for s in b],
                dtype=np.int64,
            )
            rules.append(arr)
        return Grammar(num_terminals=num_terminals, rules=rules)


def compress(tokens: Sequence[int] | np.ndarray, num_terminals: int) -> Grammar:
    """Run Sequitur over a token stream; returns the inferred grammar.

    ``tokens`` must all be in ``[0, num_terminals)``.
    """
    sq = _Sequitur()
    root = sq.new_rule()
    assert root == 0
    for t in np.asarray(tokens, dtype=np.int64):
        v = int(t)
        if not (0 <= v < num_terminals):
            raise ValueError(f"token {v} outside [0, {num_terminals})")
        sq.append(root, v)
    return sq.export(num_terminals)


class IncrementalSequitur:
    """Live multi-file Sequitur state that absorbs appended files.

    Sequitur is an *online* algorithm: the grammar after consuming a stream
    depends only on the stream prefix, never on what follows.  Keeping the
    node store alive between files therefore makes multi-file compression
    incremental for free — appending file k+1 to a state that already
    consumed files 0..k performs exactly the operations a from-scratch run
    over all k+2 files would, so the resulting grammar is *identical*, not
    merely equivalent (tests/test_ingest.py holds this to bit-equality).

    Two properties make the append safe at file boundaries:

    * each file ends in a globally unique splitter terminal
      (``vocab_size + file_index``) that can never form a repeated digram,
      so no rule ever spans two files and appending cannot perturb digram
      uniqueness across the boundary;
    * rule symbols are stored as negative node values internally, so
      :meth:`export` can be re-invoked with a *larger* ``num_terminals``
      as files (and their splitter ids) accrue — export is read-only.
    """

    __slots__ = ("vocab_size", "n_files", "_sq")

    def __init__(self, vocab_size: int):
        if vocab_size < 1:
            raise ValueError(f"vocab_size must be >= 1, got {vocab_size}")
        self.vocab_size = int(vocab_size)
        self.n_files = 0
        self._sq = _Sequitur()
        root = self._sq.new_rule()
        assert root == 0

    @property
    def num_terminals(self) -> int:
        """Words ++ splitters: ``[0, vocab_size + n_files)``."""
        return self.vocab_size + self.n_files

    def append_file(self, tokens: Sequence[int] | np.ndarray) -> None:
        """Feed one file's word tokens, then its unique splitter.

        Word tokens must be in ``[0, vocab_size)`` — a word colliding with
        a splitter id would corrupt per-file ownership, so this validates
        strictly against the word range (empty files are fine: they
        contribute just their splitter)."""
        toks = np.asarray(tokens, dtype=np.int64)
        if toks.ndim != 1:
            raise ValueError(f"file must be a 1-D token array, "
                             f"got shape {toks.shape}")
        if toks.size and not (0 <= int(toks.min())
                              and int(toks.max()) < self.vocab_size):
            bad = toks[(toks < 0) | (toks >= self.vocab_size)][0]
            raise ValueError(f"token {int(bad)} outside word range "
                             f"[0, {self.vocab_size})")
        t0 = time.perf_counter()
        for t in toks:
            self._sq.append(0, int(t))
        self._sq.append(0, self.vocab_size + self.n_files)
        self.n_files += 1
        # ingest throughput: host-side Sequitur is the streaming tier's
        # bottleneck candidate, so appends are metered on the process
        # registry (wall time — compression runs outside any server clock)
        reg = global_registry()
        reg.counter("repro_ingest_files_total",
                    "files fed through IncrementalSequitur").inc()
        reg.counter("repro_ingest_tokens_total",
                    "word tokens fed through IncrementalSequitur"
                    ).inc(float(toks.size))
        reg.histogram("repro_ingest_append_seconds",
                      "wall seconds per IncrementalSequitur.append_file"
                      ).observe(time.perf_counter() - t0)

    def append_files(self, files: Sequence[np.ndarray]) -> None:
        for f in files:
            self.append_file(f)

    def export(self) -> Grammar:
        """Snapshot the current grammar (read-only; callable after every
        append — the live state is untouched)."""
        return self._sq.export(self.num_terminals)


def compress_files(
    files: Sequence[np.ndarray], vocab_size: int
) -> Tuple[Grammar, int]:
    """TADOC multi-file compression (paper §II-A).

    Inserts a *unique* splitter symbol after each file so rules never span
    file boundaries.  Terminal id space becomes
    ``[0, vocab_size)`` words ++ ``[vocab_size, vocab_size + n_files)``
    splitters.  Returns (grammar, num_files).

    Implemented on :class:`IncrementalSequitur` (one-shot build and
    streaming append are the same code path, so "incremental ==
    from-scratch" is structural, not coincidental).
    """
    inc = IncrementalSequitur(vocab_size)
    inc.append_files(files)
    return inc.export(), inc.n_files
