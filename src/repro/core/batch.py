"""Batched multi-corpus analytics: pack N grammars, traverse them together.

The single-corpus engine (traversal.py / analytics.py) runs one compressed
corpus per jitted call.  Under serving load ("heavy traffic" — many corpora,
many queries) that wastes the device: every corpus gets its own dispatch,
its own while_loop, its own compilation (shapes differ corpus to corpus).

This module is the TPU analogue of batching many compressed segments into
one GPU program: a :class:`GrammarBatch` packs N :class:`GrammarArrays`
into padded, bucketed ``[N, ...]`` device arrays (the pre-planned memory
pool of paper §IV-C, extended across corpora), and every analytic runs as
ONE jitted program over the whole batch:

* ``frontier`` traversal — vmap of the masked-rounds engine over the packed
  batch, sharing a single ``while_loop`` whose stop flag is ``mask.any()``
  across *all* corpora (finished corpora idle harmlessly: their masks are
  empty, so extra rounds are no-ops).
* ``leveled`` traversal — per-level edge segments are padded to a common
  width across corpora, so the level schedule is shared and each real edge
  is still touched exactly once.
* all six analytics (word count, sort, inverted index, term vector,
  sequence count, ranked inverted index) — bit-identical to running the
  single-corpus functions in a Python loop (tests/test_batch.py).

Padding convention: padded edges carry ``freq == 0`` and are additionally
masked by ``edge_valid``; padded rule slots have ``in_deg == out_deg == 0``
(they become "ready" in round 0 with weight 0 and never contribute).
Dimensions are bucketed (rounded up to powers of two) so batches of similar
size hit the same compiled program — the dispatch layer
(serving/analytics_server.py) groups queries by this signature.

DESIGN — the ELL edge plan (methods ``frontier_ell`` / ``leveled_ell``):
:meth:`GrammarBatch.ell_plan` converts each corpus's COO in-edges to a
dense ``src/freq [N, R_pad, K]`` layout (row r = rule r's parents, K = max
in-degree across the batch bucketed to a power of two, padding src=0 /
freq=0).  Because the row index IS the destination rule, one propagation
round needs no scatter: ``kernels.ops.ell_propagate_batched`` fuses the
gather, mask-gate, multiply and row-sum — and emits the ``seen`` frontier
bookkeeping — in a single launch (two segment_sum scatters per round on
the COO path).  The plan is built lazily and memoized per batch; method
``auto`` asks ``kernels.ops.ell_batched_use_ref`` (occupancy over edge
count, plan width K, batch width N) whether the dense plan pays off.  The
leveled variant replays the same plan once per level with the mask
``level[parent] == lv`` — each real edge still contributes exactly once,
at its parent's level.  Per-file traversals keep the segment_sum path
(their payload is a [R, F] vector per rule; the ELL kernels are scalar).

DESIGN — device-sharded batches (:meth:`GrammarBatch.shard`): the corpus
axis N is embarrassingly parallel (every traversal above is a vmap over
it), so a pack placed with ``NamedSharding(mesh, P(CORPUS_AXIS, ...))``
splits row-wise across a 1-D device mesh and the same analytics run as one
jitted program spanning all devices.  The frontier engines (a
``while_loop`` whose stop flag is ``mask.any()``) are wrapped in
``shard_map`` so each shard's loop stops when *its own* corpora finish —
no per-round cross-device all-reduce, and each shard executes exactly the
single-device program on its ``[N/D, ...]`` slice, which keeps results
bit-identical to the unsharded path (all counts are integer-valued and far
below 2**24, so float32 arithmetic is exact in any summation order).  The
leveled engines (static schedule, no loop) shard by placement alone.
Sharding requires N to be a multiple of the mesh size;
:mod:`repro.distributed.shard_batch` pads a corpus list to that multiple
(``n_real`` tracks how many rows are real — finalization and
:func:`unbatch` never surface padding rows).  Per-shard pack signatures
are identical by construction (same padded dims on every shard), so
recurring sharded traffic reuses compiled programs exactly like the
single-device pack cache.
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from dataclasses import field as dataclass_field
from typing import Any, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
try:
    from jax import shard_map  # jax >= 0.8 canonical location
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

from repro.obs import plan_stage as _plan_stage

from .grammar import (GrammarArrays, StaleGrammarError,
                      pow2_bucket as _pow2_bucket)
from . import sequence as _sequence
from .sequence import _K_HEAD, _K_LIT, _K_TAIL


# ----------------------------------------------------------------------- #
# Packed layout                                                            #
# ----------------------------------------------------------------------- #
#: Mesh axis name the corpus dimension N shards over (1-D device mesh,
#: built by repro.distributed.shard_batch.corpus_mesh).
CORPUS_AXIS = "corpus"


def _round_up_pow2(x: int, minimum: int = 8) -> int:
    if x <= minimum:
        return minimum
    return 1 << (int(x) - 1).bit_length()


def _pad_stack(arrs: Sequence[np.ndarray], width: int, fill=0,
               dtype=np.int32) -> np.ndarray:
    out = np.full((len(arrs), width), fill, dtype)
    for i, a in enumerate(arrs):
        out[i, : len(a)] = a
    return out


@dataclass(frozen=True, eq=False)   # eq over jnp fields would raise; identity
class GrammarBatch:
    """N grammars packed into padded ``[N, ...]`` device arrays."""

    gas: Tuple[GrammarArrays, ...]      # originals (host, for finalization)

    # padded dims (bucketed)
    R_pad: int
    E_pad: int
    T_pad: int
    F_pad: int
    V_pad: int
    Tf_pad: int

    # per-corpus true sizes (host)
    num_rules: np.ndarray               # [N]
    vocab_sizes: np.ndarray             # [N]
    num_files: np.ndarray               # [N]

    # packed DAG (device)
    edge_parent: jnp.ndarray            # [N, E_pad] int32
    edge_child: jnp.ndarray             # [N, E_pad] int32
    edge_freq: jnp.ndarray              # [N, E_pad] float32 (0 on padding)
    edge_valid: jnp.ndarray             # [N, E_pad] bool
    in_deg: jnp.ndarray                 # [N, R_pad] int32
    root_seen: jnp.ndarray              # [N, R_pad] int32 (in-edges from root)

    # packed local word tables (device)
    tw_rule: jnp.ndarray                # [N, T_pad] int32
    tw_word: jnp.ndarray                # [N, T_pad] int32
    tw_cnt: jnp.ndarray                 # [N, T_pad] float32 (0 on padding)

    # packed per-file root segments (device)
    fedge_file: jnp.ndarray             # [N, Ef_pad] int32
    fedge_child: jnp.ndarray            # [N, Ef_pad] int32
    fedge_freq: jnp.ndarray             # [N, Ef_pad] float32
    fword_file: jnp.ndarray             # [N, Tf_pad] int32
    fword_word: jnp.ndarray             # [N, Tf_pad] int32
    fword_cnt: jnp.ndarray              # [N, Tf_pad] float32

    # leveled schedule: per-level segments padded to shared widths
    lv_parent: jnp.ndarray              # [N, EL] int32
    lv_child: jnp.ndarray               # [N, EL] int32
    lv_freq: jnp.ndarray                # [N, EL] float32 (0 on padding)
    lv_slices: Tuple[Tuple[int, int], ...]   # shared (start, end) per level

    # device-sharded execution (module DESIGN note): a 1-D jax Mesh whose
    # CORPUS_AXIS splits the N axis row-wise, and the count of *real* rows
    # when the pack was padded up to a mesh multiple (None: all rows real)
    mesh: Any = None
    n_real: Optional[int] = None

    # ingest-tier staleness guard: the source-corpus epoch of each packed
    # row at pack time (None when the pack was built from bare immutable
    # GrammarArrays with no mutable store behind them).  A pack snapshots
    # its gas, so the pack itself stays internally consistent forever —
    # including every lazy plan below, which derives from those snapshot
    # arrays — but serving it for a corpus whose store has since absorbed
    # appended files would answer with pre-append data.  check_epochs is
    # the loud guard against that.
    epochs: Optional[Tuple[int, ...]] = None

    # per-batch memo for host-side sequence plans (mutable contents are
    # fine on a frozen dataclass; keyed by window length l)
    _plan_cache: dict = dataclass_field(default_factory=dict, repr=False,
                                        compare=False)

    @property
    def n(self) -> int:
        return len(self.gas)

    @property
    def real(self) -> int:
        """Rows that correspond to real corpora (the rest is shard padding;
        their results are computed and discarded, never surfaced)."""
        return self.n if self.n_real is None else self.n_real

    @property
    def real_gas(self) -> Tuple[GrammarArrays, ...]:
        return self.gas[: self.real]

    @property
    def shards(self) -> int:
        """Device count the pack spans (1 when unsharded)."""
        return 1 if self.mesh is None else int(self.mesh.size)

    @property
    def signature(self) -> Tuple[int, ...]:
        """Compilation signature: batches with equal signatures (and equal
        ``lv_slices`` for the leveled engine) reuse jitted programs.  The
        trailing element is the shard count — a sharded pack compiles a
        different (partitioned) program than a single-device pack of the
        same shape."""
        return (self.n, self.R_pad, self.E_pad, self.T_pad, self.F_pad,
                self.V_pad, int(self.fedge_file.shape[1]), self.Tf_pad,
                self.shards)

    # ------------------------------------------------------------- shard --
    def _placement(self, ndim: int):
        """NamedSharding splitting the leading (corpus) axis, or None when
        the pack is unsharded."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(CORPUS_AXIS, *([None] * (ndim - 1))))

    def _place(self, arr) -> jnp.ndarray:
        """Put one [N, ...] array where the pack lives: sharded row-wise
        across ``mesh`` (lazy plan arrays must land with the same placement
        as the packed arrays, or every sharded call pays a reshard)."""
        sh = self._placement(np.ndim(arr))
        a = jnp.asarray(arr)
        return a if sh is None else jax.device_put(a, sh)

    def shard(self, mesh, n_real: Optional[int] = None) -> "GrammarBatch":
        """Re-place every packed device array row-sharded over ``mesh``.

        ``mesh`` must be a 1-D mesh over axis ``CORPUS_AXIS`` whose size
        divides N (use :func:`repro.distributed.shard_batch.shard_batch` to
        pad an arbitrary corpus list up to the multiple).  Returns a new
        :class:`GrammarBatch`; lazy plans (ELL, sequence) are rebuilt on
        demand with the sharded placement.
        """
        if tuple(mesh.axis_names) != (CORPUS_AXIS,):
            raise ValueError(f"mesh must be 1-D over axis {CORPUS_AXIS!r}, "
                             f"got axes {tuple(mesh.axis_names)}")
        d = int(mesh.shape[CORPUS_AXIS])
        if self.n % d:
            raise ValueError(
                f"batch of {self.n} corpora does not divide across {d} "
                f"devices; pad first (distributed.shard_batch.shard_batch)")
        if n_real is not None and not (0 < n_real <= self.n):
            raise ValueError(f"n_real={n_real} out of range for N={self.n}")
        sharded = dataclasses.replace(
            self, mesh=mesh,
            n_real=self.n_real if n_real is None else n_real,
            _plan_cache={})
        # re-place the packed [N, ...] device arrays row-sharded
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, jnp.ndarray):
                object.__setattr__(sharded, f.name, sharded._place(v))
        return sharded

    def check_epochs(self, current: Sequence[int]) -> None:
        """Raise :class:`StaleGrammarError` if any source corpus has moved
        past the epoch this pack (and every lazy plan memoized on it) was
        built from.

        ``current`` is the live epoch per *real* row, in pack order (shard
        padding rows duplicate a real grammar and are never surfaced, so
        only the real prefix is compared).  Packs without epoch stamps
        (``epochs is None`` — built from bare immutable arrays) pass
        trivially.  The serving layer re-packs instead of raising; this is
        the backstop for any caller that skips that refresh.
        """
        if self.epochs is None:
            return
        cur = tuple(int(e) for e in current)
        if len(cur) > len(self.epochs):
            raise StaleGrammarError(
                f"epoch check over {len(cur)} corpora against a pack "
                f"stamped with {len(self.epochs)}")
        for i, (have, now) in enumerate(zip(self.epochs, cur)):
            if have != now:
                raise StaleGrammarError(
                    f"pack row {i} was built at corpus epoch {have} but "
                    f"the corpus is now at epoch {now} — re-pack before "
                    f"serving (the corpus absorbed appended files)")

    @property
    def total_edges(self) -> int:
        """True (unpadded) edge count across the batch (memoized: the
        dispatch runs per batched call on cached packs)."""
        if ("edges",) not in self._plan_cache:
            self._plan_cache[("edges",)] = sum(ga.num_edges
                                               for ga in self.gas)
        return self._plan_cache[("edges",)]

    def ell_plan_width(self) -> int:
        """K of the dense ELL plan (max in-degree across the batch, bucketed
        to a power of two) — host-only and memoized: lets the auto dispatch
        reject the plan before building it, on every call, for free."""
        if ("ell_width",) not in self._plan_cache:
            kmax = max((int(ga.in_deg.max(initial=0)) for ga in self.gas),
                       default=0)
            self._plan_cache[("ell_width",)] = _pow2_bucket(kmax)
        return self._plan_cache[("ell_width",)]

    def ell_plan(self) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, int]:
        """Dense [N, R_pad, K] in-edge plan + per-rule levels (memoized).

        Returns ``(src, freq, level, num_levels)``: src/freq are the padded
        per-corpus :meth:`GrammarArrays.in_edges_ell_dense` plans stacked to
        a shared K, ``level[i, r]`` is corpus i's rule level (-1 on padded
        rule slots — never active in the leveled replay), and num_levels the
        shared (max) level count.  Built lazily: packs that never run an ELL
        method never pay the dense layout.
        """
        key = ("ell",)
        if key not in self._plan_cache:
            with _plan_stage("ell"):
                K = self.ell_plan_width()
                src = np.zeros((self.n, self.R_pad, K), np.int32)
                freq = np.zeros((self.n, self.R_pad, K), np.float32)
                level = np.full((self.n, self.R_pad), -1, np.int32)
                for i, ga in enumerate(self.gas):
                    s, f = ga.in_edges_ell_dense(k=K)
                    src[i, : ga.num_rules] = s
                    freq[i, : ga.num_rules] = f
                    level[i, : ga.num_rules] = ga.level
                self._plan_cache[key] = (
                    self._place(src), self._place(freq),
                    self._place(level),
                    max(ga.num_levels for ga in self.gas))
        return self._plan_cache[key]

    # ------------------------------------------------------------ build --
    @classmethod
    def build(cls, gas: Sequence[GrammarArrays],
              bucket: bool = True,
              epochs: Optional[Sequence[int]] = None) -> "GrammarBatch":
        if not gas:
            raise ValueError("GrammarBatch needs at least one corpus")
        gas = tuple(gas)
        if epochs is not None:
            epochs = tuple(int(e) for e in epochs)
            if len(epochs) != len(gas):
                raise ValueError(f"epochs stamps {len(epochs)} corpora but "
                                 f"the pack holds {len(gas)}")
        rnd = _round_up_pow2 if bucket else (lambda x, minimum=1:
                                             max(int(x), minimum))
        R_pad = rnd(max(ga.num_rules for ga in gas))
        E_pad = rnd(max(ga.num_edges for ga in gas))
        T_pad = rnd(max(len(ga.tw_rule) for ga in gas))
        F_pad = rnd(max(ga.num_files for ga in gas), 1)
        V_pad = rnd(max(ga.vocab_size for ga in gas))
        Ef_pad = rnd(max(len(ga.fedge_file) for ga in gas), 1)
        Tf_pad = rnd(max(len(ga.fword_file) for ga in gas), 1)

        in_deg = _pad_stack([ga.in_deg for ga in gas], R_pad)
        root_seen = _pad_stack(
            [np.bincount(ga.edge_child[ga.edge_parent == 0],
                         minlength=ga.num_rules).astype(np.int32)
             for ga in gas], R_pad)
        valid = np.zeros((len(gas), E_pad), bool)
        for i, ga in enumerate(gas):
            valid[i, : ga.num_edges] = True

        # leveled schedule: align per-level segments across corpora
        n_levels = max(ga.num_levels for ga in gas)
        per_corpus = []
        for ga in gas:
            slices, order = ga.level_edge_slices()
            per_corpus.append((slices, order))
        widths = []
        for lv in range(n_levels):
            w = 0
            for (slices, _) in per_corpus:
                if lv < len(slices):
                    s, e = slices[lv]
                    w = max(w, e - s)
            widths.append(w)
        EL = sum(widths)
        lv_parent = np.zeros((len(gas), EL), np.int32)
        lv_child = np.zeros((len(gas), EL), np.int32)
        lv_freq = np.zeros((len(gas), EL), np.float32)
        lv_slices: List[Tuple[int, int]] = []
        off = 0
        for lv, w in enumerate(widths):
            lv_slices.append((off, off + w))
            for i, (ga, (slices, order)) in enumerate(zip(gas, per_corpus)):
                if lv >= len(slices):
                    continue
                s, e = slices[lv]
                sel = order[s:e]
                lv_parent[i, off: off + (e - s)] = ga.edge_parent[sel]
                lv_child[i, off: off + (e - s)] = ga.edge_child[sel]
                lv_freq[i, off: off + (e - s)] = ga.edge_freq[sel]
            off += w

        return cls(
            gas=gas,
            epochs=epochs,
            R_pad=R_pad, E_pad=E_pad, T_pad=T_pad, F_pad=F_pad,
            V_pad=V_pad, Tf_pad=Tf_pad,
            num_rules=np.array([ga.num_rules for ga in gas]),
            vocab_sizes=np.array([ga.vocab_size for ga in gas]),
            num_files=np.array([ga.num_files for ga in gas]),
            edge_parent=jnp.asarray(
                _pad_stack([ga.edge_parent for ga in gas], E_pad)),
            edge_child=jnp.asarray(
                _pad_stack([ga.edge_child for ga in gas], E_pad)),
            edge_freq=jnp.asarray(
                _pad_stack([ga.edge_freq for ga in gas], E_pad,
                           dtype=np.float32)),
            edge_valid=jnp.asarray(valid),
            in_deg=jnp.asarray(in_deg),
            root_seen=jnp.asarray(root_seen),
            tw_rule=jnp.asarray(_pad_stack([ga.tw_rule for ga in gas], T_pad)),
            tw_word=jnp.asarray(_pad_stack([ga.tw_word for ga in gas], T_pad)),
            tw_cnt=jnp.asarray(
                _pad_stack([ga.tw_cnt for ga in gas], T_pad,
                           dtype=np.float32)),
            fedge_file=jnp.asarray(
                _pad_stack([ga.fedge_file for ga in gas], Ef_pad)),
            fedge_child=jnp.asarray(
                _pad_stack([ga.fedge_child for ga in gas], Ef_pad)),
            fedge_freq=jnp.asarray(
                _pad_stack([ga.fedge_freq for ga in gas], Ef_pad,
                           dtype=np.float32)),
            fword_file=jnp.asarray(
                _pad_stack([ga.fword_file for ga in gas], Tf_pad)),
            fword_word=jnp.asarray(
                _pad_stack([ga.fword_word for ga in gas], Tf_pad)),
            fword_cnt=jnp.asarray(
                _pad_stack([ga.fword_cnt for ga in gas], Tf_pad,
                           dtype=np.float32)),
            lv_parent=jnp.asarray(lv_parent),
            lv_child=jnp.asarray(lv_child),
            lv_freq=jnp.asarray(lv_freq),
            lv_slices=tuple(lv_slices),
        )


# ----------------------------------------------------------------------- #
# Batched traversals                                                       #
# ----------------------------------------------------------------------- #
@functools.lru_cache(maxsize=None)
def _sharded_program(fn, mesh, in_ndims: Tuple[int, ...],
                     out_ndim: Union[int, Tuple[int, ...]],
                     static: Tuple[Tuple[str, Any], ...] = ()):
    """``jit(shard_map(fn))`` splitting every array's leading corpus axis.

    Each shard runs ``fn`` — the exact single-device program — on its
    ``[N/D, ...]`` row slice; nothing crosses shards, so a frontier
    ``while_loop`` inside ``fn`` stops as soon as the shard's own corpora
    finish instead of spinning until the globally slowest one does.
    Memoized per (fn, mesh, shapes, statics) so recurring sharded calls
    reach jit's compile cache instead of rebuilding a fresh (cache-missing)
    wrapper each time; ``static`` binds hashable keyword args (level
    schedules, padded dims) before wrapping.  ``out_ndim`` may be a tuple
    of ranks for functions returning several row-sharded arrays (the
    search scorer returns top-k values + indices).
    """
    bound = functools.partial(fn, **dict(static)) if static else fn

    def spec(nd: int) -> P:
        return P(CORPUS_AXIS, *([None] * (nd - 1)))

    out_specs = (tuple(spec(nd) for nd in out_ndim)
                 if isinstance(out_ndim, tuple) else spec(out_ndim))
    sm = shard_map(bound, mesh=mesh,
                   in_specs=tuple(spec(nd) for nd in in_ndims),
                   out_specs=out_specs, check_rep=False)
    return jax.jit(sm)


def _frontier_weights_impl(ep, ec, ef, valid, in_deg):
    """vmap of the masked frontier rounds; one shared while_loop.

    The vmapped ``while_loop`` runs until every corpus's mask is empty;
    corpora that finish early keep executing no-op rounds (their ``mask``
    is all-False, so delta and seen are zero and the state is a fixpoint).
    """
    R = in_deg.shape[1]

    def one(ep, ec, ef, valid, in_deg):
        def cond(state):
            _, _, mask, _ = state
            return jnp.any(mask)

        def body(state):
            weight, cur_in, mask, ever = state
            active_e = mask[ep] & valid
            contrib = jnp.where(active_e, ef * weight[ep], 0.0)
            delta = jax.ops.segment_sum(contrib, ec, num_segments=R)
            seen = jax.ops.segment_sum(active_e.astype(jnp.int32), ec,
                                       num_segments=R)
            weight = weight + delta
            cur_in = cur_in + seen
            new_ready = (cur_in == in_deg) & (~ever)
            return weight, cur_in, new_ready, ever | new_ready

        weight0 = jnp.zeros(R, jnp.float32).at[0].set(1.0)
        mask0 = (in_deg == 0)
        state = (weight0, jnp.zeros(R, jnp.int32), mask0, mask0)
        weight, _, _, _ = jax.lax.while_loop(cond, body, state)
        return weight

    return jax.vmap(one)(ep, ec, ef, valid, in_deg)


_frontier_weights_batched = jax.jit(_frontier_weights_impl)


def _leveled_weights_impl(ep, ec, ef, slices, R):
    """Shared static level schedule; each real edge touched exactly once
    (padded slots have freq 0)."""
    N = ep.shape[0]
    w = jnp.zeros((N, R), jnp.float32).at[:, 0].set(1.0)
    seg = jax.vmap(lambda c, i: jax.ops.segment_sum(c, i, num_segments=R))
    for (s, e) in slices:
        if s == e:
            continue
        contrib = ef[:, s:e] * jnp.take_along_axis(w, ep[:, s:e], axis=1)
        w = w + seg(contrib, ec[:, s:e])
    return w


_leveled_weights_batched = jax.jit(_leveled_weights_impl,
                                   static_argnames=("slices", "R"))


# Methods that run on the dense ELL plan, and the segment_sum bases an
# ineligible request degrades to.  ``resolve_traversal_method`` is the ONE
# place the gates live: the engines dispatch on its answer and the serving
# layer compares requested-vs-resolved to count downgrades
# (ServerStats.method_fallbacks) instead of remapping silently.
ELL_METHODS = ("frontier_ell", "leveled_ell", "frontier_fused")
SEGMENT_SUM_BASES = {"frontier_ell": "frontier", "frontier_fused": "frontier",
                     "leveled_ell": "leveled"}
# Kinds whose traversal carries the [R, F] per-file payload (the rest use
# the scalar weight vector).  Search kinds feed from batched_term_vector,
# so they are per-file too (serving/analytics_server.py extends this set).
PER_FILE_KINDS = ("term_vector", "inverted_index", "ranked_inverted_index")


def resolve_traversal_method(method: str, *, n: int, rows: int, k: int,
                             edges: int, shards: int = 1,
                             per_file: bool = False, f: int = 1) -> str:
    """Resolve a requested traversal method against the pack's shape gates.

    Pure over dimensions (n/rows/k are the pack's N, R_pad and ELL plan
    width; ``f`` is F_pad for per-file traversals) so the serving layer can
    predict the engine's routing without building a plan.  Rules:

    * ``auto`` — occupancy dispatch (kernels.ops.ell_batched_use_ref, per
      shard), then the fused path when the scalar state fits VMEM;
    * explicit ELL methods degrade to their segment_sum base when the dense
      plan itself is ineligible (width / absolute-entry safety valves, and
      the vector-payload budget for per-file traversals);
    * ``frontier_fused`` degrades to ``frontier_ell`` (still an ELL base —
      NOT a fallback) when the fused state exceeds VMEM residency or the
      traversal is per-file (the fused kernel is scalar-payload).
    """
    from repro.kernels import ops as kops

    if method == "auto":
        if kops.ell_batched_use_ref(edges, n, rows, k, shards=shards):
            return "frontier"
        if per_file:
            if not kops.ell_vector_plan_ok(n, rows, k, f):
                return "frontier"
            return "frontier_ell"
        if kops.ell_fused_use_kernel(rows):
            return "frontier_fused"
        return "frontier_ell"
    if method in ELL_METHODS:
        # safety valves even when ELL is requested explicitly: a skewed
        # grammar (hub rule with huge in-degree) or a huge sparse one
        # (many rules x a moderate hub's K) would make the dense plan
        # O(N * R_pad * K) memory — fall back to the segment_sum base
        # (identical results).
        if (k > kops.ELL_BATCH_MAX_WIDTH
                or n * rows * k > kops.ELL_PLAN_MAX_ENTRIES):
            return SEGMENT_SUM_BASES[method]
        if per_file:
            if not kops.ell_vector_plan_ok(n, rows, k, f):
                return SEGMENT_SUM_BASES[method]
            if method == "frontier_fused":
                return "frontier_ell"
        elif method == "frontier_fused":
            if not kops.ell_fused_use_kernel(rows):
                return "frontier_ell"
    return method


def is_segment_sum_fallback(requested: str, resolved: str) -> bool:
    """True when an explicitly-requested ELL-family method landed on a
    segment_sum base (the downgrade ServerStats.method_fallbacks counts)."""
    return requested in ELL_METHODS and resolved in ("frontier", "leveled")


def resolve_batch_method(gb: "GrammarBatch", method: str,
                         per_file: bool = False) -> str:
    """`resolve_traversal_method` with the dims read off a built pack."""
    if method != "auto" and method not in ELL_METHODS:
        return method
    return resolve_traversal_method(
        method, n=gb.n, rows=gb.R_pad, k=gb.ell_plan_width(),
        edges=gb.total_edges, shards=gb.shards, per_file=per_file,
        f=gb.F_pad)


def _frontier_ell_impl(ell_src, ell_freq, in_deg):
    """Masked frontier rounds over the dense ELL plan: every round is ONE
    fused gather + row-sum (no scatter), with delta and the seen-counter
    emitted by the same kernels.ops.ell_propagate_batched call."""
    from repro.kernels import ops as kops

    N, R = in_deg.shape

    def cond(state):
        _, _, mask, _ = state
        return jnp.any(mask)

    def body(state):
        weight, cur_in, mask, ever = state
        delta, seen = kops.ell_propagate_batched(
            weight, mask.astype(jnp.float32), ell_src, ell_freq)
        weight = weight + delta
        cur_in = cur_in + seen.astype(jnp.int32)
        new_ready = (cur_in == in_deg) & (~ever)
        return weight, cur_in, new_ready, ever | new_ready

    weight0 = jnp.zeros((N, R), jnp.float32).at[:, 0].set(1.0)
    mask0 = (in_deg == 0)
    state = (weight0, jnp.zeros((N, R), jnp.int32), mask0, mask0)
    weight, _, _, _ = jax.lax.while_loop(cond, body, state)
    return weight


_frontier_weights_batched_ell = jax.jit(_frontier_ell_impl)


def _leveled_ell_impl(ell_src, ell_freq, level, num_levels):
    """Static level schedule over the dense ELL plan: level lv's round
    activates exactly the parents at that level, so each real edge
    contributes once, at its parent's level (padded slots: level -1)."""
    from repro.kernels import ops as kops

    N, R = level.shape
    w = jnp.zeros((N, R), jnp.float32).at[:, 0].set(1.0)
    for lv in range(num_levels):
        active = (level == lv).astype(jnp.float32)
        delta, _ = kops.ell_propagate_batched(w, active, ell_src, ell_freq)
        w = w + delta
    return w


_leveled_weights_batched_ell = jax.jit(_leveled_ell_impl,
                                       static_argnames=("num_levels",))


def _frontier_fused_impl(ell_src, ell_freq, in_deg, num_levels):
    """The whole frontier loop in ONE dispatch (kernels.ops dispatches to
    the fused Pallas kernel on TPU / the jitted fori_loop form on CPU).

    ``num_levels`` — the pack's max DAG depth — is the exact round count
    the while_loop form executes (level-L rules activate in round L+1), so
    the static bound loses nothing; corpora shallower than the deepest one
    converge early and their remaining rounds are exact no-ops.  This
    replaces the per-round while_loop -> kernel -> XLA round-trip
    ("the structural tax"): one launch instead of num_levels launches.
    """
    from repro.kernels import ops as kops

    N, R = in_deg.shape
    w0 = jnp.zeros((N, R), jnp.float32).at[:, 0].set(1.0)
    return kops.ell_frontier_fused(w0, in_deg.astype(jnp.float32),
                                   ell_src, ell_freq, num_levels)


_frontier_fused_batched = jax.jit(_frontier_fused_impl,
                                  static_argnames=("num_levels",))


def batched_top_down_weights(gb: GrammarBatch,
                             method: str = "frontier") -> jnp.ndarray:
    """weights[i, r] == occurrences of corpus i's rule r. Shape [N, R_pad].

    Methods: ``frontier`` / ``leveled`` (COO + segment_sum),
    ``frontier_ell`` / ``leveled_ell`` (dense ELL plan, scatter-free,
    per-round), ``frontier_fused`` (the ELL frontier loop in ONE dispatch —
    kernels/propagate_fused.py), and ``auto`` (occupancy dispatch via
    ``resolve_traversal_method``: ELL when the plan is dense enough, fused
    when the state fits VMEM).  Sharded packs (``gb.mesh``) run the same
    methods through ``shard_map`` — each device traverses its own corpus
    rows (module DESIGN note), results bit-identical to the unsharded
    program.
    """
    method = resolve_batch_method(gb, method)
    if method in ("frontier", "top_down", "bottom_up"):
        if gb.mesh is not None:
            return _sharded_program(_frontier_weights_impl, gb.mesh,
                                    (2, 2, 2, 2, 2), 2)(
                gb.edge_parent, gb.edge_child, gb.edge_freq, gb.edge_valid,
                gb.in_deg)
        return _frontier_weights_batched(
            gb.edge_parent, gb.edge_child, gb.edge_freq, gb.edge_valid,
            gb.in_deg)
    if method == "leveled":
        if gb.mesh is not None:
            return _sharded_program(
                _leveled_weights_impl, gb.mesh, (2, 2, 2), 2,
                static=(("slices", gb.lv_slices), ("R", gb.R_pad)))(
                gb.lv_parent, gb.lv_child, gb.lv_freq)
        return _leveled_weights_batched(
            gb.lv_parent, gb.lv_child, gb.lv_freq, gb.lv_slices, gb.R_pad)
    if method == "frontier_ell":
        src, freq, _, _ = gb.ell_plan()
        if gb.mesh is not None:
            return _sharded_program(_frontier_ell_impl, gb.mesh,
                                    (3, 3, 2), 2)(src, freq, gb.in_deg)
        return _frontier_weights_batched_ell(src, freq, gb.in_deg)
    if method == "leveled_ell":
        src, freq, level, num_levels = gb.ell_plan()
        if gb.mesh is not None:
            return _sharded_program(
                _leveled_ell_impl, gb.mesh, (3, 3, 2), 2,
                static=(("num_levels", num_levels),))(src, freq, level)
        return _leveled_weights_batched_ell(src, freq, level, num_levels)
    if method == "frontier_fused":
        src, freq, _, num_levels = gb.ell_plan()
        if gb.mesh is not None:
            return _sharded_program(
                _frontier_fused_impl, gb.mesh, (3, 3, 2), 2,
                static=(("num_levels", num_levels),))(src, freq, gb.in_deg)
        return _frontier_fused_batched(src, freq, gb.in_deg,
                                       num_levels=num_levels)
    raise ValueError(f"unknown batched traversal method {method!r}")


def _per_file_frontier_impl(ep, ec, ef, valid, in_deg, root_seen,
                            fedge_child, fedge_file, fedge_freq, F):
    R = in_deg.shape[1]

    def one(ep, ec, ef, valid, in_deg, root_seen, fc, ff, fq):
        W0 = jnp.zeros((R, F), jnp.float32).at[fc, ff].add(fq)

        def cond(state):
            _, _, mask, _ = state
            return jnp.any(mask)

        def body(state):
            W, cur_in, mask, ever = state
            active_e = mask[ep] & valid & (ep != 0)
            gathered = W[ep, :] * ef[:, None]
            gathered = jnp.where(active_e[:, None], gathered, 0.0)
            delta = jax.ops.segment_sum(gathered, ec, num_segments=R)
            seen = jax.ops.segment_sum(active_e.astype(jnp.int32), ec,
                                       num_segments=R)
            W = W + delta
            cur_in = cur_in + seen
            new_ready = (cur_in == in_deg) & (~ever)
            return W, cur_in, new_ready, ever | new_ready

        mask0 = (root_seen == in_deg) & (in_deg > 0)
        state = (W0, root_seen, mask0, mask0 | (in_deg == 0))
        W, _, _, _ = jax.lax.while_loop(cond, body, state)
        return W

    return jax.vmap(one)(ep, ec, ef, valid, in_deg, root_seen,
                         fedge_child, fedge_file, fedge_freq)


_per_file_weights_batched = jax.jit(_per_file_frontier_impl,
                                    static_argnames=("F",))


def _per_file_leveled_impl(ep, ec, ef, fedge_child, fedge_file,
                           fedge_freq, slices, R, F):
    """Leveled per-file traversal: root edges are consumed by the per-file
    init (splitter segments), so every non-root edge is touched once.
    Padded slots have ``parent == 0`` and are excluded by the same gate."""
    N = ep.shape[0]
    W = jax.vmap(
        lambda fc, ff, fq: jnp.zeros((R, F), jnp.float32).at[fc, ff].add(fq)
    )(fedge_child, fedge_file, fedge_freq)
    seg = jax.vmap(lambda c, i: jax.ops.segment_sum(c, i, num_segments=R))
    for (s, e) in slices:
        if s == e:
            continue
        keep = (ep[:, s:e] != 0).astype(jnp.float32)
        gathered = jnp.take_along_axis(W, ep[:, s:e, None], axis=1)  # [N,w,F]
        contrib = gathered * (ef[:, s:e] * keep)[:, :, None]
        W = W + seg(contrib, ec[:, s:e])
    return W


_per_file_leveled_batched = jax.jit(_per_file_leveled_impl,
                                    static_argnames=("slices", "R", "F"))


def _per_file_frontier_ell_impl(ell_src, ell_freq, in_deg, root_seen,
                                fedge_child, fedge_file, fedge_freq, F):
    """Per-file frontier rounds over the dense ELL plan with the VECTOR
    payload round (kernels.ops.ell_propagate_vector).  Root-edge exclusion
    is structural: the root has in_deg == 0 so it enters ``ever`` at init
    and its mask entry is never 1 — plan entries with src == 0 contribute
    nothing, exactly the ``ep != 0`` gate of the COO form (root edges are
    consumed by the per-file init and pre-counted in ``root_seen``)."""
    from repro.kernels import ops as kops

    R = in_deg.shape[1]

    def cond(state):
        _, _, mask, _ = state
        return jnp.any(mask)

    def body(state):
        W, cur_in, mask, ever = state
        delta, seen = kops.ell_propagate_vector(
            W, mask.astype(jnp.float32), ell_src, ell_freq)
        W = W + delta
        cur_in = cur_in + seen.astype(jnp.int32)
        new_ready = (cur_in == in_deg) & (~ever)
        return W, cur_in, new_ready, ever | new_ready

    W0 = jax.vmap(
        lambda fc, ff, fq: jnp.zeros((R, F), jnp.float32).at[fc, ff].add(fq)
    )(fedge_child, fedge_file, fedge_freq.astype(jnp.float32))
    mask0 = (root_seen == in_deg) & (in_deg > 0)
    state = (W0, root_seen, mask0, mask0 | (in_deg == 0))
    W, _, _, _ = jax.lax.while_loop(cond, body, state)
    return W


_per_file_ell_batched = jax.jit(_per_file_frontier_ell_impl,
                                static_argnames=("F",))


def _per_file_leveled_ell_impl(ell_src, ell_freq, level, fedge_child,
                               fedge_file, fedge_freq, num_levels, F):
    """Leveled per-file traversal over the dense ELL plan: level lv's
    vector round activates exactly the parents at that level.  The root
    (rule 0, level 0) is masked out — its edges are consumed by the
    per-file init, like the COO form's ``parent != 0`` gate."""
    from repro.kernels import ops as kops

    R = level.shape[1]
    W = jax.vmap(
        lambda fc, ff, fq: jnp.zeros((R, F), jnp.float32).at[fc, ff].add(fq)
    )(fedge_child, fedge_file, fedge_freq.astype(jnp.float32))
    nonroot = (jnp.arange(R) > 0)[None, :]
    for lv in range(num_levels):
        active = ((level == lv) & nonroot).astype(jnp.float32)
        delta, _ = kops.ell_propagate_vector(W, active, ell_src, ell_freq)
        W = W + delta
    return W


_per_file_leveled_ell_batched = jax.jit(_per_file_leveled_ell_impl,
                                        static_argnames=("num_levels", "F"))


def batched_per_file_weights(gb: GrammarBatch,
                             method: str = "frontier") -> jnp.ndarray:
    """Wf[i, r, f] == occurrences of rule r inside file f of corpus i.

    The ELL methods run the vector-payload [R, F] rounds
    (kernels/propagate_vector.py) over the same dense edge plan as the
    scalar traversals — no more silent remap to the segment_sum bases
    (``resolve_batch_method`` still degrades ineligible plans, and the
    serving layer counts those downgrades).  ``frontier_fused`` runs its
    per-round ELL base here (the fused kernel is scalar-payload).  Sharded
    packs run through ``shard_map`` like the scalar traversals.
    """
    method = resolve_batch_method(gb, method, per_file=True)
    if method in ("frontier", "top_down", "bottom_up"):
        if gb.mesh is not None:
            return _sharded_program(
                _per_file_frontier_impl, gb.mesh,
                (2, 2, 2, 2, 2, 2, 2, 2, 2), 3,
                static=(("F", gb.F_pad),))(
                gb.edge_parent, gb.edge_child, gb.edge_freq, gb.edge_valid,
                gb.in_deg, gb.root_seen, gb.fedge_child, gb.fedge_file,
                gb.fedge_freq)
        return _per_file_weights_batched(
            gb.edge_parent, gb.edge_child, gb.edge_freq, gb.edge_valid,
            gb.in_deg, gb.root_seen, gb.fedge_child, gb.fedge_file,
            gb.fedge_freq, gb.F_pad)
    if method == "leveled":
        if gb.mesh is not None:
            return _sharded_program(
                _per_file_leveled_impl, gb.mesh, (2, 2, 2, 2, 2, 2), 3,
                static=(("slices", gb.lv_slices), ("R", gb.R_pad),
                        ("F", gb.F_pad)))(
                gb.lv_parent, gb.lv_child, gb.lv_freq, gb.fedge_child,
                gb.fedge_file, gb.fedge_freq)
        return _per_file_leveled_batched(
            gb.lv_parent, gb.lv_child, gb.lv_freq, gb.fedge_child,
            gb.fedge_file, gb.fedge_freq, gb.lv_slices, gb.R_pad, gb.F_pad)
    if method == "frontier_ell":
        src, freq, _, _ = gb.ell_plan()
        if gb.mesh is not None:
            return _sharded_program(
                _per_file_frontier_ell_impl, gb.mesh,
                (3, 3, 2, 2, 2, 2, 2), 3, static=(("F", gb.F_pad),))(
                src, freq, gb.in_deg, gb.root_seen, gb.fedge_child,
                gb.fedge_file, gb.fedge_freq)
        return _per_file_ell_batched(
            src, freq, gb.in_deg, gb.root_seen, gb.fedge_child,
            gb.fedge_file, gb.fedge_freq, gb.F_pad)
    if method == "leveled_ell":
        src, freq, level, num_levels = gb.ell_plan()
        if gb.mesh is not None:
            return _sharded_program(
                _per_file_leveled_ell_impl, gb.mesh,
                (3, 3, 2, 2, 2, 2), 3,
                static=(("num_levels", num_levels), ("F", gb.F_pad)))(
                src, freq, level, gb.fedge_child, gb.fedge_file,
                gb.fedge_freq)
        return _per_file_leveled_ell_batched(
            src, freq, level, gb.fedge_child, gb.fedge_file, gb.fedge_freq,
            num_levels, gb.F_pad)
    raise ValueError(f"unknown batched traversal method {method!r}")


# ----------------------------------------------------------------------- #
# Batched analytics (the six CompressDirect apps)                          #
# ----------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("V",))
def _word_count_from_weights(w, tw_rule, tw_word, tw_cnt, V):
    vals = tw_cnt * jnp.take_along_axis(w, tw_rule, axis=1)
    return jax.vmap(
        lambda i, v: jax.ops.segment_sum(v, i, num_segments=V))(tw_word, vals)


def batched_word_count(gb: GrammarBatch, method: str = "frontier",
                       backend: str = "jnp") -> jnp.ndarray:
    """counts[i, v] for every corpus in one jitted call. Shape [N, V_pad]."""
    w = batched_top_down_weights(gb, method=method)
    if backend == "pallas":
        from repro.kernels import ops as kops
        vals = gb.tw_cnt * jnp.take_along_axis(w, gb.tw_rule, axis=1)
        return kops.weighted_bincount_batched(gb.tw_word, vals, gb.V_pad)
    return _word_count_from_weights(w, gb.tw_rule, gb.tw_word, gb.tw_cnt,
                                    gb.V_pad)


def batched_sort_words(gb: GrammarBatch, method: str = "frontier",
                       backend: str = "jnp"
                       ) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Per corpus (word_ids, counts) sorted by frequency desc — the heavy
    reduction is batched; the final per-corpus argsort runs on true sizes so
    results match :func:`repro.core.analytics.sort_words` exactly."""
    wc = batched_word_count(gb, method=method, backend=backend)
    out = []
    for i, ga in enumerate(gb.real_gas):
        counts = wc[i, : ga.vocab_size]
        order = jnp.argsort(-counts, stable=True)
        out.append((order, counts[order]))
    return out


@functools.partial(jax.jit, static_argnames=("V",))
def _term_vector_from_weights(Wf, tw_rule, tw_word, tw_cnt,
                              fword_file, fword_word, fword_cnt, V):
    def one(Wf, tr, twd, tc, ff, fw, fc):
        contrib = Wf[tr, :] * tc[:, None]                       # [T, F]
        tv = jax.ops.segment_sum(contrib, twd, num_segments=V)  # [V, F]
        tv = tv.T
        return tv.at[ff, fw].add(fc)

    return jax.vmap(one)(Wf, tw_rule, tw_word, tw_cnt,
                         fword_file, fword_word, fword_cnt)


def batched_term_vector(gb: GrammarBatch,
                        method: str = "frontier") -> jnp.ndarray:
    """tv[i, f, v] — dense per-file counts, all corpora in one call."""
    Wf = batched_per_file_weights(gb, method=method)
    return _term_vector_from_weights(
        Wf, gb.tw_rule, gb.tw_word, gb.tw_cnt,
        gb.fword_file, gb.fword_word, gb.fword_cnt, gb.V_pad)


def batched_inverted_index(gb: GrammarBatch,
                           method: str = "frontier") -> jnp.ndarray:
    return batched_term_vector(gb, method=method) > 0


def batched_ranked_inverted_index(gb: GrammarBatch, method: str = "frontier"
                                  ) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Per corpus (ranking [V, F], counts [V, F]) — batched traversal, true
    per-corpus shapes out (matches the single-corpus function exactly)."""
    tv = batched_term_vector(gb, method=method)
    out = []
    for i, ga in enumerate(gb.real_gas):
        tvi = tv[i, : ga.num_files, : ga.vocab_size]
        order = jnp.argsort(-tvi, axis=0, stable=True)
        ranked = jnp.take_along_axis(tvi, order, axis=0)
        out.append((order.T, ranked.T))
    return out


def unbatch(gb: GrammarBatch, packed: jnp.ndarray,
            kind: str = "word_count") -> List[np.ndarray]:
    """Slice a packed ``[N, ...]`` result back to per-corpus true shapes
    (shard-padding rows, if any, are dropped)."""
    out = []
    for i, ga in enumerate(gb.real_gas):
        if kind == "word_count":
            out.append(np.asarray(packed[i, : ga.vocab_size]))
        elif kind in ("term_vector", "inverted_index"):
            out.append(np.asarray(packed[i, : ga.num_files, : ga.vocab_size]))
        else:
            raise ValueError(f"cannot unbatch kind {kind!r}")
    return out


# ----------------------------------------------------------------------- #
# Batched sequence count (paper §IV-D across corpora)                      #
# ----------------------------------------------------------------------- #
@jax.jit
def _resolve_buffers_batched(is_lit, lit, src, idx, dep):
    R = is_lit.shape[1]

    def one(is_lit, lit, src, idx, dep):
        leaf = (dep < 0).all(axis=1)
        buf0 = jnp.where(is_lit, lit, -1)

        def cond(state):
            _, ready, prev = state
            return jnp.any(ready != prev)

        def body(state):
            buf, ready, _ = state
            dep_ok = jnp.where(dep < 0, True,
                               ready[jnp.clip(dep, 0, R - 1)]).all(axis=1)
            newly = dep_ok & (~ready)
            gathered = jnp.where(is_lit, lit, buf[src, idx])
            buf = jnp.where(newly[:, None], gathered, buf)
            return buf, ready | newly, ready

        buf, _, _ = jax.lax.while_loop(
            cond, body, (buf0, leaf, jnp.zeros(R, bool)))
        return buf

    return jax.vmap(one)(is_lit, lit, src, idx, dep)


@functools.partial(jax.jit, static_argnames=("l",))
def _count_windows_batched(head, tail, weights, st_kind, st_lit, st_src,
                           st_idx, st_symj, win_start, win_rule, win_valid,
                           l):
    def one(head, tail, w, kind, lit, src, idx, symj, ws, wr, wv):
        tok = jnp.where(kind == _K_LIT, lit,
                        jnp.where(kind == _K_HEAD, head[src, idx],
                                  jnp.where(kind == _K_TAIL,
                                            tail[src, idx], lit)))
        pos = ws[:, None] + jnp.arange(l)[None, :]
        wtok = tok[pos]                                   # [Nw, l]
        wsym = symj[pos]
        valid = (wtok >= 0).all(axis=1) & (wsym[:, 0] != wsym[:, -1]) & wv
        wweight = jnp.where(valid, w[wr], 0.0)
        order = jnp.lexsort(tuple(wtok[:, c] for c in range(l - 1, -1, -1)))
        stok = wtok[order]
        sw = wweight[order]
        newseg = jnp.concatenate([
            jnp.array([True]),
            (stok[1:] != stok[:-1]).any(axis=1)])
        seg = jnp.cumsum(newseg) - 1
        counts = jax.ops.segment_sum(sw, seg, num_segments=stok.shape[0])
        return stok, seg, counts

    return jax.vmap(one)(head, tail, weights, st_kind, st_lit, st_src,
                         st_idx, st_symj, win_start, win_rule, win_valid)


def _padded_sequence_plans(gb: GrammarBatch, l: int):
    """Host-side planning + padding + resolved head/tail buffers, memoized
    per (batch, l): the serving layer reuses packed batches across query
    groups, so repeat sequence_count traffic pays the planning once."""
    if l in gb._plan_cache:
        return gb._plan_cache[l]
    with _plan_stage("sequence"):
        gb._plan_cache[l] = _build_sequence_plans(gb, l)
    return gb._plan_cache[l]


def _build_sequence_plans(gb: GrammarBatch, l: int):
    N = gb.n
    h = l - 1
    htps = [_sequence.plan_head_tail(ga, l) for ga in gb.gas]
    sps = [_sequence.plan_stream(ga, l) for ga in gb.gas]

    R_pad = gb.R_pad
    # bucket the data-dependent plan widths like the pack dims: packs with
    # equal signatures then reuse the jitted resolve/count programs across
    # corpus compositions instead of compiling per exact max-width
    Kd = _round_up_pow2(
        max(max(p.head_dep.shape[1], p.tail_dep.shape[1]) for p in htps), 1)

    def _stack_plan(get_arr, fill, dtype, width2):
        out = np.full((N, R_pad, width2), fill, dtype)
        for i, p in enumerate(htps):
            a = get_arr(p)
            out[i, : a.shape[0], : a.shape[1]] = a
        return gb._place(out)

    def _resolve(side: str) -> jnp.ndarray:
        return _resolve_buffers_batched(
            _stack_plan(lambda p: getattr(p, f"{side}_is_lit"), False, bool, h),
            _stack_plan(lambda p: getattr(p, f"{side}_lit"), -1, np.int32, h),
            _stack_plan(lambda p: getattr(p, f"{side}_src"), 0, np.int32, h),
            _stack_plan(lambda p: getattr(p, f"{side}_idx"), 0, np.int32, h),
            _stack_plan(lambda p: getattr(p, f"{side}_dep"), -1, np.int32, Kd))

    head = _resolve("head")
    tail = _resolve("tail")

    S_pad = _round_up_pow2(max(max(len(p.st_kind) for p in sps), l), 1)
    W_pad = _round_up_pow2(max(max(len(p.win_start) for p in sps), 1), 1)
    win_valid = np.zeros((N, W_pad), bool)
    for i, p in enumerate(sps):
        win_valid[i, : len(p.win_start)] = True
    stream = (
        gb._place(_pad_stack([p.st_kind for p in sps], S_pad,
                             fill=_sequence._K_BREAK, dtype=np.int8)),
        gb._place(_pad_stack([p.st_lit for p in sps], S_pad,
                             fill=_sequence._BREAK)),
        gb._place(_pad_stack([p.st_src for p in sps], S_pad)),
        gb._place(_pad_stack([p.st_idx for p in sps], S_pad)),
        gb._place(_pad_stack([p.st_symj for p in sps], S_pad)),
        gb._place(_pad_stack([p.win_start for p in sps], W_pad)),
        gb._place(_pad_stack([p.win_rule for p in sps], W_pad)),
        gb._place(win_valid))
    return (head, tail, stream)


def batched_sequence_count(gb: GrammarBatch, l: int = 3,
                           method: str = "frontier"
                           ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Per corpus (grams [U, l], counts [U]) — head/tail resolution, stream
    gathers, window sorting and segment reduction all run batched; only the
    final distinct-gram extraction is per corpus (ragged output)."""
    if l < 2:
        raise ValueError("sequence_count needs l >= 2")
    weights = batched_top_down_weights(gb, method=method)
    head, tail, stream = _padded_sequence_plans(gb, l)
    stok, seg, counts = _count_windows_batched(head, tail, weights,
                                               *stream, l)

    stok_h = np.asarray(stok)
    seg_h = np.asarray(seg)
    counts_h = np.asarray(counts)
    out: List[Tuple[np.ndarray, np.ndarray]] = []
    for i in range(gb.real):
        n_seg = int(seg_h[i, -1]) + 1
        first_idx = np.searchsorted(seg_h[i], np.arange(n_seg), "left")
        grams = stok_h[i][first_idx]
        cnts = counts_h[i, :n_seg]
        keep = cnts > 0           # padded / invalid windows carry zero weight
        out.append((grams[keep].astype(np.int32), cnts[keep]))
    return out


# ----------------------------------------------------------------------- #
# Convenience: run any of the six analytics batched, per-corpus results    #
# ----------------------------------------------------------------------- #
ANALYTICS_KINDS = ("word_count", "sort", "inverted_index", "term_vector",
                   "sequence_count", "ranked_inverted_index")


def run_batched(gb: GrammarBatch, kind: str, method: str = "frontier",
                backend: str = "jnp", l: int = 3) -> List:
    """Dispatch one analytics kind over the whole batch; returns a list of
    per-corpus results shaped exactly like the single-corpus functions."""
    if kind == "word_count":
        return unbatch(gb, batched_word_count(gb, method=method,
                                              backend=backend), "word_count")
    if kind == "sort":
        return [(np.asarray(o), np.asarray(c))
                for (o, c) in batched_sort_words(gb, method=method,
                                                 backend=backend)]
    if kind == "term_vector":
        return unbatch(gb, batched_term_vector(gb, method=method),
                       "term_vector")
    if kind == "inverted_index":
        return unbatch(gb, batched_inverted_index(gb, method=method),
                       "inverted_index")
    if kind == "ranked_inverted_index":
        return [(np.asarray(r), np.asarray(c))
                for (r, c) in batched_ranked_inverted_index(gb,
                                                            method=method)]
    if kind == "sequence_count":
        return batched_sequence_count(gb, l=l, method=method)
    raise ValueError(f"unknown analytics kind {kind!r}; "
                     f"expected one of {ANALYTICS_KINDS}")
