"""G-TADOC DAG traversals in JAX (the paper's §IV-B execution engine).

The paper's fine-grained GPU scheduling assigns one thread per rule with a
per-rule ``mask``, in/out-edge counters, and a host loop that relaunches the
kernel until a ``stopFlag`` says the DAG is exhausted (Algorithms 1 and 2).

TPU adaptation (DESIGN.md §2): a "thread" becomes a vector lane.  Each
relaunch round becomes one dense gather + segment-reduce over *all* edges,
gated by the mask — identical schedule, identical results, but expressed as
SpMV-shaped ops the VPU/MXU like.  The host relaunch loop becomes
``jax.lax.while_loop`` (the stop flag is ``mask.any()``).

Two engines are provided:

* ``frontier``  — paper-faithful masked rounds (Algorithm 1/2 semantics).
* ``leveled``   — beyond-paper optimization: topological levels are known
  statically (host precomputes them in grammar.py), so each edge is touched
  exactly once, in level order, with zero mask bookkeeping.  This removes
  the O(E) per-round re-scan the masked design pays (see EXPERIMENTS.md
  §Perf/core).

Both produce bit-identical results (tests/test_traversal.py).
"""

from __future__ import annotations

import functools
import weakref
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .grammar import GrammarArrays, pow2_bucket as _pow2_bucket


# ----------------------------------------------------------------------- #
# Top-down: rule weights (occurrence counts of each rule in the corpus).   #
# ----------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("num_rules",))
def _top_down_frontier(edge_parent: jnp.ndarray, edge_child: jnp.ndarray,
                       edge_freq: jnp.ndarray, in_deg: jnp.ndarray,
                       num_rules: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Masked top-down rounds (paper Algorithm 1). Returns (weights, rounds)."""
    R = num_rules
    dtype = jnp.float32

    def cond(state):
        _, _, mask, _, _ = state
        return jnp.any(mask)

    def body(state):
        weight, cur_in, mask, ever, rounds = state
        active_e = mask[edge_parent]
        contrib = jnp.where(active_e, edge_freq.astype(dtype) * weight[edge_parent], 0.0)
        delta = jax.ops.segment_sum(contrib, edge_child, num_segments=R)
        seen = jax.ops.segment_sum(active_e.astype(jnp.int32), edge_child,
                                   num_segments=R)
        weight = weight + delta
        cur_in = cur_in + seen
        new_ready = (cur_in == in_deg) & (~ever)
        return weight, cur_in, new_ready, ever | new_ready, rounds + 1

    weight0 = jnp.zeros(R, dtype).at[0].set(1.0)
    cur0 = jnp.zeros(R, jnp.int32)
    mask0 = (in_deg == 0)                      # root (and only root)
    state = (weight0, cur0, mask0, mask0, jnp.int32(0))
    weight, _, _, _, rounds = jax.lax.while_loop(cond, body, state)
    return weight, rounds


def top_down_weights(ga: GrammarArrays, method: str = "frontier") -> jnp.ndarray:
    """weights[r] == number of times rule r's expansion occurs in the corpus."""
    if method in ("frontier", "top_down", "bottom_up"):
        # Direction selection affects the *analytics* data flow; the weight
        # pass itself is always top-down (weights are defined root-down).
        w, _ = _top_down_frontier(
            jnp.asarray(ga.edge_parent), jnp.asarray(ga.edge_child),
            jnp.asarray(ga.edge_freq), jnp.asarray(ga.in_deg), ga.num_rules)
        return w
    if method in ("leveled", "leveled_ell"):
        # single-corpus leveled: the per-level segments are already gathers
        # over tiny slices; the dense ELL replay only pays off batched.
        return _top_down_leveled(ga)
    if method == "frontier_ell":
        return _top_down_frontier_ell(ga)
    if method == "frontier_fused":
        return _top_down_frontier_fused(ga)
    raise ValueError(f"unknown traversal method {method!r}")


def resolve_single_method(ga: GrammarArrays, method: str,
                          per_file: bool = False) -> str:
    """Predict the single-corpus engine's routing for ``method`` — the N=1
    analogue of :func:`repro.core.batch.resolve_batch_method`, so the
    serving layer can count ELL→segment_sum downgrades on the per-corpus
    path too.  Mirrors the actual dispatch: scalar ``leveled_ell`` always
    runs the N=1 leveled replay (see :func:`top_down_weights`), everything
    else goes through the shared shape gates."""
    if method not in ("frontier_ell", "leveled_ell", "frontier_fused"):
        return method
    if not per_file and method == "leveled_ell":
        return "leveled"
    from .batch import resolve_traversal_method
    K = _pow2_bucket(int(ga.in_deg.max(initial=0)))
    return resolve_traversal_method(
        method, n=1, rows=ga.num_rules, k=K, edges=len(ga.edge_parent),
        per_file=per_file, f=ga.num_files)


def _top_down_frontier_ell(ga: GrammarArrays) -> jnp.ndarray:
    """Masked frontier rounds over the dense per-rule ELL plan.

    The N=1 case of core/batch.py's ``_frontier_weights_batched_ell`` —
    the jitted loop (and its compilation cache) is shared with the batched
    engine; each round is ONE fused ``kernels.ops.ell_propagate_batched``
    call with no scatter (row index == destination rule).  The blocked
    kernels stream weight vectors of any size through VMEM in grid-blocked
    chunks, so there is no rule-count cliff.  Skewed grammars
    whose plan width would exceed ELL_BATCH_MAX_WIDTH take the COO
    frontier instead (the dense plan is O(R * K) memory).
    """
    from repro.kernels import ops as kops
    from .batch import _frontier_weights_batched_ell

    K = _pow2_bucket(int(ga.in_deg.max(initial=0)))
    if (K > kops.ELL_BATCH_MAX_WIDTH
            or ga.num_rules * K > kops.ELL_PLAN_MAX_ENTRIES):
        w, _ = _top_down_frontier(
            jnp.asarray(ga.edge_parent), jnp.asarray(ga.edge_child),
            jnp.asarray(ga.edge_freq), jnp.asarray(ga.in_deg), ga.num_rules)
        return w

    srcj, freqj, in_deg = _ell_plan_single(ga)
    return _frontier_weights_batched_ell(srcj, freqj, in_deg)[0]


def _ell_plan_single(ga: GrammarArrays):
    """Memoized N=1 dense ELL plan (src, freq, in_deg), shared by the
    per-round and fused single-corpus engines (same eviction discipline as
    the other _ENGINE_CACHE entries)."""
    key = ("ell", id(ga))
    entry = _ENGINE_CACHE.get(key)
    if entry is None:
        src, freq = ga.in_edges_ell_dense()
        entry = (jnp.asarray(src)[None],           # [1, R, K]
                 jnp.asarray(freq)[None],
                 jnp.asarray(ga.in_deg)[None])     # [1, R]
        _ENGINE_CACHE[key] = entry
        weakref.finalize(ga, _ENGINE_CACHE.pop, key, None)
    return entry


def _top_down_frontier_fused(ga: GrammarArrays) -> jnp.ndarray:
    """The whole frontier loop in ONE dispatch over the N=1 ELL plan.

    ``ga.num_levels`` is the exact round count the while_loop form needs
    (level-L rules activate in round L+1), so the fused form loses nothing
    to its static bound.  Gates mirror the batched engine: plans too wide /
    too big for the dense layout take the COO frontier; rule counts beyond
    the fused kernel's VMEM state residency take the per-round ELL path.
    """
    from repro.kernels import ops as kops

    K = _pow2_bucket(int(ga.in_deg.max(initial=0)))
    if (K > kops.ELL_BATCH_MAX_WIDTH
            or ga.num_rules * K > kops.ELL_PLAN_MAX_ENTRIES):
        w, _ = _top_down_frontier(
            jnp.asarray(ga.edge_parent), jnp.asarray(ga.edge_child),
            jnp.asarray(ga.edge_freq), jnp.asarray(ga.in_deg), ga.num_rules)
        return w
    if not kops.ell_fused_use_kernel(ga.num_rules):
        return _top_down_frontier_ell(ga)
    from .batch import _frontier_fused_batched

    srcj, freqj, in_deg = _ell_plan_single(ga)
    return _frontier_fused_batched(srcj, freqj, in_deg,
                                   num_levels=ga.num_levels)[0]


_ENGINE_CACHE: Dict = {}


def _top_down_leveled(ga: GrammarArrays) -> jnp.ndarray:
    """Leveled top-down: each edge processed exactly once (static schedule)."""
    key = ("leveled", id(ga))
    if key in _ENGINE_CACHE:
        run, args = _ENGINE_CACHE[key]
        return run(*args)
    (slices, order) = ga.level_edge_slices()
    ep = jnp.asarray(ga.edge_parent[order])
    ec = jnp.asarray(ga.edge_child[order])
    ef = jnp.asarray(ga.edge_freq[order].astype(np.float32))
    R = ga.num_rules

    @jax.jit
    def run(ep, ec, ef):
        weight = jnp.zeros(R, jnp.float32).at[0].set(1.0)
        for (s, e) in slices:          # static python loop: levels are static
            if s == e:
                continue
            contrib = ef[s:e] * weight[ep[s:e]]
            weight = weight + jax.ops.segment_sum(contrib, ec[s:e],
                                                  num_segments=R)
        return weight

    _ENGINE_CACHE[key] = (run, (ep, ec, ef))
    # evict when ga dies: id() values are recycled, and a same-id key must
    # never serve another grammar's schedule (same scheme as frontier_ell)
    weakref.finalize(ga, _ENGINE_CACHE.pop, key, None)
    return run(ep, ec, ef)


# ----------------------------------------------------------------------- #
# Per-file top-down (batched): weights of each rule w.r.t. each file.      #
# ----------------------------------------------------------------------- #
def per_file_weights(ga: GrammarArrays, method: str = "frontier") -> jnp.ndarray:
    """Wf[r, f] == occurrences of rule r inside file f. Shape [R, F].

    The root's processing is replaced by per-file initialization from the
    root-segment edge lists (splitters partition the root body).  The mask
    schedule is *identical* to the global traversal — topology does not
    depend on the propagated payload — so the paper's Algorithm 1 carries
    over with a batched weight vector.

    The ELL methods run the vector-payload [R, F] rounds over the N=1
    dense edge plan (kernels/propagate_vector.py) — the historical silent
    remap to the segment_sum bases is gone; only shape-gate-ineligible
    plans degrade (same valves as the batched engine).  ``frontier_fused``
    runs its per-round ELL base (the fused kernel is scalar-payload).
    """
    if method in ("frontier_ell", "leveled_ell", "frontier_fused"):
        from .batch import resolve_traversal_method
        K = _pow2_bucket(int(ga.in_deg.max(initial=0)))
        method = resolve_traversal_method(
            method, n=1, rows=ga.num_rules, k=K, edges=len(ga.edge_parent),
            per_file=True, f=ga.num_files)
    if method in ("frontier_ell", "leveled_ell"):
        return _per_file_weights_ell(ga, method)
    R, F = ga.num_rules, ga.num_files
    ep = jnp.asarray(ga.edge_parent)
    ec = jnp.asarray(ga.edge_child)
    ef = jnp.asarray(ga.edge_freq)
    in_deg = jnp.asarray(ga.in_deg)

    W0 = jnp.zeros((R, F), jnp.float32)
    W0 = W0.at[ga.fedge_child, ga.fedge_file].add(
        ga.fedge_freq.astype(np.float32))
    # in-edges from the root are consumed by the init above
    root_seen = jnp.asarray(
        np.bincount(ga.edge_child[ga.edge_parent == 0],
                    minlength=ga.num_rules).astype(np.int32))

    if method == "leveled":
        (slices, order) = ga.level_edge_slices()
        epo, eco = ep[jnp.asarray(order)], ec[jnp.asarray(order)]
        efo = ef[jnp.asarray(order)].astype(jnp.float32)

        @jax.jit
        def run(W):
            for (s, e) in slices:
                if s == e:
                    continue
                keep = ga.edge_parent[order][s:e] != 0   # host bool, static
                if not keep.any():
                    continue
                contrib = efo[s:e, None] * W[epo[s:e], :]
                contrib = contrib * jnp.asarray(keep, jnp.float32)[:, None]
                W = W + jax.ops.segment_sum(contrib, eco[s:e], num_segments=R)
            return W

        return run(W0)

    @jax.jit
    def run(W):
        def cond(state):
            _, _, mask, _ = state
            return jnp.any(mask)

        def body(state):
            W, cur_in, mask, ever = state
            active_e = mask[ep] & (ep != 0)
            gathered = W[ep, :] * ef.astype(jnp.float32)[:, None]
            gathered = jnp.where(active_e[:, None], gathered, 0.0)
            delta = jax.ops.segment_sum(gathered, ec, num_segments=R)
            seen = jax.ops.segment_sum(active_e.astype(jnp.int32), ec,
                                       num_segments=R)
            W = W + delta
            cur_in = cur_in + seen
            new_ready = (cur_in == in_deg) & (~ever)
            return W, cur_in, new_ready, ever | new_ready

        mask0 = (root_seen == in_deg) & (in_deg > 0)
        state = (W, root_seen, mask0, mask0 | (in_deg == 0))
        W, _, _, _ = jax.lax.while_loop(cond, body, state)
        return W

    return run(W0)


def _per_file_weights_ell(ga: GrammarArrays, method: str) -> jnp.ndarray:
    """Per-file traversal over the N=1 dense ELL plan with vector-payload
    rounds — the single-corpus case of core/batch.py's per-file ELL
    engines (shared jitted loops + compile cache).  Plan arrays are
    memoized per grammar with the same id-keyed weakref eviction as the
    scalar plan."""
    from .batch import _per_file_ell_batched, _per_file_leveled_ell_batched

    srcj, freqj, in_deg = _ell_plan_single(ga)
    key = ("ell_pf", id(ga))
    entry = _ENGINE_CACHE.get(key)
    if entry is None:
        root_seen = np.bincount(ga.edge_child[ga.edge_parent == 0],
                                minlength=ga.num_rules).astype(np.int32)
        entry = (jnp.asarray(root_seen)[None],     # [1, R]
                 jnp.asarray(ga.fedge_child)[None],
                 jnp.asarray(ga.fedge_file)[None],
                 jnp.asarray(ga.fedge_freq.astype(np.float32))[None],
                 jnp.asarray(ga.level)[None])      # [1, R]
        _ENGINE_CACHE[key] = entry
        # evict when ga dies: id() values are recycled, and a same-id key
        # must never serve another grammar's plan
        weakref.finalize(ga, _ENGINE_CACHE.pop, key, None)
    root_seen, fc, ff, fq, level = entry
    if method == "frontier_ell":
        return _per_file_ell_batched(srcj, freqj, in_deg, root_seen,
                                     fc, ff, fq, ga.num_files)[0]
    return _per_file_leveled_ell_batched(srcj, freqj, level, fc, ff, fq,
                                         ga.num_levels, ga.num_files)[0]


# ----------------------------------------------------------------------- #
# Bottom-up: local word tables merged leaves -> root (paper Algorithm 2).  #
# ----------------------------------------------------------------------- #
def bottom_up_tables(ga: GrammarArrays) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense local tables C[r, v] = word counts of rule r's full expansion,
    plus the merged global result (the paper's ``reduceResultKernel``:
    root's own words + level-2 children scaled by their root frequencies).

    Dense [R, V] — used for validation and small/medium corpora; the
    production word-count path is the top-down weights + weighted bincount
    (mathematically identical, O(R+T) memory instead of O(R*V)).
    """
    R, V = ga.num_rules, ga.vocab_size
    ep = jnp.asarray(ga.edge_parent)
    ec = jnp.asarray(ga.edge_child)
    ef = jnp.asarray(ga.edge_freq)
    out_deg = jnp.asarray(ga.out_deg)

    C0 = jnp.zeros((R, V), jnp.float32).at[ga.tw_rule, ga.tw_word].add(
        ga.tw_cnt.astype(np.float32))

    @jax.jit
    def run(C):
        def cond(state):
            _, _, mask, _ = state
            return jnp.any(mask)

        def body(state):
            C, cur_out, mask, ever = state
            # Edges whose *child* is active push tables upward.  The paper
            # does NOT accumulate into the root ("the root contains file
            # information", §IV-B bottom-up): the root-level merge happens in
            # reduceResultKernel below.
            active_e = mask[ec] & (ep != 0)
            gathered = C[ec, :] * ef.astype(jnp.float32)[:, None]
            gathered = jnp.where(active_e[:, None], gathered, 0.0)
            delta = jax.ops.segment_sum(gathered, ep, num_segments=R)
            seen = jax.ops.segment_sum(active_e.astype(jnp.int32), ep,
                                       num_segments=R)
            C = C + delta
            cur_out = cur_out + seen
            new_ready = (cur_out == out_deg) & (~ever)
            return C, cur_out, new_ready, ever | new_ready

        mask0 = (out_deg == 0)                     # leaves
        state = (C, jnp.zeros(R, jnp.int32), mask0, mask0)
        C, _, _, _ = jax.lax.while_loop(cond, body, state)
        return C

    C = run(C0)
    # reduceResultKernel: root own words + direct children x root freqs
    root_mask = np.asarray(ga.edge_parent == 0)
    lvl2 = jnp.asarray(ga.edge_child[root_mask])
    lvl2_f = jnp.asarray(ga.edge_freq[root_mask].astype(np.float32))
    result = C[0] + (C[lvl2] * lvl2_f[:, None]).sum(axis=0)
    return C, result


def bottom_up_bounds(ga: GrammarArrays) -> jnp.ndarray:
    """The paper's ``genLocTblBoundKernel``: upper bound on each rule's local
    table size — own unique words + sum of children's bounds (merging can
    only dedup).  Used by the memory planner (core/memory.py).
    """
    R = ga.num_rules
    own = np.bincount(ga.tw_rule, minlength=R).astype(np.float32)
    ep = jnp.asarray(ga.edge_parent)
    ec = jnp.asarray(ga.edge_child)
    out_deg = jnp.asarray(ga.out_deg)

    @jax.jit
    def run(bound):
        def cond(state):
            _, _, mask, _ = state
            return jnp.any(mask)

        def body(state):
            bound, cur_out, mask, ever = state
            active_e = mask[ec]
            contrib = jnp.where(active_e, bound[ec], 0.0)
            delta = jax.ops.segment_sum(contrib, ep, num_segments=R)
            seen = jax.ops.segment_sum(active_e.astype(jnp.int32), ep,
                                       num_segments=R)
            bound = bound + delta
            cur_out = cur_out + seen
            new_ready = (cur_out == out_deg) & (~ever)
            return bound, cur_out, new_ready, ever | new_ready

        mask0 = (out_deg == 0)
        state = (bound, jnp.zeros(R, jnp.int32), mask0, mask0)
        bound, _, _, _ = jax.lax.while_loop(cond, body, state)
        return bound

    return run(jnp.asarray(own))


def traversal_rounds(ga: GrammarArrays) -> int:
    """Number of masked rounds the frontier engine needs (== DAG depth+1)."""
    _, rounds = _top_down_frontier(
        jnp.asarray(ga.edge_parent), jnp.asarray(ga.edge_child),
        jnp.asarray(ga.edge_freq), jnp.asarray(ga.in_deg), ga.num_rules)
    return int(rounds)
