"""Flat (CSR/ELL) grammar arrays — the DAG that TADOC analytics traverse.

The paper (§II-A) views the Sequitur CFG as a DAG: nodes are rules, an edge
``parent -> child`` exists when ``child`` appears in ``parent``'s body, with
an edge *frequency* (occurrence count).  All G-TADOC phases operate on this
DAG.  On TPU the DAG must be laid out as dense, statically-shaped arrays;
this module performs that layout (host side, numpy) once per corpus:

  * rule bodies as CSR (``body`` / ``body_offsets``);
  * unique parent->child edges with frequencies (COO, sorted by child and by
    parent — the two traversal directions);
  * per-rule unique-word counts (the rules' *local word tables* of paper
    §IV-C, pre-planned instead of hashed);
  * per-file slices of the root (TADOC's file splitters partition the root
    body; per-file analytics need root-level ownership);
  * expansion lengths and topological levels (used by the memory planner,
    the sequence-support layout, and the *leveled* traversal variant).

Symbol encoding inside bodies: ``0..V-1`` words, ``V..V+F-1`` file
splitters, ``V+F+r`` rule ``r``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from .sequitur import Grammar


class StaleGrammarError(RuntimeError):
    """A derived artifact (memoized weights, a pack, a plan) was produced
    at an earlier corpus epoch than the grammar it is about to serve.

    Raised by the epoch guards on :class:`repro.data.store.CompressedCorpus`
    and :meth:`repro.core.batch.GrammarBatch.check_epochs` — the ingest
    tier's contract that a mutated corpus can never be served from stale
    caches (the serving layer catches the mismatch earlier and re-packs;
    this exception is the backstop that makes skipping that check loud)."""


def pow2_bucket(x: int) -> int:
    """Smallest power of two >= max(x, 1): the ELL plan-width bucketing
    (shared with core/batch.py so batch packs agree on K; semantically
    identical to kernels._common.round_up_pow2 — kept separate only so the
    host-planning layer does not import the kernels package)."""
    return 1 << max(0, (max(int(x), 1) - 1).bit_length())


@dataclass(frozen=True)
class GrammarArrays:
    """Static flat layout of a TADOC grammar (all numpy, host-resident)."""

    vocab_size: int          # V: word terminals
    num_files: int           # F: splitter terminals V..V+F-1
    num_rules: int           # R (root == rule 0)

    body: np.ndarray         # [E_body] int32 symbols (encoding above)
    body_offsets: np.ndarray  # [R+1] int32

    # unique parent->child edges, COO; sorted by (parent, child)
    edge_parent: np.ndarray  # [E] int32
    edge_child: np.ndarray   # [E] int32
    edge_freq: np.ndarray    # [E] int32

    in_deg: np.ndarray       # [R] int32 unique-parent count (root: 0)
    out_deg: np.ndarray      # [R] int32 unique-child count

    # per-rule unique-word counts ("local word tables"), sorted by rule
    tw_rule: np.ndarray      # [T] int32
    tw_word: np.ndarray      # [T] int32
    tw_cnt: np.ndarray       # [T] int32

    # per-file ownership at the root (segments between splitters)
    fedge_file: np.ndarray   # [Ef] int32
    fedge_child: np.ndarray  # [Ef] int32
    fedge_freq: np.ndarray   # [Ef] int32
    fword_file: np.ndarray   # [Tf] int32
    fword_word: np.ndarray   # [Tf] int32
    fword_cnt: np.ndarray    # [Tf] int32

    exp_len: np.ndarray      # [R] int64 expansion length in terminals
    level: np.ndarray        # [R] int32 longest-path depth from root
    num_levels: int

    # ------------------------------------------------------------------ --
    @property
    def num_terminals(self) -> int:
        return self.vocab_size + self.num_files

    @property
    def num_edges(self) -> int:
        return int(self.edge_parent.shape[0])

    def rule_body(self, r: int) -> np.ndarray:
        return self.body[self.body_offsets[r]: self.body_offsets[r + 1]]

    def is_word(self, sym: np.ndarray) -> np.ndarray:
        return sym < self.vocab_size

    def is_splitter(self, sym: np.ndarray) -> np.ndarray:
        return (sym >= self.vocab_size) & (sym < self.num_terminals)

    def is_rule_sym(self, sym: np.ndarray) -> np.ndarray:
        return sym >= self.num_terminals

    def sym_rule(self, sym: np.ndarray) -> np.ndarray:
        return sym - self.num_terminals

    # ------------------------------------------------------- ELL layout --
    def in_edges_ell_dense(self, k: int | None = None
                           ) -> Tuple[np.ndarray, np.ndarray]:
        """Dense per-rule in-edge plan: row r lists rule r's in-edges.

        Returns ``(src, freq)`` shaped ``[R, K]`` with K the max in-degree
        rounded up to a power of two (>= 1; pass ``k`` to pad to a shared
        batch width).  Padding entries are src=0 / freq=0: the root has no
        in-edges and ``freq == 0`` gates padding out of every kernel.

        There is no row splitting — the row index IS the destination rule,
        so a propagation round is a pure gather + row-sum with no scatter
        (kernels/propagate_batched.py).  The paper's 16x thread-group
        threshold for oversized rules (§IV-B) becomes the width gate in the
        traversal engines: grammars whose max in-degree exceeds
        ``kernels.ops.ELL_BATCH_MAX_WIDTH`` fall back to segment_sum
        instead of splitting rows.
        """
        R = self.num_rules
        deg = self.in_deg.astype(np.int64)
        kmax = int(deg.max(initial=0))
        if k is None:
            k = pow2_bucket(kmax)
        elif k < kmax:
            raise ValueError(f"k={k} narrower than max in-degree {kmax}")
        src = np.zeros((R, k), np.int32)
        freq = np.zeros((R, k), np.float32)
        if self.num_edges:
            order = np.argsort(self.edge_child, kind="stable")
            child = self.edge_child[order]
            starts = np.zeros(R + 1, np.int64)
            np.cumsum(deg, out=starts[1:])
            col = np.arange(self.num_edges) - starts[child]
            src[child, col] = self.edge_parent[order]
            freq[child, col] = self.edge_freq[order]
        return src, freq

    # ---------------------------------------------------- level buckets --
    def level_edge_slices(self) -> List[Tuple[int, int]]:
        """Edge ranges grouped by parent level, for the leveled traversal.

        Edges sorted by ``level[parent]``; returns per-level (start, end)
        offsets into that ordering.  Host-static: lets the optimized
        traversal touch each edge exactly once (vs. once per round in the
        paper-faithful masked variant).
        """
        lv = self.level[self.edge_parent]
        order = np.argsort(lv, kind="stable")
        lv_sorted = lv[order]
        slices = []
        for l in range(self.num_levels):
            s = int(np.searchsorted(lv_sorted, l, "left"))
            e = int(np.searchsorted(lv_sorted, l, "right"))
            slices.append((s, e))
        return slices, order

    def compression_ratio(self) -> float:
        total_terminals = float(self.exp_len[0])
        grammar_syms = float(self.body.shape[0])
        return total_terminals / max(grammar_syms, 1.0)


def flatten(g: Grammar, vocab_size: int, num_files: int) -> GrammarArrays:
    """Lay out an inferred grammar as flat arrays (one-time, host side)."""
    R = g.num_rules
    nt = g.num_terminals
    assert nt == vocab_size + num_files, (nt, vocab_size, num_files)

    body = np.concatenate([r for r in g.rules]) if R else np.zeros(0, np.int64)
    body_offsets = np.zeros(R + 1, np.int64)
    np.cumsum([len(r) for r in g.rules], out=body_offsets[1:])

    # unique parent->child edges with frequencies
    ep: List[np.ndarray] = []
    ec: List[np.ndarray] = []
    ef: List[np.ndarray] = []
    tw_r: List[np.ndarray] = []
    tw_w: List[np.ndarray] = []
    tw_c: List[np.ndarray] = []
    for r in range(R):
        b = g.rules[r]
        subs = b[b >= nt] - nt
        if len(subs):
            u, c = np.unique(subs, return_counts=True)
            ep.append(np.full(len(u), r))
            ec.append(u)
            ef.append(c)
        words = b[b < vocab_size]
        if len(words):
            u, c = np.unique(words, return_counts=True)
            tw_r.append(np.full(len(u), r))
            tw_w.append(u)
            tw_c.append(c)

    def _cat(xs, dtype=np.int32):
        return (np.concatenate(xs).astype(dtype) if xs else np.zeros(0, dtype))

    edge_parent = _cat(ep)
    edge_child = _cat(ec)
    edge_freq = _cat(ef)
    tw_rule, tw_word, tw_cnt = _cat(tw_r), _cat(tw_w), _cat(tw_c)

    in_deg = np.bincount(edge_child, minlength=R).astype(np.int32)
    out_deg = np.bincount(edge_parent, minlength=R).astype(np.int32)

    # per-file root segments
    root = g.rules[0]
    fe_f: List[int] = []
    fe_c: List[int] = []
    fe_q: List[int] = []
    fw_f: List[int] = []
    fw_w: List[int] = []
    fw_c: List[int] = []
    cur = 0
    seg_subs: Dict[int, int] = {}
    seg_words: Dict[int, int] = {}

    def _flush(fid: int) -> None:
        for k, v in sorted(seg_subs.items()):
            fe_f.append(fid)
            fe_c.append(k)
            fe_q.append(v)
        for k, v in sorted(seg_words.items()):
            fw_f.append(fid)
            fw_w.append(k)
            fw_c.append(v)
        seg_subs.clear()
        seg_words.clear()

    for s in root:
        s = int(s)
        if vocab_size <= s < nt:          # splitter == end of file `cur`
            _flush(cur)
            cur += 1
        elif s >= nt:
            seg_subs[s - nt] = seg_subs.get(s - nt, 0) + 1
        else:
            seg_words[s] = seg_words.get(s, 0) + 1
    if seg_subs or seg_words:             # trailing segment w/o splitter
        _flush(min(cur, max(num_files - 1, 0)))

    # expansion lengths (bottom-up over reverse topo order)
    exp_len = np.zeros(R, np.int64)
    level = np.zeros(R, np.int32)
    # topo order: repeated relaxation is O(R * depth); do DFS instead
    children = {r: g.rules[r][g.rules[r] >= nt] - nt for r in range(R)}
    state = np.zeros(R, np.int8)  # 0 new, 1 open, 2 done
    order: List[int] = []
    for start in range(R):
        if state[start]:
            continue
        stack = [(start, 0)]
        while stack:
            node, phase = stack.pop()
            if phase == 0:
                if state[node]:
                    continue
                state[node] = 1
                stack.append((node, 1))
                for ch in children[node]:
                    if not state[ch]:
                        stack.append((int(ch), 0))
            else:
                state[node] = 2
                order.append(node)
    for r in order:  # children complete before parents
        b = g.rules[r]
        n_term = int((b < nt).sum())
        sub = b[b >= nt] - nt
        exp_len[r] = n_term + int(exp_len[sub].sum())
    # levels: longest path from root, forward over reverse topo order
    for r in reversed(order):
        for ch in children[r]:
            level[ch] = max(level[ch], level[r] + 1)
    num_levels = int(level.max(initial=0)) + 1

    return GrammarArrays(
        vocab_size=vocab_size,
        num_files=num_files,
        num_rules=R,
        body=body.astype(np.int32),
        body_offsets=body_offsets.astype(np.int64),
        edge_parent=edge_parent, edge_child=edge_child, edge_freq=edge_freq,
        in_deg=in_deg, out_deg=out_deg,
        tw_rule=tw_rule, tw_word=tw_word, tw_cnt=tw_cnt,
        fedge_file=np.array(fe_f, np.int32), fedge_child=np.array(fe_c, np.int32),
        fedge_freq=np.array(fe_q, np.int32),
        fword_file=np.array(fw_f, np.int32), fword_word=np.array(fw_w, np.int32),
        fword_cnt=np.array(fw_c, np.int32),
        exp_len=exp_len, level=level, num_levels=num_levels,
    )


# --------------------------------------------------------- random access --
def expand_range(ga: GrammarArrays, start: int, length: int) -> np.ndarray:
    """Expand ``length`` terminals starting at global offset ``start``
    without decompressing anything outside the window (paper [3]'s random
    access, host side — this is what the data pipeline's sampler uses).
    """
    out = np.empty(length, np.int64)
    n_out = 0
    # iterative descent: stack of (rule, body_idx, remaining-skip)
    skip = int(start)
    stack: List[Tuple[int, int]] = [(0, 0)]
    while stack and n_out < length:
        r, i = stack.pop()
        b = ga.rule_body(r)
        while i < len(b) and n_out < length:
            s = int(b[i])
            i += 1
            if s < ga.num_terminals:
                if skip > 0:
                    skip -= 1
                else:
                    out[n_out] = s
                    n_out += 1
            else:
                sub = s - ga.num_terminals
                l = int(ga.exp_len[sub])
                if skip >= l:
                    skip -= l
                else:
                    stack.append((r, i))
                    stack.append((sub, 0))
                    break
    return out[:n_out]
