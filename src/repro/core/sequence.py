"""Sequence support (paper §IV-D): head/tail buffers + cross-rule l-grams.

G-TADOC's insight: a word sequence (l-gram) either lies entirely inside one
rule's expansion — counted *once* by that rule and scaled by the rule's
occurrence weight — or it crosses a junction between adjacent symbols of
some rule's body, in which case the *parent* counts it by looking only at
the head/tail buffers of its children (no recursive descent).

Each rule r stores:
  head[r] = first  min(len(r), l-1) tokens of its expansion
  tail[r] = last   min(len(r), l-1) tokens of its expansion

Phase 1 (paper Fig. 7): fill head/tail with masked iterative rounds — a rule
resolves once the sub-rules in its body prefix/suffix have resolved.

Phase 2 (paper Fig. 8): per rule, scan the "junction stream" — the body with
each sub-rule occurrence replaced by ``head ++ GAP ++ tail`` (or its full
expansion when it is short enough to be covered by head+tail) — and count
every window of l tokens that (a) contains no GAP and no file splitter, and
(b) spans at least two body symbols (windows inside a single symbol are the
sub-rule's own business).  Window counts are scaled by the rule's top-down
weight.  The paper's lock+atomic hash-table merge becomes a sort+segment
reduction (DESIGN.md §2: no TPU atomics; deterministic by construction).

The *layout* of all gathers is static given the grammar (expansion lengths
are known host-side), so the device phases are pure dense gathers/reduces —
this is the TPU analogue of the paper's pre-planned memory pool.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .grammar import GrammarArrays
from .traversal import top_down_weights

_GAP = -1
_BREAK = -2

_K_LIT, _K_HEAD, _K_TAIL, _K_GAP, _K_BREAK = 0, 1, 2, 3, 4


# ----------------------------------------------------------------------- #
# Host-side static planning                                                #
# ----------------------------------------------------------------------- #
@dataclass(frozen=True)
class HeadTailPlan:
    """Static gather plan for resolving head/tail buffers on device."""
    h: int
    # head gather: head[r, t] = lit[r,t] if is_lit else head_src's buffer
    head_is_lit: np.ndarray   # [R, h] bool
    head_lit: np.ndarray      # [R, h] int32 (token or -1 pad)
    head_src: np.ndarray      # [R, h] int32 source rule
    head_idx: np.ndarray      # [R, h] int32 index into source head buffer
    head_dep: np.ndarray      # [R, Kd] int32 rules that must resolve first (pad -1)
    tail_is_lit: np.ndarray
    tail_lit: np.ndarray
    tail_src: np.ndarray
    tail_idx: np.ndarray
    tail_dep: np.ndarray
    head_len: np.ndarray      # [R] int32 = min(len, h)
    tail_len: np.ndarray


def plan_head_tail(ga: GrammarArrays, l: int) -> HeadTailPlan:
    h = l - 1
    R = ga.num_rules
    nt = ga.num_terminals
    lens = ga.exp_len

    head_is_lit = np.zeros((R, h), bool)
    head_lit = np.full((R, h), -1, np.int32)
    head_src = np.zeros((R, h), np.int32)
    head_idx = np.zeros((R, h), np.int32)
    tail_is_lit = np.zeros((R, h), bool)
    tail_lit = np.full((R, h), -1, np.int32)
    tail_src = np.zeros((R, h), np.int32)
    tail_idx = np.zeros((R, h), np.int32)
    head_dep: List[List[int]] = [[] for _ in range(R)]
    tail_dep: List[List[int]] = [[] for _ in range(R)]

    for r in range(R):
        b = ga.rule_body(r)
        # ---- head: walk prefix until h tokens are covered
        off = 0
        for s in b:
            if off >= h:
                break
            s = int(s)
            if s < nt:
                head_is_lit[r, off] = True
                head_lit[r, off] = s
                off += 1
            else:
                sub = s - nt
                c = int(min(lens[sub], h - off))
                head_is_lit[r, off: off + c] = False
                head_src[r, off: off + c] = sub
                head_idx[r, off: off + c] = np.arange(c)
                head_dep[r].append(sub)
                off += c
        # ---- tail: walk suffix backwards
        off = 0  # tokens collected from the end
        for s in b[::-1]:
            if off >= h:
                break
            s = int(s)
            if s < nt:
                tail_is_lit[r, h - 1 - off] = True
                tail_lit[r, h - 1 - off] = s
                off += 1
            else:
                sub = s - nt
                tl = int(min(lens[sub], h))      # sub's tail buffer length
                c = int(min(lens[sub], h - off))
                # we need the last c tokens of sub == tail[sub][tl-c : tl]
                # (sub tail buffer is left-aligned with tl valid entries)
                dst = slice(h - off - c, h - off)
                tail_is_lit[r, dst] = False
                tail_src[r, dst] = sub
                tail_idx[r, dst] = np.arange(tl - c, tl)
                tail_dep[r].append(sub)
                off += c
        # tail stored left-aligned: shift so valid tokens occupy [0, tlen)
        tlen = int(min(lens[r], h))
        shift = h - off
        if shift > 0 and off > 0:
            tail_is_lit[r, :off] = tail_is_lit[r, shift: shift + off]
            tail_lit[r, :off] = tail_lit[r, shift: shift + off]
            tail_src[r, :off] = tail_src[r, shift: shift + off]
            tail_idx[r, :off] = tail_idx[r, shift: shift + off]
            tail_is_lit[r, off:] = False
            tail_lit[r, off:] = -1

    Kd = max(1, max((len(d) for d in head_dep + tail_dep), default=1))

    def _pad_dep(dep):
        out = np.full((R, Kd), -1, np.int32)
        for r, d in enumerate(dep):
            u = sorted(set(d))[:Kd]
            out[r, :len(u)] = u
        return out

    return HeadTailPlan(
        h=h,
        head_is_lit=head_is_lit, head_lit=head_lit,
        head_src=head_src, head_idx=head_idx, head_dep=_pad_dep(head_dep),
        tail_is_lit=tail_is_lit, tail_lit=tail_lit,
        tail_src=tail_src, tail_idx=tail_idx, tail_dep=_pad_dep(tail_dep),
        head_len=np.minimum(lens, h).astype(np.int32),
        tail_len=np.minimum(lens, h).astype(np.int32),
    )


@dataclass(frozen=True)
class StreamPlan:
    """Static junction-stream layout + window index for one grammar."""
    l: int
    st_kind: np.ndarray    # [S] int8
    st_lit: np.ndarray     # [S] int32
    st_src: np.ndarray     # [S] int32
    st_idx: np.ndarray     # [S] int32
    st_symj: np.ndarray    # [S] int32 body-symbol ordinal within owner rule
    win_start: np.ndarray  # [Nw] int32 stream positions where a window fits
    win_rule: np.ndarray   # [Nw] int32 owner rule of each window


def plan_stream(ga: GrammarArrays, l: int) -> StreamPlan:
    h = l - 1
    nt = ga.num_terminals
    V = ga.vocab_size
    lens = ga.exp_len
    kinds: List[int] = []
    lits: List[int] = []
    srcs: List[int] = []
    idxs: List[int] = []
    symjs: List[int] = []
    win_start: List[int] = []
    win_rule: List[int] = []

    for r in range(ga.num_rules):
        b = ga.rule_body(r)
        seg_start = len(kinds)
        for j, s in enumerate(b):
            s = int(s)
            if s < V:                                   # word literal
                kinds.append(_K_LIT); lits.append(s)
                srcs.append(0); idxs.append(0); symjs.append(j)
            elif s < nt:                                # file splitter
                kinds.append(_K_BREAK); lits.append(_BREAK)
                srcs.append(0); idxs.append(0); symjs.append(j)
            else:
                sub = s - nt
                L = int(lens[sub])
                if L <= 2 * h:
                    # full expansion reconstructible from head ++ tail tail-end
                    hl = int(min(L, h))
                    for t in range(hl):
                        kinds.append(_K_HEAD); lits.append(-1)
                        srcs.append(sub); idxs.append(t); symjs.append(j)
                    rem = L - hl
                    tl = int(min(L, h))
                    for t in range(tl - rem, tl):
                        kinds.append(_K_TAIL); lits.append(-1)
                        srcs.append(sub); idxs.append(t); symjs.append(j)
                else:
                    for t in range(h):
                        kinds.append(_K_HEAD); lits.append(-1)
                        srcs.append(sub); idxs.append(t); symjs.append(j)
                    kinds.append(_K_GAP); lits.append(_GAP)
                    srcs.append(0); idxs.append(0); symjs.append(j)
                    for t in range(h):
                        kinds.append(_K_TAIL); lits.append(-1)
                        srcs.append(sub); idxs.append(t); symjs.append(j)
        # windows inside this rule's stream segment
        seg_len = len(kinds) - seg_start
        for p in range(seg_len - l + 1):
            win_start.append(seg_start + p)
            win_rule.append(r)

    return StreamPlan(
        l=l,
        st_kind=np.array(kinds, np.int8), st_lit=np.array(lits, np.int32),
        st_src=np.array(srcs, np.int32), st_idx=np.array(idxs, np.int32),
        st_symj=np.array(symjs, np.int32),
        win_start=np.array(win_start, np.int32),
        win_rule=np.array(win_rule, np.int32),
    )


# ----------------------------------------------------------------------- #
# Device phase 1: resolve head/tail (paper Fig. 7, masked rounds)          #
# ----------------------------------------------------------------------- #
def resolve_head_tail(ga: GrammarArrays, plan: HeadTailPlan
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    R, h = ga.num_rules, plan.h

    def _resolve(is_lit, lit, src, idx, dep):
        is_lit = jnp.asarray(is_lit)
        lit = jnp.asarray(lit)
        src = jnp.asarray(src)
        idx = jnp.asarray(idx)
        dep = jnp.asarray(dep)          # [R, Kd], -1 pad
        leaf = (dep < 0).all(axis=1)

        @jax.jit
        def run():
            buf0 = jnp.where(is_lit, lit, -1)
            ready0 = leaf

            def cond(state):
                _, ready, prev = state
                return jnp.any(ready != prev)

            def body(state):
                buf, ready, _ = state
                dep_ok = jnp.where(dep < 0, True,
                                   ready[jnp.clip(dep, 0, R - 1)]).all(axis=1)
                newly = dep_ok & (~ready)
                gathered = jnp.where(is_lit, lit, buf[src, idx])
                buf = jnp.where(newly[:, None], gathered, buf)
                return buf, ready | newly, ready

            buf, ready, _ = jax.lax.while_loop(
                cond, body, (buf0, ready0, jnp.zeros(R, bool)))
            return buf

        return run()

    head = _resolve(plan.head_is_lit, plan.head_lit, plan.head_src,
                    plan.head_idx, plan.head_dep)
    tail = _resolve(plan.tail_is_lit, plan.tail_lit, plan.tail_src,
                    plan.tail_idx, plan.tail_dep)
    return head, tail


# ----------------------------------------------------------------------- #
# Device phase 2: gather streams, count windows (paper Fig. 8)             #
# ----------------------------------------------------------------------- #
def sequence_count(ga: GrammarArrays, l: int = 3, method: str = "frontier",
                   weights: jnp.ndarray | None = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Count all l-grams of the corpus directly on the grammar.

    Returns (grams [U, l], counts [U]) for the U distinct l-grams, sorted
    lexicographically.  File splitters break windows (sequences never span
    files), matching per-file direct counting.  ``weights`` lets callers
    reuse a memoized traversal (must equal ``top_down_weights(ga)``).
    """
    if l < 2:
        raise ValueError("sequence_count needs l >= 2")
    htp = plan_head_tail(ga, l)
    sp = plan_stream(ga, l)
    head, tail = resolve_head_tail(ga, htp)
    if weights is None:
        weights = top_down_weights(ga, method=method)

    if sp.win_start.shape[0] == 0:
        return np.zeros((0, l), np.int32), np.zeros((0,), np.float32)

    st_kind = jnp.asarray(sp.st_kind)
    st_lit = jnp.asarray(sp.st_lit)
    st_src = jnp.asarray(sp.st_src)
    st_idx = jnp.asarray(sp.st_idx)
    st_symj = jnp.asarray(sp.st_symj)
    win_start = jnp.asarray(sp.win_start)
    win_rule = jnp.asarray(sp.win_rule)

    @jax.jit
    def count(head, tail, weights):
        tok = jnp.where(st_kind == _K_LIT, st_lit,
                        jnp.where(st_kind == _K_HEAD, head[st_src, st_idx],
                                  jnp.where(st_kind == _K_TAIL,
                                            tail[st_src, st_idx], st_lit)))
        # windows: [Nw, l] gather
        pos = win_start[:, None] + jnp.arange(l)[None, :]
        wtok = tok[pos]                                   # [Nw, l]
        wsym = st_symj[pos]
        valid = (wtok >= 0).all(axis=1) & (wsym[:, 0] != wsym[:, -1])
        wweight = jnp.where(valid, weights[win_rule], 0.0)

        # sort windows lexicographically by token tuple (primary = col 0)
        order = jnp.lexsort(tuple(wtok[:, c] for c in range(l - 1, -1, -1)))
        stok = wtok[order]
        sw = wweight[order]
        newseg = jnp.concatenate([
            jnp.array([True]),
            (stok[1:] != stok[:-1]).any(axis=1)])
        seg = jnp.cumsum(newseg) - 1
        counts = jax.ops.segment_sum(sw, seg, num_segments=stok.shape[0])
        return stok, seg, counts

    stok, seg, counts = count(head, tail, weights)
    stok = np.asarray(stok)
    counts = np.asarray(counts)
    n_seg = int(np.asarray(seg)[-1]) + 1
    # representative token tuple of each segment = first row of the segment
    first_idx = np.searchsorted(np.asarray(seg), np.arange(n_seg), "left")
    grams = stok[first_idx]
    cnts = counts[:n_seg]
    keep = cnts > 0
    return grams[keep].astype(np.int32), cnts[keep]
