"""Memory-pool planning (paper §IV-C, adapted per DESIGN.md §2).

G-TADOC manages its own GPU memory pool because (1) required sizes are
unknown until runtime and (2) per-thread malloc is slow.  Sizes are derived
by a light-weight bound-propagation pass (``genLocTblBoundKernel``) and the
pool is carved once.

On TPU/JAX, shapes must be static *at trace time* anyway — so the paper's
planning pass becomes the shape oracle: it computes per-rule table bounds
and head/tail bounds (paper Equation 1), and :class:`ArenaPlan` assigns
every rule a [offset, offset+size) slice of one flat buffer.  Tests assert
the bounds dominate the true sizes (tests/test_memory.py).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .grammar import GrammarArrays
from .traversal import bottom_up_bounds


@dataclass(frozen=True)
class ArenaPlan:
    """One flat buffer; rule r owns [offsets[r], offsets[r] + sizes[r])."""
    sizes: np.ndarray     # [R] int64
    offsets: np.ndarray   # [R] int64
    total: int

    def slice_of(self, r: int) -> slice:
        return slice(int(self.offsets[r]), int(self.offsets[r] + self.sizes[r]))


def head_tail_upper_limit(ga: GrammarArrays, l: int) -> np.ndarray:
    """Paper Equation (1): per-rule junction-stream upper bound.

        upperLimit = wordSize + (l-1) * subRuleSize - (l-1)

    where wordSize counts terminal symbols in the body and subRuleSize the
    sub-rule occurrences.  (Each sub-rule contributes at most head+tail =
    2(l-1) tokens plus a gap marker; the paper's bound tracks the head side;
    we keep their formula and verify dominance against our exact stream in
    tests — our stream uses 2(l-1)+1 per sub-rule, so the *stream* bound is
    word + (2l-1) * sub.)
    """
    R = ga.num_rules
    word_size = np.zeros(R, np.int64)
    sub_size = np.zeros(R, np.int64)
    nt = ga.num_terminals
    for r in range(R):
        b = ga.rule_body(r)
        word_size[r] = int((b < nt).sum())
        sub_size[r] = int((b >= nt).sum())
    return word_size + (l - 1) * sub_size - (l - 1)


def stream_upper_limit(ga: GrammarArrays, l: int) -> np.ndarray:
    """Exact-dominating bound for our junction stream layout."""
    R = ga.num_rules
    nt = ga.num_terminals
    out = np.zeros(R, np.int64)
    for r in range(R):
        b = ga.rule_body(r)
        n_term = int((b < nt).sum())
        n_sub = int((b >= nt).sum())
        out[r] = n_term + (2 * (l - 1) + 1) * n_sub
    return out


def plan_local_tables(ga: GrammarArrays) -> ArenaPlan:
    """Arena for per-rule local word tables (bottom-up analytics).

    Sizes come from the paper's bound pass (own unique words + children's
    bounds, merging can only dedup), clamped by the vocabulary size.
    """
    bounds = np.asarray(bottom_up_bounds(ga)).astype(np.int64)
    sizes = np.minimum(bounds, ga.vocab_size)
    offsets = np.zeros_like(sizes)
    np.cumsum(sizes[:-1], out=offsets[1:])
    return ArenaPlan(sizes=sizes, offsets=offsets, total=int(sizes.sum()))


def plan_streams(ga: GrammarArrays, l: int) -> ArenaPlan:
    """Arena for per-rule junction streams (sequence support)."""
    sizes = stream_upper_limit(ga, l)
    offsets = np.zeros_like(sizes)
    np.cumsum(sizes[:-1], out=offsets[1:])
    return ArenaPlan(sizes=sizes, offsets=offsets, total=int(sizes.sum()))
