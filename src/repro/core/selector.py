"""Traversal-strategy selector (paper §IV-B / [4] §VI-C).

The optimal traversal is input- and task-dependent: top-down carries
per-file payload of width F (expensive when the corpus has many files,
e.g. dataset A: 134k files -> bottom-up wins 9x); bottom-up carries local
word tables of width ~unique-words-per-subtree (expensive for wide
vocabularies in few files, e.g. dataset B: 4 files -> top-down wins 4x).

We port [4]'s selector: a closed-form cost model over the flattened grammar
(payload width x edges touched), optionally calibrated by a greedy sampled
trial on a small extracted subset (the paper uses a Wikipedia sample when
the input is unavailable until runtime).
"""

from __future__ import annotations

import numpy as np

from .grammar import GrammarArrays


def estimate_costs(ga: GrammarArrays) -> dict:
    """Payload-volume cost model: bytes moved across DAG edges per strategy."""
    E = max(ga.num_edges, 1)
    # top-down payload: per-file weight vector (width F) per edge
    top_down = float(E) * float(max(ga.num_files, 1))
    # bottom-up payload: local table entries; bound pass gives per-rule table
    # sizes — edges carry the child's table upward
    child_tbl = np.minimum(
        np.maximum(np.bincount(ga.tw_rule, minlength=ga.num_rules), 1),
        ga.vocab_size).astype(np.float64)
    # subtree table sizes grow toward the root; approximate with the unique
    # word footprint of each child's subtree, clamped by vocab
    bottom_up = float(child_tbl[ga.edge_child].sum()) if E else 1.0
    return {"top_down": top_down, "bottom_up": bottom_up}


def select_traversal(ga: GrammarArrays) -> str:
    """Return the masked-rounds engine flavour to use ("frontier" always),
    with direction folded in by the analytics caller.  Kept separate so the
    benchmark (bench_traversal.py) can interrogate the raw decision.
    """
    d = select_direction(ga)
    # both directions are served by the frontier engine; the leveled engine
    # is the beyond-paper optimization toggled explicitly
    return "frontier" if d else "frontier"


def select_direction(ga: GrammarArrays, calibrate: bool = False,
                     sample_rules: int = 256) -> str:
    """"top_down" or "bottom_up" per the cost model (optionally calibrated)."""
    costs = estimate_costs(ga)
    if calibrate and ga.num_rules > sample_rules:
        # greedy sampled calibration (paper: small extracted sample, set each
        # parameter in turns): scale the model by measured per-payload costs
        # on a rule sample.  On CPU the model constants are ~1; keep hooks.
        pass
    return "top_down" if costs["top_down"] <= costs["bottom_up"] else "bottom_up"
