"""Compressed-domain ranked retrieval: BM25 / TF-IDF top-k directly on
grammars.

The subsystem turns the analytics engine into a retrieval engine: term
frequencies, document frequencies and document lengths are derived from
the batched per-file traversal weights (never from decompressed text),
idf tables are prepared on host (numpy float32 — bit-stable against the
decompress-then-scan oracle), and scoring + top-k runs as one jitted
program per pack — batched across corpora, sharded across the corpus
mesh, and served through the same grouping/flush machinery as the six
analytics (query kinds ``search_bm25`` / ``search_tfidf``).
"""

from .scoring import (DEFAULT_TOP_K, KIND_SCHEME, SCHEMES, SEARCH_KINDS,
                      idf_bm25, idf_tfidf, normalize_terms)
from .index import SearchIndex, build_search_index
from .engine import (batch_search_stats, batched_search, search_corpus,
                     search_index_topk, search_sharded)

__all__ = [
    "SEARCH_KINDS", "KIND_SCHEME", "SCHEMES", "DEFAULT_TOP_K",
    "idf_bm25", "idf_tfidf", "normalize_terms",
    "SearchIndex", "build_search_index",
    "batched_search", "search_corpus", "search_index_topk",
    "search_sharded", "batch_search_stats",
]
