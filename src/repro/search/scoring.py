"""Host-side scoring math for compressed-domain ranked retrieval.

The retrieval subsystem answers multi-term queries with BM25 or plain
TF-IDF top-k document rankings computed *directly from the grammar* —
term frequencies, document frequencies and document lengths all come from
the per-file traversal weights (no decompression anywhere).  This module
owns the scoring formulas; :mod:`repro.search.engine` owns the jitted
batched evaluation.

DESIGN — why the transcendental parts live on host, in numpy float32:
rankings must be *bit-identical* to the decompress-then-scan oracle
(tests/_oracle.py mirrors these expressions op for op), and IEEE float32
add/mul/div are exactly specified — but ``log`` is not: XLA's and numpy's
libm disagree by a couple of ulp.  So everything that needs a ``log``
(the idf tables) or feeds a division chain that is cheap per *document*
rather than per (document, term) (the BM25 length normalizer) is computed
here with numpy on the small host-side ``df``/``dl`` statistics, and the
device program is left with only exactly-specified elementwise ops.
Every expression below is deliberately float32 end to end and must keep
its operation ORDER if edited — the oracle asserts bit equality.

Formulas (the classic Robertson/Sparck-Jones variants):

* ``idf_bm25(df, n) = ln(1 + (n - df + 0.5) / (df + 0.5))`` — the
  "+1 inside the log" form, positive for every df in [0, n];
* ``bm25`` per-(doc, term) contribution:
  ``idf * tf * (k1 + 1) / (tf + k1 * (1 - b + b * dl / avgdl))``;
* ``idf_tfidf(df, n) = ln((n + 1) / (df + 1)) + 1`` (smoothed, positive);
  ``tfidf`` contribution: ``idf * tf``.

A term outside a corpus's vocabulary simply has ``tf == df == 0``: it
contributes exactly ``+0.0`` to every document's score, so out-of-vocab
(and padded) query slots need no special cases anywhere downstream.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

#: Query kinds served by the retrieval subsystem (serving layer accepts
#: these alongside core.batch.ANALYTICS_KINDS).
SEARCH_KINDS = ("search_bm25", "search_tfidf")

#: Query kind -> scoring scheme.
KIND_SCHEME = {"search_bm25": "bm25", "search_tfidf": "tfidf"}

SCHEMES = ("bm25", "tfidf")

#: Documents returned when a search query does not say how many.
DEFAULT_TOP_K = 10

# BM25 free parameters (the standard defaults), pinned to float32 — the
# device scorer and the numpy oracle must see the exact same constants.
K1 = np.float32(1.2)
B = np.float32(0.75)
_ONE = np.float32(1.0)
_HALF = np.float32(0.5)
K1P1 = K1 + _ONE


def normalize_terms(terms: Optional[Sequence[int]]) -> Tuple[int, ...]:
    """Canonical query-term tuple: ints, order preserved (scores accumulate
    in term order, so order is part of the query identity), duplicates kept
    (a repeated term legitimately counts twice).  Empty/None is an error —
    a search with no terms has no defined ranking."""
    if terms is None:
        raise ValueError("search queries need a non-empty terms sequence")
    out = tuple(int(t) for t in terms)
    if not out:
        raise ValueError("search queries need at least one term")
    if any(t < 0 for t in out):
        raise ValueError(f"negative term ids are invalid: {out}")
    return out


def idf_bm25(df: np.ndarray, n_docs) -> np.ndarray:
    """BM25 idf, float32, elementwise over ``df`` (``n_docs`` broadcasts).
    Positive for every df in [0, n]; df == 0 (out-of-vocab term) is
    well-defined and never reached by a non-zero tf anyway."""
    df = np.asarray(df, np.float32)
    n = np.asarray(n_docs, np.float32)
    return np.log(_ONE + (n - df + _HALF) / (df + _HALF)).astype(np.float32)


def idf_tfidf(df: np.ndarray, n_docs) -> np.ndarray:
    """Smoothed TF-IDF idf, float32: ``ln((n + 1) / (df + 1)) + 1``."""
    df = np.asarray(df, np.float32)
    n = np.asarray(n_docs, np.float32)
    return (np.log((n + _ONE) / (df + _ONE)) + _ONE).astype(np.float32)


def idf(df: np.ndarray, n_docs, scheme: str) -> np.ndarray:
    if scheme == "bm25":
        return idf_bm25(df, n_docs)
    if scheme == "tfidf":
        return idf_tfidf(df, n_docs)
    raise ValueError(f"unknown scoring scheme {scheme!r}; "
                     f"expected one of {SCHEMES}")


def avg_doc_len(dl: np.ndarray, n_docs: Optional[int] = None) -> np.float32:
    """Mean document length in float32.  ``n_docs`` overrides the divisor
    when ``dl`` carries padded (all-zero) document slots beyond the real
    count.  An all-empty corpus gets 1.0 so the BM25 length normalizer
    stays finite (tf == 0 everywhere then; scores are all +0.0)."""
    dl = np.asarray(dl, np.float32)
    n = int(dl.shape[0]) if n_docs is None else int(n_docs)
    avg = np.float32(dl.sum(dtype=np.float32)) / np.float32(max(n, 1))
    return avg if avg > 0 else _ONE


def bm25_norm(dl: np.ndarray, avgdl) -> np.ndarray:
    """Per-document BM25 length normalizer ``k1 * (1 - b + b*dl/avgdl)``,
    float32, elementwise over ``dl`` — the whole denominator except the
    per-term tf.  Strictly positive (dl >= 0, avgdl > 0)."""
    dl = np.asarray(dl, np.float32)
    return (K1 * (_ONE - B + B * (dl / np.float32(avgdl)))).astype(
        np.float32)
