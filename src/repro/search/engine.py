"""Jitted batched BM25/TF-IDF scoring + top-k over :class:`GrammarBatch`.

One call ranks every file of every corpus in a pack against a multi-term
query — the "ranked inverted index" application family of the TADOC
journal paper promoted to a serving workload, still entirely in the
compressed domain:

1. **Statistics** come from the batched per-file traversal
   (:func:`repro.core.batch.batched_term_vector`): ``tv [N, F, V]`` term
   frequencies, ``dl = tv.sum(V)`` doc lengths, ``df = (tv > 0).sum(F)``
   document frequencies.  They are memoized per (pack, traversal base) on
   the pack's plan cache — recurring search traffic against a cached pack
   pays the traversal once, like the ELL and sequence plans.
2. **Transcendental prep** (idf tables, the BM25 length normalizer) runs
   on host in numpy float32 (:mod:`repro.search.scoring` DESIGN note:
   ``log`` is not bit-stable across libms, so it never runs on device).
3. **Scoring + top-k** is ONE jitted program per pack signature:
   vocab-gather of the query terms' tf columns, the per-(doc, term)
   contribution, a ``fori_loop`` accumulation over term slots, and
   ``kernels.ops.masked_top_k`` (``jax.lax.top_k``: ties resolve to the
   lower file id — deterministic rankings).  The accumulation is a
   ``fori_loop`` over a *materialized* contribution tensor on purpose:
   an unrolled ``score += idf * quot`` lets XLA contract the mul+add into
   an FMA and the result stops being bit-identical to the numpy oracle;
   the loop-carried add keeps every operation an exactly-specified IEEE
   elementwise op (tests/test_differential.py asserts bit equality of
   both rankings and scores).
4. **Sharded packs** (``gb.mesh``) run the same scoring program through
   ``shard_map`` (:func:`repro.core.batch._sharded_program`): each device
   ranks its own corpus rows — per-shard top-k, no cross-device traffic —
   and the host merge slices per-corpus results exactly like ``unbatch``.

Padding is inert end to end: padded files are masked to ``-inf`` before
top-k (and sliced off by ``min(k, num_files)``), padded/out-of-vocab term
slots contribute exactly ``+0.0`` (zero idf or zero tf), and padded
corpus rows are dropped by ``real_gas``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batch import GrammarBatch, _sharded_program, \
    batched_term_vector
from repro.core.grammar import pow2_bucket
from repro.distributed.shard_batch import shard_batch
from repro.kernels import ops as kops
from repro.obs import plan_stage as _plan_stage

from .index import SearchIndex, base_method, build_search_index
from .scoring import (DEFAULT_TOP_K, K1P1, SCHEMES, avg_doc_len, bm25_norm,
                      idf, normalize_terms)

__all__ = ["batched_search", "search_corpus", "search_index_topk",
           "search_sharded", "batch_search_stats"]


# ----------------------------------------------------------------------- #
# The jitted scoring + top-k program                                       #
# ----------------------------------------------------------------------- #
def _score_topk_impl(tv, terms, idf_q, norm, fvalid, k=None, scheme=None):
    """Score ``[n, F]`` docs against ``[n, Q]`` term slots and rank top-k.

    ``terms`` are pre-clipped vocab indices (host prep), ``idf_q`` is 0.0
    on invalid/padded slots, ``norm`` the host-computed BM25 length
    normalizer.  Every op is an exactly-specified IEEE float32 elementwise
    op in a fixed order (module DESIGN note) — the numpy oracle mirrors it
    bit for bit.  shard_map-compatible: batch-only leading axes, no
    cross-row communication.
    """
    tf_q = jnp.take_along_axis(tv, terms[:, None, :], axis=2)   # [n, F, Q]
    if scheme == "bm25":
        quot = (tf_q * jnp.float32(K1P1)) / (tf_q + norm[:, :, None])
    elif scheme == "tfidf":
        quot = tf_q
    else:
        raise ValueError(f"unknown scoring scheme {scheme!r}; "
                         f"expected one of {SCHEMES}")
    contrib = jnp.moveaxis(idf_q[:, None, :] * quot, 2, 0)      # [Q, n, F]
    # fori over the materialized contribs: keeps adds un-contractible
    score = jax.lax.fori_loop(
        0, contrib.shape[0], lambda j, s: s + contrib[j],
        jnp.zeros(tv.shape[:2], jnp.float32))
    return kops.masked_top_k(score, fvalid, k)


_score_topk = jax.jit(_score_topk_impl, static_argnames=("k", "scheme"))


# ----------------------------------------------------------------------- #
# Pack-level retrieval statistics (memoized like the ELL/sequence plans)   #
# ----------------------------------------------------------------------- #
@dataclass(frozen=True)
class _BatchSearchStats:
    tv: jnp.ndarray       # [N, F_pad, V_pad] device (pack placement)
    norm: jnp.ndarray     # [N, F_pad] device, bm25_norm per corpus
    fvalid: jnp.ndarray   # [N, F_pad] bool device (file < num_files)
    df: np.ndarray        # [N, V_pad] float32 host document frequencies
    nf: np.ndarray        # [N] int64 host true file counts


def batch_search_stats(gb: GrammarBatch,
                       method: str = "frontier") -> _BatchSearchStats:
    """Doc lengths / document frequencies / tf lookups for a whole pack,
    derived from the batched per-file traversal and memoized on the pack
    (key: traversal base) — sharded packs keep the device arrays with the
    pack's placement."""
    m = base_method(method)
    key = ("search", m)
    if key not in gb._plan_cache:
        with _plan_stage("search_stats"):
            tv = batched_term_vector(gb, method=m)
            # dl/df are integer-valued (exact in float32 in any reduce
            # order)
            dl = np.asarray(jnp.sum(tv, axis=2), np.float32)    # [N, F_pad]
            df = np.asarray(jnp.sum(tv > 0, axis=1)).astype(np.float32)
            nf = gb.num_files.astype(np.int64)
            norm = np.stack([
                bm25_norm(dl[i], avg_doc_len(dl[i], int(nf[i])))
                for i in range(gb.n)]).astype(np.float32)
            fvalid = np.arange(gb.F_pad)[None, :] < nf[:, None]
            gb._plan_cache[key] = _BatchSearchStats(
                tv=tv, norm=gb._place(norm), fvalid=gb._place(fvalid),
                df=df, nf=nf)
    return gb._plan_cache[key]


def _query_arrays(df: np.ndarray, nf: np.ndarray, vocab: int,
                  terms: Tuple[int, ...], scheme: str
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Host query prep: pow2-padded clipped term indices [Qp] and the
    per-corpus idf table [N, Qp] (0.0 on padded / out-of-range slots —
    their contribution must be exactly +0.0)."""
    qp = pow2_bucket(len(terms))
    t = np.full(qp, -1, np.int64)
    t[: len(terms)] = terms
    ok = (t >= 0) & (t < vocab)
    t_clip = np.clip(t, 0, max(vocab - 1, 0)).astype(np.int32)
    df_q = np.where(ok[None, :], df[:, t_clip], np.float32(0.0))
    idf_q = idf(df_q, nf[:, None], scheme)
    idf_q = np.where(ok[None, :], idf_q, np.float32(0.0)).astype(np.float32)
    return t_clip, idf_q


def _check_query(k: int, scheme: str) -> int:
    if scheme not in SCHEMES:
        raise ValueError(f"unknown scoring scheme {scheme!r}; "
                         f"expected one of {SCHEMES}")
    k = int(k)
    if k < 1:
        raise ValueError(f"top-k needs k >= 1, got {k}")
    return k


# ----------------------------------------------------------------------- #
# Entry points                                                             #
# ----------------------------------------------------------------------- #
def batched_search(gb: GrammarBatch, terms: Sequence[int],
                   k: int = DEFAULT_TOP_K, scheme: str = "bm25",
                   method: str = "frontier"
                   ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Rank every corpus in the pack against one query, in ONE program.

    Returns per real corpus ``(doc_ids [k_i], scores [k_i])`` with
    ``k_i = min(k, num_files)``, scores descending, ties broken toward the
    lower file id.  Sharded packs rank per shard and merge on host.
    """
    terms = normalize_terms(terms)
    k = _check_query(k, scheme)
    st = batch_search_stats(gb, method)
    t_clip, idf_q = _query_arrays(st.df, st.nf, gb.V_pad, terms, scheme)
    terms_dev = gb._place(np.tile(t_clip[None, :], (gb.n, 1)))
    idf_dev = gb._place(idf_q)
    # k bucketed to pow2 (<= F_pad) so nearby k values share the compiled
    # program; the per-corpus slice below restores the exact ask
    k_run = min(pow2_bucket(k), gb.F_pad)
    if gb.mesh is not None:
        vals, idx = _sharded_program(
            _score_topk_impl, gb.mesh, (3, 2, 2, 2, 2), (2, 2),
            static=(("k", k_run), ("scheme", scheme)))(
            st.tv, terms_dev, idf_dev, st.norm, st.fvalid)
    else:
        vals, idx = _score_topk(st.tv, terms_dev, idf_dev, st.norm,
                                st.fvalid, k_run, scheme)
    vals_h = np.asarray(vals)
    idx_h = np.asarray(idx)
    out: List[Tuple[np.ndarray, np.ndarray]] = []
    for i, ga in enumerate(gb.real_gas):
        k_eff = min(k, ga.num_files)
        out.append((idx_h[i, :k_eff].astype(np.int32), vals_h[i, :k_eff]))
    return out


def _index_device_arrays(si: SearchIndex):
    """Device copies of an index's tf/norm/valid, memoized on the index:
    repeat single-corpus traffic pays the [F, V] upload once, like the
    batched path's pack-resident statistics."""
    if "arrays" not in si._device_cache:
        si._device_cache["arrays"] = (
            jnp.asarray(si.tf)[None], jnp.asarray(si.norm)[None],
            jnp.ones((1, si.n_docs), bool))
    return si._device_cache["arrays"]


def search_index_topk(si: SearchIndex, terms: Sequence[int],
                      k: int = DEFAULT_TOP_K, scheme: str = "bm25"
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Rank one corpus through its memoized :class:`SearchIndex` — the
    same jitted scoring program (and the same host query prep) as the
    batched path, at N == 1: results bit-identical to the corpus's row in
    a batched pack."""
    terms = normalize_terms(terms)
    k = _check_query(k, scheme)
    if si.n_docs == 0:
        return np.zeros(0, np.int32), np.zeros(0, np.float32)
    t_clip, idf_q = _query_arrays(si.df[None, :],
                                  np.array([si.n_docs], np.int64),
                                  si.vocab_size, terms, scheme)
    tf_dev, norm_dev, valid_dev = _index_device_arrays(si)
    k_run = min(pow2_bucket(k), si.n_docs)
    vals, idx = _score_topk(
        tf_dev, jnp.asarray(t_clip)[None], jnp.asarray(idf_q),
        norm_dev, valid_dev, k_run, scheme)
    k_eff = min(k, si.n_docs)
    return (np.asarray(idx)[0, :k_eff].astype(np.int32),
            np.asarray(vals)[0, :k_eff])


def search_corpus(source, terms: Sequence[int], k: int = DEFAULT_TOP_K,
                  scheme: str = "bm25", method: str = "frontier"
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Single-corpus retrieval.  ``source`` is a ``GrammarArrays`` or a
    ``CompressedCorpus`` — the latter's memoized index (and per-file
    traversal weights) are reused across queries."""
    si = (source.search_index(base_method(method))
          if hasattr(source, "search_index")
          else build_search_index(source, method=method))
    return search_index_topk(si, terms, k=k, scheme=scheme)


def search_sharded(gas: Sequence, terms: Sequence[int],
                   k: int = DEFAULT_TOP_K, scheme: str = "bm25",
                   mesh=None, method: str = "frontier", bucket: bool = True
                   ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """One-call device-sharded retrieval: pad + pack + shard + rank (see
    :func:`repro.distributed.shard_batch.shard_batch`); bit-identical to
    :func:`batched_search` on a single device.  Recurring traffic should
    keep the pack (serving layer) instead of re-packing per query."""
    gb = shard_batch(gas, mesh=mesh, bucket=bucket)
    return batched_search(gb, terms, k=k, scheme=scheme, method=method)
