"""Per-corpus search index derived from the compressed grammar.

A :class:`SearchIndex` is the retrieval-side view of one corpus: the
``[F, V]`` term-frequency table, ``[F]`` document lengths, ``[V]``
document frequencies and the BM25 length normalizer — all computed from
the per-file traversal weights (:func:`repro.core.analytics.term_vector`),
never from decompressed text.  Building one costs a single per-file
traversal; everything else is host-side numpy over the resulting integer
statistics.

The index is meant to be memoized exactly like traversal weights:
:meth:`repro.data.store.CompressedCorpus.search_index` caches it per
(corpus, traversal-method), so recurring search traffic against a
registered store pays the traversal once.  Batched packs keep the
equivalent statistics on the pack itself (:mod:`repro.search.engine`).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.analytics import term_vector
from repro.core.grammar import GrammarArrays

from .scoring import avg_doc_len, bm25_norm, idf

#: The per-file traversal base an index build (or the pack-level search
#: statistics) runs for each requested method.  ELL methods now pass
#: through to the vector-payload per-file engines
#: (kernels/propagate_vector.py) instead of remapping to segment_sum;
#: ``frontier_fused`` runs the per-round ELL base (the fused kernel is
#: scalar-payload) and ``auto`` keeps its historical frontier base here so
#: index cache keys stay stable across pack shapes.
_BASE_METHOD = {"frontier_fused": "frontier_ell", "auto": "frontier",
                "top_down": "frontier", "bottom_up": "frontier"}


def base_method(method: str) -> str:
    """The per-file traversal base a search index build actually runs."""
    return _BASE_METHOD.get(method, method)


@dataclass(frozen=True)
class SearchIndex:
    """Host-side retrieval statistics of one corpus (all float32; every
    value is an integer count except ``avgdl`` and ``norm``)."""

    tf: np.ndarray        # [F, V] term frequencies (== term_vector)
    dl: np.ndarray        # [F] document lengths (word terminals per file)
    df: np.ndarray        # [V] document frequencies
    norm: np.ndarray      # [F] BM25 length normalizer (bm25_norm(dl, avgdl))
    avgdl: np.float32     # mean document length (>= 1.0 guard on empty)
    n_docs: int           # F
    vocab_size: int       # V
    # device-resident copies of tf/norm/mask, filled by the scoring engine
    # on first use: repeat single-corpus queries must not re-upload the
    # [F, V] table per call (mutable memo on a frozen dataclass, like the
    # pack plan cache)
    _device_cache: dict = field(default_factory=dict, repr=False,
                                compare=False)

    def idf_for_terms(self, terms, scheme: str) -> np.ndarray:
        """float32 ``[Q]`` idf values for a term-id sequence; out-of-range
        ids get df == 0 (their tf is 0 everywhere, contribution +0.0)."""
        t = np.asarray(terms, np.int64)
        df_q = np.zeros(len(t), np.float32)
        ok = (t >= 0) & (t < self.vocab_size)
        df_q[ok] = self.df[t[ok]]
        return idf(df_q, self.n_docs, scheme)


def build_search_index(source, method: str = "frontier") -> SearchIndex:
    """Build a :class:`SearchIndex` from a :class:`GrammarArrays` or
    anything carrying one as ``.ga`` (a ``CompressedCorpus`` — duck-typed
    so this module never imports the store and the store can lazily import
    us).  A source with memoized ``per_file_weights`` contributes them, so
    store-backed builds share the traversal with the other per-file
    analytics."""
    m = base_method(method)
    ga = getattr(source, "ga", source)
    if not isinstance(ga, GrammarArrays):
        raise TypeError(f"cannot index {type(source).__name__}")
    fw = (source.per_file_weights(m)
          if hasattr(source, "per_file_weights") else None)
    tf = np.asarray(term_vector(ga, method=m, file_weights=fw), np.float32)
    dl = tf.sum(axis=1, dtype=np.float32)
    df = (tf > 0).sum(axis=0).astype(np.float32)
    avgdl = avg_doc_len(dl)
    return SearchIndex(tf=tf, dl=dl, df=df, norm=bm25_norm(dl, avgdl),
                       avgdl=avgdl, n_docs=int(ga.num_files),
                       vocab_size=int(ga.vocab_size))
