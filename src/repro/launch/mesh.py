"""Production mesh factory.

Single pod: (data=16, model=16) = 256 chips (TPU v5e-256 topology).
Multi-pod: (pod=2, data=16, model=16) = 512 chips across two pods — the
"pod" axis is the DCN boundary; cross-pod collectives are gradient
all-reduces (and optional cross-pod FSDP), everything else stays inside a
pod's ICI.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = None):
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    data = data or (n // model)
    assert data * model == n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))
