import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_llvm_disable_expensive_passes=true")
# ^ MUST precede every other import: jax locks the device count at first
# init.  512 placeholder host devices back both the 16x16 single-pod mesh
# and the 2x16x16 multi-pod mesh.  (Only the dry-run does this — tests and
# benchmarks see the real single CPU device.)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the exact assigned config and its ShapeDtypeStruct inputs
     (configs/base.py input_specs — no allocation anywhere);
  2. derives parameter/optimizer/cache shardings from the logical axes
     (distributed/sharding.py: DP x FSDP x TP x EP x SP);
  3. ``jax.jit(step).lower(...).compile()`` on the production mesh;
  4. records memory_analysis, cost_analysis, the collective-byte histogram
     parsed from the compiled HLO, and the model-FLOPs accounting into
     ``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

Resumable: existing JSONs are skipped unless --force.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import functools
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, ALIASES, get_config, input_specs, \
    shape_supported
from repro.distributed import (batch_shardings, cache_shardings,
                               default_rules, param_shardings, replicated)
from repro.launch.mesh import make_production_mesh
from repro.models import init_lm, unbox, init_cache
from repro.models.config import LM_SHAPES
from repro.models.partitioning import activation_policy
from jax.sharding import PartitionSpec as P
from repro.serving import make_serve_step, make_prefill_step
from repro.training import AdamW, make_train_step
from repro.utils.hlo_analysis import (op_histogram, parse_collectives,
                                      total_collective_bytes)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _attach(structs, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        structs, shardings)


def model_flops(cfg, shape_name: str) -> float:
    """MODEL_FLOPS per step: 6*N*D train (N active params, D tokens),
    2*N*D forward-only (prefill/decode)."""
    spec = LM_SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        return 6.0 * n_active * tokens
    if spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        return 2.0 * n_active * tokens
    tokens = spec.global_batch * 1           # one token per stream
    return 2.0 * n_active * tokens


def make_activation_policy(cfg, shape_name: str, mesh, rules,
                           variant: str = "baseline") -> Dict:
    """PartitionSpecs pinning activations through scan/remat boundaries.

    act_btd: [B, S/1, d] -> batch over (pod, data), replicated over model.
    logits:  [B, S, V]   -> batch over (pod, data), vocab over model.
    Skipped when the dim does not divide (long_500k batch=1)."""
    spec = LM_SHAPES[shape_name]
    ba = rules.batch_axes
    b_assign = ba[0] if len(ba) == 1 else tuple(ba)
    import numpy as _np
    b_size = int(_np.prod([mesh.shape[a] for a in ba]))
    model_sz = mesh.shape.get("model", 1)
    pol: Dict = {}
    b_ok = spec.global_batch % b_size == 0
    v_ok = cfg.vocab_size % model_sz == 0
    s_ok = spec.seq_len % model_sz == 0 and spec.kind in ("train", "prefill")
    if b_ok:
        if variant == "fullsp" and s_ok:
            # Megatron-style full sequence parallelism: the layer carry
            # stays seq-sharded over `model`; FFN/attention projections
            # all-gather once in bf16 and reduce-scatter back, replacing
            # the baseline's per-layer f32 boundary gathers.
            pol["act_btd"] = P(b_assign, "model", None)
        else:
            pol["act_btd"] = P(b_assign, None, None)
        pol["logits"] = P(b_assign, None, "model" if v_ok else None)
    elif v_ok:
        pol["logits"] = P(None, None, "model")
    # SP attention: shard q over seq on the model axis whenever head counts
    # don't divide it (qwen2 14H, qwen1.5/whisper 20H, and GQA reshapes
    # where kv_heads < model); full-seq shapes only (decode q has S=1).
    if spec.kind in ("train", "prefill") and cfg.num_heads:
        heads_ok = (cfg.num_kv_heads % model_sz == 0)
        if not heads_ok and spec.seq_len % model_sz == 0 and b_ok:
            pol["attn_q"] = P(b_assign, "model", None, None)
    return pol


def build_cell(arch: str, shape_name: str, mesh, rules,
               microbatches: int = 1, remat: bool = True,
               fsdp_over_pod: bool = False, unroll: bool = False):
    """Returns (step_fn, arg_structs: tuple, donate) ready to lower."""
    cfg = get_config(arch)
    spec = LM_SHAPES[shape_name]

    # ---- parameter structs (eval_shape: zero allocation) ----
    boxed = jax.eval_shape(functools.partial(init_lm, cfg=cfg),
                           jax.random.PRNGKey(0))
    p_structs, axes = unbox(boxed)
    p_shard = param_shardings(axes, p_structs, mesh, rules)
    params = _attach(p_structs, p_shard)

    ins = input_specs(cfg, shape_name)

    if spec.kind == "train":
        opt = AdamW(lr=1e-4)
        o_structs = jax.eval_shape(opt.init, p_structs)
        # moments shard exactly like their params; count is scalar
        o_shard = type(o_structs)(
            count=replicated(mesh),
            mu=param_shardings(axes, o_structs.mu, mesh, rules),
            nu=param_shardings(axes, o_structs.nu, mesh, rules))
        opt_state = _attach(o_structs, o_shard)
        batch = {k: v for k, v in ins.items()}
        b_shard = batch_shardings(batch, mesh, rules)
        batch = _attach(batch, b_shard)
        step = make_train_step(cfg, opt, remat=remat,
                               microbatches=microbatches, unroll=unroll)
        return cfg, step, (params, opt_state, batch), (0, 1)

    if spec.kind == "prefill":
        step = make_prefill_step(cfg, unroll=unroll)
        batch = dict(ins)
        b_shard = batch_shardings(batch, mesh, rules)
        batch = _attach(batch, b_shard)
        args = (params, batch["tokens"])
        kw = {}
        if "extra_embeds" in batch:
            args = args + (batch["extra_embeds"],)

            def step2(p, t, e):
                return step(p, t, extra_embeds=e)
            return cfg, step2, args, ()
        return cfg, step, args, ()

    # decode: serve_step against a seq_len-deep cache
    c_structs = jax.eval_shape(
        functools.partial(init_cache, cfg, spec.global_batch, spec.seq_len))
    c_shard = cache_shardings(cfg, c_structs, mesh, rules)
    cache = _attach(c_structs, c_shard)
    tokens = jax.ShapeDtypeStruct(
        (spec.global_batch, 1), jnp.int32,
        sharding=batch_shardings(
            {"t": jax.ShapeDtypeStruct((spec.global_batch, 1), jnp.int32)},
            mesh, rules)["t"])
    serve = make_serve_step(cfg, unroll=unroll)

    def step(p, c, t):
        nxt, c, _ = serve(p, c, t)
        return nxt, c

    return cfg, step, (params, cache, tokens), (1,)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str = OUT_DIR, force: bool = False,
             microbatches: int = 1, remat="full",
             fsdp_over_pod: bool = False, tag: str = "",
             policy_variant: str = "baseline", fast: bool = False,
             rules=None) -> Optional[Dict]:
    cfg = get_config(arch)
    name = f"{ALIASES.get(arch, arch)}__{shape_name}__{mesh_kind}"
    if tag:
        name += f"__{tag}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, name + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    ok, reason = shape_supported(cfg, shape_name)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped", "reason": reason}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[dryrun] SKIP {name}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = rules or default_rules(mesh, fsdp_over_pod=fsdp_over_pod)
    policy = make_activation_policy(cfg, shape_name, mesh, rules,
                                    variant=policy_variant)
    t0 = time.time()
    try:
        # pass 1 — production form (scan over layers): buffer reuse across
        # layers is what a real compiler does; this is the memory report.
        cfg, step, args, donate = build_cell(
            arch, shape_name, mesh, rules, microbatches=microbatches,
            remat=remat, fsdp_over_pod=fsdp_over_pod, unroll=False)
        with mesh, activation_policy(policy):
            lowered = jax.jit(step, donate_argnums=donate).lower(*args)
            t1 = time.time()
            compiled_s = lowered.compile()
            mem = compiled_s.memory_analysis()
        if fast:
            # fast mode (mamba2: 64 unrolled SSD layers do not compile in
            # container time): reuse the scan-pass artifact; cost_analysis
            # counted each while body ONCE, so the roofline corrects
            # per-layer quantities by the scan trip count (recorded below).
            t2 = time.time()
            cost = compiled_s.cost_analysis() or {}
            text = compiled_s.as_text()
        else:
            # pass 2 — unrolled layers: XLA cost_analysis counts a while
            # body once (not x trip count), so FLOPs/collective bytes need
            # the layers inline.  (Temp bytes from this pass are
            # pessimistic on the CPU backend and are NOT reported.)
            cfg, step, args, donate = build_cell(
                arch, shape_name, mesh, rules, microbatches=microbatches,
                remat=remat, fsdp_over_pod=fsdp_over_pod, unroll=True)
            with mesh, activation_policy(policy):
                lowered_u = jax.jit(step, donate_argnums=donate).lower(*args)
                compiled = lowered_u.compile()
                t2 = time.time()
                cost = compiled.cost_analysis() or {}
                text = compiled.as_text()
        colls = parse_collectives(text)
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "tag": tag, "status": "ok",
            "devices": int(len(mesh.devices.flatten())),
            "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
            },
            "cost": {
                "flops_per_device": float(cost.get("flops", -1.0)),
                "bytes_per_device": float(cost.get("bytes accessed", -1.0)),
            },
            "collectives": colls,
            "collective_bytes_per_device": total_collective_bytes(text),
            "ops": op_histogram(text),
            "model_flops_total": model_flops(cfg, shape_name),
            "params_total": cfg.param_count(),
            "params_active": cfg.active_param_count(),
            "counting": "scan_body_once" if fast else "unrolled",
            "scan_repeats": cfg.num_layers // cfg.block_size,
        }
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        per_dev_gb = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                      ) / (1 << 30)
        print(f"[dryrun] OK   {name}: compile {rec['compile_s']}s, "
              f"{per_dev_gb:.2f} GiB/dev, "
              f"{rec['cost']['flops_per_device']/1e9:.1f} GFLOP/dev, "
              f"coll {rec['collective_bytes_per_device']/1e6:.1f} MB/dev")
        return rec
    except Exception as e:  # record failures; they are bugs to fix
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "error", "error": repr(e),
               "trace": traceback.format_exc()[-2000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[dryrun] FAIL {name}: {e}")
        return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="assignment id, e.g. yi-9b (default: all)")
    ap.add_argument("--shape", default=None,
                    help="train_4k|prefill_32k|decode_32k|long_500k")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "none"])
    ap.add_argument("--fsdp-over-pod", action="store_true")
    ap.add_argument("--policy", default="baseline",
                    choices=["baseline", "fullsp"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--fast", action="store_true",
                    help="single scan-pass compile (see run_cell docstring)")
    args = ap.parse_args()

    archs = ([args.arch] if args.arch else list(ALIASES.keys()))
    shapes = ([args.shape] if args.shape else list(LM_SHAPES.keys()))
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                rec = run_cell(arch, shape, mk, out_dir=args.out,
                               force=args.force,
                               microbatches=args.microbatches,
                               remat=(False if args.remat == "none"
                                      else args.remat),
                               fsdp_over_pod=args.fsdp_over_pod,
                               policy_variant=args.policy,
                               fast=args.fast,
                               tag=args.tag)
                if rec and rec.get("status") == "error":
                    failures += 1
    print(f"[dryrun] done, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
