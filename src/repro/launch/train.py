"""Production training launcher.

On a real fleet this runs once per host::

    python -m repro.launch.train --arch qwen2-0.5b --corpus corpus.npz \
        --coordinator $COORD:1234 --num-hosts 64 --host-id $ID \
        --mesh 16x16 --steps 10000 --ckpt-dir gs://...

`jax.distributed.initialize` wires the hosts together; the mesh spans all
devices; every host feeds its own data-parallel shard from the same
deterministic compressed-corpus stream (restart- and topology-exact).  On
this container it degrades gracefully to the local device count — the same
code path the multi-device subprocess tests exercise.
"""

from __future__ import annotations

import argparse
import os

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU containers)")
    ap.add_argument("--corpus", default=None,
                    help=".npz compressed corpus (default: synthetic E)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--mesh", default=None,
                    help="DxM data x model (default: all devices x 1)")
    # multi-host wiring
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    args = ap.parse_args()

    if args.coordinator:
        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_hosts, process_id=args.host_id)

    from repro.configs import get_config
    from repro.data import BatchPipeline, CompressedCorpus, synthetic
    from repro.distributed import (batch_shardings, default_rules,
                                   param_shardings)
    from repro.models import init_lm, reduced, unbox
    from repro.training import AdamW, StragglerWatchdog, make_train_step, \
        train

    if args.corpus:
        cc = CompressedCorpus.load(args.corpus)
    else:
        spec = synthetic.TABLE2["E"]
        cc = CompressedCorpus.build(synthetic.make_table2_corpus("E"),
                                    vocab_size=spec.vocab)
    print(f"[train] corpus: {cc.stats()}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, vocab_size=max(cc.ga.vocab_size + 1, 257),
                      dtype="float32")

    # mesh + shardings
    n_dev = len(jax.devices())
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
    else:
        d, m = n_dev, 1
    mesh = jax.make_mesh((d, m), ("data", "model"))
    rules = default_rules(mesh)

    boxed = init_lm(jax.random.PRNGKey(0), cfg)
    params, axes = unbox(boxed)
    params = jax.tree.map(jax.device_put, params,
                          param_shardings(axes, params, mesh, rules))

    opt = AdamW(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                schedule="cosine", total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt,
                                      microbatches=args.microbatches),
                      donate_argnums=(0, 1))

    shard_id = jax.process_index()
    pipeline = BatchPipeline(cc, global_batch=args.global_batch,
                             seq_len=args.seq_len, seed=0,
                             shard=shard_id,
                             num_shards=jax.process_count(), prefetch=2)
    wd = StragglerWatchdog(on_straggler=lambda s, dt, ema: print(
        f"[watchdog] host {shard_id}: step {s} {dt:.2f}s vs ema {ema:.2f}s"))
    with mesh:
        out = train(cfg, params, opt, pipeline, steps=args.steps,
                    ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                    train_step=step_fn, watchdog=wd)
    print(f"[train] done: loss {out['history'][0]:.3f} -> "
          f"{out['history'][-1]:.3f}, stragglers {out['straggler_events']}")
    pipeline.close()


if __name__ == "__main__":
    main()
