# Launchers: mesh factory, multi-pod dry-run, roofline extraction,
# train/serve drivers.  NOTE: dryrun.py sets XLA_FLAGS at import; import it
# only in dedicated processes.
