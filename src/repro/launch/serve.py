"""Serving launcher: batched KV-cache decode with request padding.

    python -m repro.launch.serve --arch yi-9b --reduced --batch 8 --steps 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import init_cache, init_lm, reduced, unbox
    from repro.serving import make_serve_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, dtype="float32")
    params, _ = unbox(init_lm(jax.random.PRNGKey(0), cfg))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32))
    cache = init_cache(cfg, args.batch, args.prompt_len + args.steps)
    sample = "greedy" if args.temperature == 0 else "categorical"
    step = jax.jit(make_serve_step(cfg, sample=sample,
                                   temperature=max(args.temperature, 1e-3)),
                   donate_argnums=(1,), static_argnames=())

    tok = None
    key = jax.random.PRNGKey(0)
    for t in range(args.prompt_len):
        tok, cache, _ = step(params, cache, prompts[:, t:t + 1], key)
    jax.block_until_ready(tok)
    t0 = time.perf_counter()
    gen = []
    for _ in range(args.steps):
        gen.append(int(tok[0, 0]))
        key, sub = jax.random.split(key)
        tok, cache, _ = step(params, cache, tok, sub)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"[serve] {args.arch}: {args.batch * args.steps / dt:.0f} tok/s "
          f"(batch {args.batch})")
    print(f"[serve] request 0 ids: {gen[:16]}")


if __name__ == "__main__":
    main()
