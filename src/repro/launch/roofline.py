"""Roofline extraction: dryrun JSONs -> three-term analysis per cell.

TPU v5e constants (assignment):
    peak bf16 compute   197 TFLOP/s per chip
    HBM bandwidth       819 GB/s per chip
    ICI link bandwidth  ~50 GB/s per link

Terms (seconds, per chip, per step):
    compute    = HLO_flops / 197e12
    memory     = HLO_bytes / 819e9
    collective = collective_bytes / 50e9

"useful" = MODEL_FLOPS / HLO_flops (6*N_active*D train, 2*N_active*D
forward) — how much compiled compute is model math vs remat/dispatch/
attention overheads.  "roofline_frac" = useful compute time / the dominant
term: the fraction of the step's lower bound spent doing model math — the
score the perf loop drives up.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


def analyze(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    dev = rec["devices"]
    flops = rec["cost"]["flops_per_device"]
    hbm_bytes = rec["cost"]["bytes_per_device"]
    coll_bytes = rec["collective_bytes_per_device"]
    approx = False
    if rec.get("counting") == "scan_body_once":
        # fast-mode cells (mamba2): the artifact counted each scan body
        # once; correct per-layer quantities by the trip count (slightly
        # overcounts the non-layer embed/loss parts — marked "~" in tables)
        rep = max(int(rec.get("scan_repeats", 1)), 1)
        flops *= rep
        hbm_bytes *= rep
        coll_bytes *= rep
        approx = True
    t_c = flops / PEAK_FLOPS
    t_m = hbm_bytes / HBM_BW
    t_x = coll_bytes / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    model_per_dev = rec["model_flops_total"] / dev
    useful = model_per_dev / flops if flops > 0 else 0.0
    bound = max(terms.values())
    if rec["shape"].startswith(("decode", "long")):
        # Decode is intrinsically memory-bound: one token touches every
        # active parameter once.  The roofline fraction compares the
        # *intrinsic* byte traffic (active params in bf16, read once per
        # step — KV/state reads are batch-amortized extra) against the
        # bound; the model-FLOP metric would be ~0 by construction.
        useful_bytes = rec["params_active"] * 2 / dev
        frac = (useful_bytes / HBM_BW) / bound if bound > 0 else 0.0
    else:
        frac = (model_per_dev / PEAK_FLOPS) / bound if bound > 0 else 0.0
    mem_gib = (rec["memory"]["argument_bytes"] +
               rec["memory"]["temp_bytes"]) / 2**30
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", ""), "approx": approx,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dominant, "bound_s": bound,
        "useful_flop_ratio": useful, "roofline_frac": frac,
        "hbm_gib_per_dev": mem_gib,
        "flops_per_dev": flops, "coll_gib": coll_bytes / 2**30,
    }


def load_all(dryrun_dir: str = DRYRUN_DIR, tag: str = "") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("tag", "") != tag:
            continue
        a = analyze(rec)
        if a:
            rows.append(a)
        elif rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "skipped": rec["reason"]})
    return rows


def render(rows: List[Dict], fmt: str = "md") -> str:
    out = []
    if fmt == "md":
        out.append("| arch | shape | mesh | compute s | memory s | "
                   "collective s | dominant | useful | roofline | GiB/dev |")
        out.append("|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            if "skipped" in r:
                out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                           f"— | — | — | SKIP ({r['skipped'][:40]}…) | | | |")
                continue
            ap = "~" if r.get("approx") else ""
            out.append(
                f"| {r['arch']}{ap} | {r['shape']} | {r['mesh']} | "
                f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
                f"{r['collective_s']:.4f} | **{r['dominant']}** | "
                f"{r['useful_flop_ratio']:.2f} | {r['roofline_frac']:.3f} | "
                f"{r['hbm_gib_per_dev']:.1f} |")
    else:
        out.append("arch,shape,mesh,compute_s,memory_s,collective_s,"
                   "dominant,useful,roofline_frac,gib_per_dev")
        for r in rows:
            if "skipped" in r:
                continue
            out.append(f"{r['arch']},{r['shape']},{r['mesh']},"
                       f"{r['compute_s']:.5f},{r['memory_s']:.5f},"
                       f"{r['collective_s']:.5f},{r['dominant']},"
                       f"{r['useful_flop_ratio']:.3f},"
                       f"{r['roofline_frac']:.3f},"
                       f"{r['hbm_gib_per_dev']:.2f}")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=DRYRUN_DIR)
    ap.add_argument("--fmt", default="md", choices=["md", "csv"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    print(render(load_all(args.dir, tag=args.tag), args.fmt))


if __name__ == "__main__":
    main()
