"""Mixture-of-Experts FFN with capacity-based sort dispatch.

Dispatch is FLOP-faithful (roofline depends on it): tokens are sorted by
assigned expert and gathered into an [E, C, d] buffer (capacity
C = tokens*top_k/E * capacity_factor; overflow drops, standard practice),
so expert compute is exactly E batched matmuls over C tokens — active
parameters only, not a dense all-experts einsum.

Sharding: the "expert" logical axis maps to the mesh "model" axis when E
divides it (EP: llama4 128/16, jamba 16/16); otherwise experts stay
replicated and the *within-expert* "ffn" axis shards instead (qwen2-moe:
60 experts, hidden 1408 = 16*88).  The mapping lives in
distributed/sharding.py; here we only tag logical axes.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import Boxed, dense_init, zeros_init, _dtype


def init_moe(key, cfg) -> Dict:
    d, E, ff = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
    dt = _dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), ("embed", "expert"),
                             jnp.float32),
        "wi": dense_init(ks[1], (E, d, ff), ("expert", "embed", "ffn"), dt),
        "wg": dense_init(ks[2], (E, d, ff), ("expert", "embed", "ffn"), dt),
        "wo": dense_init(ks[3], (E, ff, d), ("expert", "ffn", "embed"), dt),
    }
    if cfg.moe_shared_d_ff:
        sf = cfg.moe_shared_d_ff
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi": dense_init(k1, (d, sf), ("embed", "ffn"), dt),
            "wg": dense_init(k2, (d, sf), ("embed", "ffn"), dt),
            "wo": dense_init(k3, (sf, d), ("ffn", "embed"), dt),
        }
    return p


def apply_moe(p: Dict, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (y, aux_loss).

    Dispatch is GROUP-LOCAL: each batch row is a dispatch group (GShard
    convention), so the sort/rank/scatter machinery never crosses the
    data-parallel sharding of the batch dim — the only cross-shard traffic
    is the [B, E, C, d] expert buffer resharding from batch(data)-sharded
    to expert(model)-sharded, i.e. the canonical MoE all-to-all.  A global
    sort would instead make XLA all-gather every token (measured: 10x
    collective blow-up in the dry-run — see EXPERIMENTS.md §Perf).
    """
    B, S, d = x.shape
    E, K = cfg.moe_num_experts, cfg.moe_top_k
    N = S * K
    C = max(int(math.ceil(N / E * cfg.moe_capacity_factor)), 1)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                    # [B, S, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style, group-averaged)
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros(E).at[idx.reshape(-1)].add(1.0) / (B * N)
    aux = E * jnp.sum(me * ce)

    def group_dispatch(xg, idx_g, gate_g):
        """One group: xg [S, d], idx_g/gate_g [S, K] -> (xb [E,C,d],
        se/st/sg/keep/slot for the combine)."""
        flat_e = idx_g.reshape(-1)                         # [N]
        flat_t = jnp.repeat(jnp.arange(S), K)
        flat_g = gate_g.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sg = flat_e[order], flat_t[order], flat_g[order]
        seg_start = jnp.searchsorted(se, jnp.arange(E), side="left")
        rank = jnp.arange(N) - seg_start[se]
        keep = rank < C
        slot = jnp.where(keep, rank, C)                    # overflow -> C
        buf = jnp.zeros((E, C + 1, d), xg.dtype)
        buf = buf.at[se, slot].add(xg[st])
        return buf[:, :C, :], (se, st, sg, keep, slot)

    xb, meta = jax.vmap(group_dispatch)(x, idx, gate)      # xb [B,E,C,d]

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xb, p["wg"])) * \
        jnp.einsum("becd,edf->becf", xb, p["wi"])
    yb = jnp.einsum("becf,efd->becd", h, p["wo"])          # [B, E, C, d]

    def group_combine(yb_g, meta_g):
        se, st, sg, keep, slot = meta_g
        contrib = jnp.where(keep[:, None],
                            yb_g[se, slot].astype(jnp.float32) *
                            sg[:, None], 0.0)
        return jnp.zeros((S, d), jnp.float32).at[st].add(contrib)

    y = jax.vmap(group_combine)(yb, meta).astype(x.dtype)  # [B, S, d]

    if "shared" in p:
        sh = p["shared"]
        hs = jax.nn.silu(x @ sh["wg"]) * (x @ sh["wi"])
        y = y + hs @ sh["wo"]
    return y, aux
