"""Core layers (pure JAX, no flax): params are dict pytrees whose leaves are
``Boxed(value, axes)`` during init — ``axes`` are *logical* axis names that
the distribution layer maps to mesh axes (DESIGN.md §4).  ``unbox`` splits
the tree into (params, axes) before use.

Logical axes: "vocab", "embed" (d_model), "heads", "kv_heads", "head_dim",
"ffn", "expert", "ssm_*", None (replicated dim).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Boxed:
    value: Any
    axes: Tuple[Optional[str], ...]


jax.tree_util.register_pytree_node(
    Boxed,
    lambda b: ((b.value,), b.axes),
    lambda axes, ch: Boxed(ch[0], axes),
)


def _is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def stack_boxed(trees):
    """Stack a list of identically-structured Boxed trees along a new
    leading "layers" axis (the scan dimension)."""
    out = jax.tree.map(
        lambda *bs: Boxed(jnp.stack([b.value for b in bs]),
                          ("layers",) + bs[0].axes),
        *trees, is_leaf=_is_boxed)
    return out


def unbox(tree):
    params = jax.tree.map(lambda b: b.value, tree, is_leaf=_is_boxed)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=_is_boxed)
    return params, axes


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ------------------------------------------------------------------ init --
def dense_init(key, shape, axes, dtype, scale: float | None = None) -> Boxed:
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = (1.0 / math.sqrt(fan_in)) if scale is None else scale
    v = jax.random.normal(key, shape, jnp.float32) * scale
    return Boxed(v.astype(dtype), axes)


def zeros_init(shape, axes, dtype) -> Boxed:
    return Boxed(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype) -> Boxed:
    return Boxed(jnp.ones(shape, dtype), axes)


# ----------------------------------------------------------------- norms --
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) +
            bias.astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------ rope --
def rope_frequencies(head_dim: int, fraction: float, theta: float
                     ) -> np.ndarray:
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float64) / rot))
    return inv.astype(np.float32)  # [rot/2]


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, inv_freq: jnp.ndarray
               ) -> jnp.ndarray:
    """x: [B, S, H, D]; positions: [B, S]; rotate the first 2*len(inv_freq)
    channels (partial rotary, stablelm-style when fraction < 1)."""
    rot = 2 * inv_freq.shape[0]
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # [B,S,rot/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), xp], axis=-1)


# ------------------------------------------------------------------- ffn --
def init_ffn(key, d_model: int, d_ff: int, act: str, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "wi": dense_init(k1, (d_model, d_ff), ("embed", "ffn"), dtype),
            "wg": dense_init(k2, (d_model, d_ff), ("embed", "ffn"), dtype),
            "wo": dense_init(k3, (d_ff, d_model), ("ffn", "embed"), dtype),
        }
    return {
        "wi": dense_init(k1, (d_model, d_ff), ("embed", "ffn"), dtype),
        "bi": zeros_init((d_ff,), ("ffn",), dtype),
        "wo": dense_init(k3, (d_ff, d_model), ("ffn", "embed"), dtype),
        "bo": zeros_init((d_model,), ("embed",), dtype),
    }


def apply_ffn(p: Dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
        return h @ p["wo"]
    h = jax.nn.gelu((x @ p["wi"]) + p["bi"])
    return h @ p["wo"] + p["bo"]


# ------------------------------------------------------------- attention --
def init_attention(key, cfg, cross: bool = False) -> Dict:
    d, H, Hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    dt = _dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (d, H, hd), ("embed", "heads", "head_dim"), dt),
        "wk": dense_init(k2, (d, Hkv, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wv": dense_init(k3, (d, Hkv, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wo": dense_init(k4, (H, hd, d), ("heads", "head_dim", "embed"), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init((H, hd), ("heads", "head_dim"), dt)
        p["bk"] = zeros_init((Hkv, hd), ("kv_heads", "head_dim"), dt)
        p["bv"] = zeros_init((Hkv, hd), ("kv_heads", "head_dim"), dt)
    return p


def _qkv(p: Dict, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, ...]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def gqa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool, q_offset: int | jnp.ndarray = 0,
                  kv_len: Optional[jnp.ndarray] = None,
                  chunk: int = 0) -> jnp.ndarray:
    """q: [B,Sq,H,D], k/v: [B,Skv,Hkv,D].  GQA by head-group reshape.

    ``q_offset``: absolute position of q[0] (decode: cache length).
    ``kv_len``: valid kv prefix length (decode with pre-allocated cache).
    ``chunk`` > 0: scan over kv blocks with online softmax (bounded memory
    for 32k prefill; the "flash-in-XLA" path).
    """
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / math.sqrt(D)

    q_pos = jnp.arange(Sq) + q_offset                       # [Sq]

    if chunk and Skv > chunk and Skv % chunk == 0:
        nblk = Skv // chunk
        kb = kf.reshape(B, nblk, chunk, Hkv, D)
        vb = vf.reshape(B, nblk, chunk, Hkv, D)

        def step(carry, blk):
            m, l, acc = carry
            kj, vj, j = blk
            kv_pos = j * chunk + jnp.arange(chunk)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kj) * scale
            mask = jnp.ones((Sq, chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= kv_pos[None, :]
            if kv_len is not None:
                mask &= kv_pos[None, :] < kv_len
            s = jnp.where(mask[None, :, None, None, :], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # explicit re-mask: a fully-masked block would otherwise give
            # exp(-1e30 - (-1e30)) == 1 and corrupt the running sum
            p = jnp.exp(s - m_new[..., None]) * mask[None, :, None, None, :]
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p, vj)
            return (m_new, l, acc), None

        m0 = jnp.full((B, Sq, Hkv, G), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
        a0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nblk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
    else:
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kf) * scale
        kv_pos = jnp.arange(Skv)
        mask = jnp.ones((Sq, Skv), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if kv_len is not None:
            mask &= kv_pos[None, :] < kv_len
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bqhgk,bkhd->bqhgd", p, vf)

    return out.reshape(B, Sq, H, D).astype(q.dtype)


def attn_out(p: Dict, ctx: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])
