"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Chunked SSD for training/prefill: the sequence is split into chunks of Q
tokens; within a chunk the dual quadratic form runs on the MXU
(``C B^T ⊙ decay`` matmuls), across chunks a small recurrent state
[H, P, N] is carried by ``lax.scan`` — O(S·Q) work, O(S) memory, exactly
the structure the paper's Fig. 3 block decomposition describes.

Single-token decode keeps the state (plus a depthwise-conv tail) in the
serving cache and does the O(1) recurrence.

Used by both mamba2-2.7b (pure SSM) and jamba (hybrid 1:7 attn:mamba —
jamba-v0.1 uses mamba1; we adapt to the SSD form per DESIGN.md hardware
notes: SSD is the TPU-friendly member of the family, MXU-dominated).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import Boxed, dense_init, zeros_init, ones_init, _dtype, rms_norm


def init_mamba(key, cfg) -> Dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    G = cfg.ssm_groups
    dt = _dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    conv_dim = di + 2 * G * N
    return {
        # order: [z | x | B | C | dt]
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * G * N + H),
                              ("embed", "ssm_inner"), dt),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_dim),
                             (None, "ssm_inner"), dt, scale=0.5),
        "conv_b": zeros_init((conv_dim,), ("ssm_inner",), dt),
        "A_log": Boxed(jnp.log(jnp.linspace(1.0, 16.0, H)), ("ssm_heads",)),
        "D": ones_init((H,), ("ssm_heads",), jnp.float32),
        "dt_bias": Boxed(
            jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
                ks[2], (H,), minval=math.log(1e-3), maxval=math.log(1e-1))))),
            ("ssm_heads",)),
        "norm": ones_init((di,), ("ssm_inner",), dt),
        "out_proj": dense_init(ks[3], (di, d), ("ssm_inner", "embed"), dt),
    }


def _split_proj(cfg, zxbcdt):
    di = cfg.ssm_expand * cfg.d_model
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    Bv = zxbcdt[..., 2 * di:2 * di + G * N]
    Cv = zxbcdt[..., 2 * di + G * N:2 * di + 2 * G * N]
    dtv = zxbcdt[..., 2 * di + 2 * G * N:]
    return z, x, Bv, Cv, dtv


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 tail: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv1d. xbc: [B,S,C]; w: [K,C]. ``tail``: [B,K-1,C]
    carry-in for decode continuity."""
    K = w.shape[0]
    if tail is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = tail.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)          # [B, S+K-1, C]
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def apply_mamba(p: Dict, x_in: jnp.ndarray, cfg, chunk: int = 64
                ) -> jnp.ndarray:
    """Training/prefill path. x_in: [B, S, d] -> [B, S, d]."""
    Bb, S, d = x_in.shape
    di = cfg.ssm_expand * d
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups

    zxbcdt = x_in @ p["in_proj"]
    z, xs, Bv, Cv, dtv = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xs, Bv, Cv], axis=-1)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, Bv, Cv = (xbc[..., :di], xbc[..., di:di + G * N],
                  xbc[..., di + G * N:])

    Xh = xs.reshape(Bb, S, H, P)
    Bg = Bv.reshape(Bb, S, G, N)
    Cg = Cv.reshape(Bb, S, G, N)
    rep = H // G
    Bh = jnp.repeat(Bg, rep, axis=2)                  # [B,S,H,N]
    Ch = jnp.repeat(Cg, rep, axis=2)

    dt_ = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    A = -jnp.exp(p["A_log"])                          # [H]
    dA = dt_ * A                                      # [B,S,H] log-decay

    y = _ssd_chunked(Xh.astype(jnp.float32), Bh.astype(jnp.float32),
                     Ch.astype(jnp.float32), dt_, dA, chunk)
    y = y + Xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bb, S, di)
    y = rms_norm(y.astype(x_in.dtype) * jax.nn.silu(z), p["norm"],
                 cfg.norm_eps)
    return y @ p["out_proj"]


def _ssd_chunked(X, B_, C_, dt_, dA, Q: int):
    """X:[B,S,H,P] B_,C_:[B,S,H,N] dt_,dA:[B,S,H] -> Y:[B,S,H,P] (f32)."""
    Bb, S, H, P = X.shape
    N = B_.shape[-1]
    if S % Q:
        pad = Q - S % Q
        X = jnp.pad(X, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_ = jnp.pad(dt_, ((0, 0), (0, pad), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
    Sp = X.shape[1]
    nc = Sp // Q

    def resh(t):
        return t.reshape((Bb, nc, Q) + t.shape[2:]).swapaxes(0, 1)

    Xc, Bc, Cc = resh(X), resh(B_), resh(C_)          # [nc,B,Q,H,*]
    dtc, dAc = resh(dt_), resh(dA)                    # [nc,B,Q,H]

    def step(h, blk):
        Xq, Bq, Cq, dtq, dAq = blk
        a = jnp.cumsum(dAq, axis=1)                   # [B,Q,H]
        a_last = a[:, -1:, :]                         # [B,1,H]
        # intra-chunk quadratic (the "dual" form, MXU matmuls)
        scores = jnp.einsum("bqhn,bkhn->bhqk", Cq, Bq)
        decay = jnp.exp(a[:, :, None, :] - a[:, None, :, :])  # [B,Q,K,H]
        qi = jnp.arange(Q)
        causal = (qi[:, None] >= qi[None, :])[None, :, :, None]
        L = jnp.where(causal, decay, 0.0).transpose(0, 3, 1, 2)  # [B,H,Q,K]
        dt_k = dtq.transpose(0, 2, 1)[:, :, None, :]             # [B,H,1,K]
        M = scores * L * dt_k
        y_intra = jnp.einsum("bhqk,bkhp->bqhp", M, Xq)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bqhn,bhpn->bqhp", Cq,
                             h) * jnp.exp(a)[..., None]
        # state update
        w = jnp.exp(a_last - a) * dtq                 # [B,Q,H]
        h_new = h * jnp.exp(a_last).transpose(0, 2, 1)[..., None] + \
            jnp.einsum("bqhp,bqhn,bqh->bhpn", Xq, Bq, w)
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    _, Yc = jax.lax.scan(step, h0, (Xc, Bc, Cc, dtc, dAc))
    Y = Yc.swapaxes(0, 1).reshape(Bb, Sp, H, P)
    return Y[:, :S]


def apply_mamba_decode(p: Dict, x_in: jnp.ndarray, state: Dict, cfg
                       ) -> Tuple[jnp.ndarray, Dict]:
    """One-token recurrence. x_in: [B, 1, d]; state: {"h": [B,H,P,N],
    "conv": [B,K-1,conv_dim]} -> (y [B,1,d], new state)."""
    Bb, _, d = x_in.shape
    di = cfg.ssm_expand * d
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    K = cfg.ssm_conv

    zxbcdt = x_in @ p["in_proj"]
    z, xs, Bv, Cv, dtv = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xs, Bv, Cv], axis=-1)      # [B,1,conv_dim]
    conv_in = jnp.concatenate([state["conv"], xbc], axis=1)  # [B,K,convd]
    out = sum(conv_in[:, i, :] * p["conv_w"][i] for i in range(K))
    xbc1 = jax.nn.silu(out + p["conv_b"])[:, None, :]
    new_conv = conv_in[:, 1:, :]

    xs, Bv, Cv = (xbc1[..., :di], xbc1[..., di:di + G * N],
                  xbc1[..., di + G * N:])
    Xh = xs.reshape(Bb, H, P).astype(jnp.float32)
    rep = H // G
    Bh = jnp.repeat(Bv.reshape(Bb, G, N), rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cv.reshape(Bb, G, N), rep, axis=1).astype(jnp.float32)
    dt_ = jax.nn.softplus(dtv[:, 0, :].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt_ * A)                          # [B,H]

    h = state["h"] * decay[..., None, None] + \
        jnp.einsum("bhp,bhn,bh->bhpn", Xh, Bh, dt_)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h)
    y = y + Xh * p["D"][None, :, None]
    y = y.reshape(Bb, 1, di)
    y = rms_norm(y.astype(x_in.dtype) * jax.nn.silu(z), p["norm"],
                 cfg.norm_eps)
    return y @ p["out_proj"], {"h": h, "conv": new_conv}


def init_mamba_state(cfg, batch: int, dtype=jnp.float32) -> Dict:
    di = cfg.ssm_expand * cfg.d_model
    conv_dim = di + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                        cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }
