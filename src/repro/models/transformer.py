"""The unified LM: covers all 10 assigned architectures.

Layer pattern (attention / mamba mixers, dense / MoE FFNs) comes from the
config; layers are *scanned* in repeating blocks of ``cfg.block_size``
positions (jamba: 8, moe-every-2: 2, uniform: 1) — HLO stays one block
big regardless of depth, which keeps 512-device dry-run compiles tractable
and matches how production frameworks (MaxText et al.) stack layers.

Entry points:
  init_lm(cfg, key)                      -> Boxed param tree
  apply_lm(cfg, params, tokens, ...)     -> logits  (train / prefill)
  init_cache(cfg, batch, max_len)        -> decode cache (KV / SSM state)
  decode_step(cfg, params, cache, tok, pos) -> (logits, cache)

Whisper (family "encdec") adds an encoder stack + cross-attention; Pixtral
(family "vlm") prepends stub patch embeddings.  Both frontends are stubs
per the assignment — ``input_specs`` supplies precomputed frame/patch
embeddings.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import (Boxed, _dtype, apply_ffn, apply_rope, attn_out,
                     dense_init, gqa_attention, init_attention, init_ffn,
                     layer_norm, ones_init, rms_norm, rope_frequencies,
                     stack_boxed, unbox, zeros_init, _qkv)
from .moe import apply_moe, init_moe
from .partitioning import constrain
from .ssm import (apply_mamba, apply_mamba_decode, init_mamba,
                  init_mamba_state)

ATTN_CHUNK_THRESHOLD = 8_192   # chunked (online-softmax) attention above this
ATTN_CHUNK = 1_024


# ------------------------------------------------------------------ init --
def _init_norm(cfg, dt):
    if cfg.act == "gelu":   # whisper-style layernorm
        return {"scale": ones_init((cfg.d_model,), ("embed",), dt),
                "bias": zeros_init((cfg.d_model,), ("embed",), dt)}
    return {"scale": ones_init((cfg.d_model,), ("embed",), dt)}


def _apply_norm(cfg, p, x):
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def _init_layer(key, cfg: ModelConfig, kind: str, ffn_kind: str,
                cross: bool) -> Dict:
    dt = _dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": _init_norm(cfg, dt)}
    if kind == "attn":
        p["attn"] = init_attention(ks[0], cfg)
    else:
        p["mamba"] = init_mamba(ks[0], cfg)
    if ffn_kind == "moe":
        p["norm2"] = _init_norm(cfg, dt)
        p["moe"] = init_moe(ks[1], cfg)
    elif cfg.d_ff > 0:
        p["norm2"] = _init_norm(cfg, dt)
        p["ffn"] = init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dt)
    if cross:
        p["cross_norm"] = _init_norm(cfg, dt)
        p["cross"] = init_attention(ks[2], cfg, cross=True)
    return p


def init_lm(key, cfg: ModelConfig) -> Dict:
    dt = _dtype(cfg.dtype)
    bs = cfg.block_size
    assert cfg.num_layers % bs == 0, (cfg.name, cfg.num_layers, bs)
    repeats = cfg.num_layers // bs
    cross = cfg.family == "encdec"
    keys = jax.random.split(key, 8)

    params: Dict[str, Any] = {
        "embed": dense_init(keys[0], (cfg.vocab_size, cfg.d_model),
                            ("vocab", "embed"), dt, scale=0.02),
        "final_norm": _init_norm(cfg, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[1], (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dt)

    blocks = []
    lkeys = jax.random.split(keys[2], cfg.num_layers)
    for p_pos in range(bs):
        per_repeat = []
        for r in range(repeats):
            i = r * bs + p_pos
            per_repeat.append(_init_layer(
                lkeys[i], cfg, cfg.layer_kind(i), cfg.layer_ffn(i), cross))
        blocks.append(stack_boxed(per_repeat))
    params["blocks"] = blocks

    if cfg.family == "encdec":
        ekeys = jax.random.split(keys[3], cfg.encoder_layers + 1)
        enc_layers = [
            _init_layer(ekeys[i], cfg, "attn", "dense", cross=False)
            for i in range(cfg.encoder_layers)]
        params["encoder"] = {
            "layers": stack_boxed(enc_layers),
            "final_norm": _init_norm(cfg, dt),
        }
    if cfg.family == "vlm":
        params["patch_proj"] = dense_init(
            keys[4], (cfg.d_model, cfg.d_model), ("embed", None), dt)
    return params


# --------------------------------------------------------------- forward --
def _sinusoid(seq: int, d: int) -> np.ndarray:
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * i / d)
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


def _sinusoid_at(pos: jnp.ndarray, d: int) -> jnp.ndarray:
    """Dynamic single-position sinusoid (decode path)."""
    i = jnp.arange(d // 2)
    ang = pos.astype(jnp.float32) / jnp.power(10_000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _mixer(cfg, p, x, positions, inv_freq, *, kind, chunk, enc_out=None):
    h = _apply_norm(cfg, p["norm1"], x)
    if kind == "attn":
        q, k, v = _qkv(p["attn"], h, cfg)
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)
        # sequence-parallel attention (SP): queries shard over the model
        # axis when head counts don't divide it — policy-installed, no-op
        # otherwise (see launch/dryrun.make_activation_policy)
        q = constrain(q, "attn_q")
        ctx = gqa_attention(q, k, v, causal=True, chunk=chunk)
        x = x + attn_out(p["attn"], ctx)
    else:
        x = x + apply_mamba(p["mamba"], h, cfg)
    if enc_out is not None and "cross" in p:
        h = _apply_norm(cfg, p["cross_norm"], x)
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"])
        q = constrain(q, "attn_q")    # SP: cross scores shard over q-seq
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"])
        if "bq" in p["cross"]:
            q, k, v = q + p["cross"]["bq"], k + p["cross"]["bk"], v + p["cross"]["bv"]
        ctx = gqa_attention(q, k, v, causal=False, chunk=0)
        x = x + attn_out(p["cross"], ctx)
    return x


def _ffn_block(cfg, p, x):
    if "moe" in p:
        h = _apply_norm(cfg, p["norm2"], x)
        y, aux = apply_moe(p["moe"], h, cfg)
        return x + y, aux
    if "ffn" in p:
        h = _apply_norm(cfg, p["norm2"], x)
        return x + apply_ffn(p["ffn"], h, cfg.act), jnp.float32(0.0)
    return x, jnp.float32(0.0)   # mixer-only layer (mamba2)


def _encoder(cfg, params, frames: jnp.ndarray,
             unroll: bool = False) -> jnp.ndarray:
    """Whisper encoder over stub frame embeddings [B, T, d]."""
    x = frames + jnp.asarray(_sinusoid(frames.shape[1], cfg.d_model)
                             ).astype(frames.dtype)
    inv_freq = jnp.asarray(rope_frequencies(
        cfg.resolved_head_dim, 0.0, cfg.rope_theta))  # no rope (sinusoid)
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1]),
                                 frames.shape[:2])
    lp = params["encoder"]["layers"]

    def body(x, layer):
        h = _apply_norm(cfg, layer["norm1"], x)
        q, k, v = _qkv(layer["attn"], h, cfg)
        ctx = gqa_attention(q, k, v, causal=False, chunk=0)
        x = x + attn_out(layer["attn"], ctx)
        x, _ = _ffn_block(cfg, layer, x)
        x = constrain(x, "act_btd")
        return x, None

    body = jax.checkpoint(body)   # encoder layers remat like decoder blocks
    if unroll:
        n = jax.tree.leaves(lp)[0].shape[0]
        for r in range(n):
            x, _ = body(x, jax.tree.map(lambda a: a[r], lp))
    else:
        x, _ = jax.lax.scan(body, x, lp)
    return _apply_norm(cfg, params["encoder"]["final_norm"], x)


def apply_lm(cfg: ModelConfig, params: Dict, tokens: jnp.ndarray,
             extra_embeds: Optional[jnp.ndarray] = None,
             remat: bool = True, unroll: bool = False
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits [B, S(, +P), V] float32, moe aux loss scalar).

    ``extra_embeds``: whisper frame embeddings [B, T, d] (encoder input) or
    pixtral patch embeddings [B, P, d] (prepended to the text sequence).
    """
    dt = _dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    enc_out = None
    if cfg.family == "encdec":
        assert extra_embeds is not None
        enc_out = _encoder(cfg, params, extra_embeds.astype(dt),
                           unroll=unroll)
        x = x + jnp.asarray(_sinusoid(x.shape[1], cfg.d_model)).astype(dt)
    elif cfg.family == "vlm" and extra_embeds is not None:
        patches = extra_embeds.astype(dt) @ params["patch_proj"]
        x = jnp.concatenate([patches, x], axis=1)

    x = constrain(x, "act_btd")
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    inv_freq = jnp.asarray(rope_frequencies(
        cfg.resolved_head_dim, cfg.rope_fraction, cfg.rope_theta))
    chunk = ATTN_CHUNK if S > ATTN_CHUNK_THRESHOLD else 0

    bs = cfg.block_size
    repeats = cfg.num_layers // bs
    stacked = params["blocks"]

    def layer_at(p_pos):
        def f(x, lp):
            x = _mixer(cfg, lp, x, positions, inv_freq,
                       kind=cfg.layer_kind(p_pos), chunk=chunk,
                       enc_out=enc_out)
            x, a = _ffn_block(cfg, lp, x)
            x = constrain(x, "act_btd")
            return x, a
        return f

    layer_fns = [layer_at(p) for p in range(bs)]
    if remat and bs > 1:
        # multi-layer blocks (jamba: 8, llama4: 2): remat each layer inside
        # the block too, else backward materializes the whole block at once
        layer_fns = [jax.checkpoint(f) for f in layer_fns]

    def block_body(carry, layer_slices):
        x, aux = carry
        for p_pos in range(bs):
            x, a = layer_fns[p_pos](x, layer_slices[p_pos])
            aux = aux + a
        return (x, aux), None

    if remat in (True, "full"):
        body = jax.checkpoint(block_body)
    elif remat == "dots":
        # save matmul outputs: halves recompute (and its FSDP re-gathers)
        # at the cost of stashing per-layer GEMM results
        body = jax.checkpoint(
            block_body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    else:
        body = block_body
    if unroll:
        # dry-run mode: XLA's cost_analysis counts a while body once, not
        # x trip-count, so roofline FLOP extraction needs the layers inline
        # (production training keeps the scan: small HLO, same math).
        carry = (x, jnp.float32(0.0))
        for r in range(repeats):
            sl = tuple(jax.tree.map(lambda a: a[r], s) for s in stacked)
            carry, _ = body(carry, sl)
        x, aux = carry
    else:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                   tuple(stacked))

    x = _apply_norm(cfg, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x @ head.astype(dt)).astype(jnp.float32)
    logits = constrain(logits, "logits")
    return logits, aux


# ---------------------------------------------------------------- decode --
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Pre-allocated decode cache: KV rings for attn layers, SSD state for
    mamba layers, cross-attn KV for encdec."""
    dt = _dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    bs = cfg.block_size
    repeats = cfg.num_layers // bs
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32), "layers": []}
    for p_pos in range(bs):
        kind = cfg.layer_kind(p_pos)
        if kind == "attn":
            entry = {
                "k": jnp.zeros((repeats, batch, max_len, cfg.num_kv_heads,
                                hd), dt),
                "v": jnp.zeros((repeats, batch, max_len, cfg.num_kv_heads,
                                hd), dt),
            }
        else:
            st = init_mamba_state(cfg, batch, dt)
            entry = {
                "h": jnp.zeros((repeats,) + st["h"].shape, jnp.float32),
                "conv": jnp.zeros((repeats,) + st["conv"].shape, dt),
            }
        cache["layers"].append(entry)
    if cfg.family == "encdec":
        cache["cross_k"] = jnp.zeros(
            (cfg.num_layers, batch, cfg.encoder_seq, cfg.num_kv_heads, hd), dt)
        cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    return cache


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: jnp.ndarray,
                unroll: bool = False) -> Tuple[jnp.ndarray, Dict]:
    """One decode step for the whole batch.  tokens: [B, 1] -> logits
    [B, 1, V].  cache["pos"] is the write position (tokens so far)."""
    dt = _dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]           # [B, 1, d]
    B = x.shape[0]
    pos = cache["pos"]
    if cfg.family == "encdec":
        x = x + _sinusoid_at(pos[None], cfg.d_model).astype(dt)[None, :]
    positions = jnp.full((B, 1), pos, jnp.int32)
    inv_freq = jnp.asarray(rope_frequencies(
        cfg.resolved_head_dim, cfg.rope_fraction, cfg.rope_theta))

    bs = cfg.block_size
    repeats = cfg.num_layers // bs
    stacked = params["blocks"]
    new_layers = []

    def layer_step(carry, slices):
        x, = carry
        updates = []
        for p_pos in range(bs):
            lp = slices[2 * p_pos]
            ce = slices[2 * p_pos + 1]
            kind = cfg.layer_kind(p_pos)
            h = _apply_norm(cfg, lp["norm1"], x)
            if kind == "attn":
                q, k1, v1 = _qkv(lp["attn"], h, cfg)
                q = apply_rope(q, positions, inv_freq)
                k1 = apply_rope(k1, positions, inv_freq)
                k = jax.lax.dynamic_update_slice_in_dim(ce["k"], k1, pos, 1)
                v = jax.lax.dynamic_update_slice_in_dim(ce["v"], v1, pos, 1)
                ctx = gqa_attention(q, k, v, causal=False, q_offset=pos,
                                    kv_len=pos + 1, chunk=0)
                x = x + attn_out(lp["attn"], ctx)
                updates.append({"k": k, "v": v})
            else:
                y, st = apply_mamba_decode(
                    lp["mamba"], h, {"h": ce["h"], "conv": ce["conv"]}, cfg)
                x = x + y
                updates.append(st)
            if "cross" in lp:
                hc = _apply_norm(cfg, lp["cross_norm"], x)
                q = jnp.einsum("bsd,dhk->bshk", hc, lp["cross"]["wq"])
                if "bq" in lp["cross"]:
                    q = q + lp["cross"]["bq"]
                ctx = gqa_attention(q, slices[-2], slices[-1], causal=False,
                                    chunk=0)
                x = x + attn_out(lp["cross"], ctx)
            x, _ = _ffn_block(cfg, lp, x)
            x = constrain(x, "act_btd")
        return (x,), tuple(updates)

    # scan over repeats, threading cache slices in/out
    xs = []
    for p_pos in range(bs):
        xs.append(stacked[p_pos])
        xs.append(cache["layers"][p_pos])
    if cfg.family == "encdec":
        xs.append(cache["cross_k"].reshape(
            (repeats, bs) + cache["cross_k"].shape[1:])[:, 0])
        xs.append(cache["cross_v"].reshape(
            (repeats, bs) + cache["cross_v"].shape[1:])[:, 0])

    if unroll:
        ups = []
        carry = (x,)
        for r in range(repeats):
            carry, up = layer_step(
                carry, jax.tree.map(lambda a: a[r], tuple(xs)))
            ups.append(up)
        (x,) = carry
        updates = jax.tree.map(lambda *us: jnp.stack(us), *ups)
    else:
        (x,), updates = jax.lax.scan(layer_step, (x,), tuple(xs))
    for p_pos in range(bs):
        new_layers.append(updates[p_pos])

    x = _apply_norm(cfg, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x @ head.astype(dt)).astype(jnp.float32)
    logits = constrain(logits, "logits")

    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    new_cache["pos"] = pos + 1
    return logits, new_cache


def prefill_cross(cfg: ModelConfig, params: Dict, cache: Dict,
                  frames: jnp.ndarray) -> Dict:
    """Run the whisper encoder once and fill cross-attention K/V."""
    dt = _dtype(cfg.dtype)
    enc_out = _encoder(cfg, params, frames.astype(dt))
    ks, vs = [], []
    for p_pos in range(cfg.block_size):
        lp = params["blocks"][p_pos]
        cr = lp["cross"]
        k = jnp.einsum("rbsd,rdhk->rbshk",
                       jnp.broadcast_to(enc_out, (cr["wk"].shape[0],) +
                                        enc_out.shape), cr["wk"])
        v = jnp.einsum("rbsd,rdhk->rbshk",
                       jnp.broadcast_to(enc_out, (cr["wv"].shape[0],) +
                                        enc_out.shape), cr["wv"])
        if "bk" in cr:
            # stacked biases: [repeats, Hkv, hd] -> broadcast over (B, S)
            k = k + cr["bk"][:, None, None]
            v = v + cr["bv"][:, None, None]
        ks.append(k)
        vs.append(v)
    cache = dict(cache)
    cache["cross_k"] = jnp.concatenate(ks, axis=0)
    cache["cross_v"] = jnp.concatenate(vs, axis=0)
    return cache
