"""Model configuration shared by all 10 assigned architectures.

One dataclass covers the whole pool: dense GQA transformers, MoE,
hybrid Mamba+attention (jamba), pure SSM (mamba2), encoder-decoder
(whisper), and VLM (pixtral).  Family-specific fields default to "off".
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int              # 0 for attention-free archs
    num_kv_heads: int
    d_ff: int                   # dense-FFN hidden (0 if none)
    vocab_size: int

    head_dim: int = 0           # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_fraction: float = 1.0  # stablelm uses partial rotary (0.25)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "swiglu"         # swiglu | gelu (whisper)

    # ---- MoE ----
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1          # layer i is MoE iff (i % moe_every == moe_offset)
    moe_offset: int = 0
    moe_d_ff: int = 0           # routed expert hidden
    moe_shared_d_ff: int = 0    # shared-expert hidden (0 = none)
    moe_capacity_factor: float = 1.25

    # ---- SSM (mamba2 / jamba mamba layers) ----
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1
    attn_layer_period: int = 0  # hybrid: 1 attention layer per period
    attn_layer_offset: int = 0

    # ---- encoder-decoder (whisper) ----
    encoder_layers: int = 0
    encoder_seq: int = 0        # stub frontend output frames

    # ---- VLM (pixtral) ----
    num_patches: int = 0        # stub vision tower output patches

    dtype: str = "bfloat16"

    # ------------------------------------------------------------------ --
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    def layer_kind(self, i: int) -> str:
        """ "attn" | "mamba" for the mixer of layer i."""
        if self.family == "ssm":
            return "mamba"
        if self.attn_layer_period:
            return ("attn" if i % self.attn_layer_period ==
                    self.attn_layer_offset else "mamba")
        return "attn"

    def layer_ffn(self, i: int) -> str:
        """ "dense" | "moe" for the FFN of layer i."""
        if (self.moe_num_experts and
                i % self.moe_every == self.moe_offset):
            return "moe"
        return "dense"

    @property
    def block_size(self) -> int:
        """Smallest repeating layer pattern (scan unit)."""
        b = self.moe_every if self.moe_num_experts else 1
        if self.attn_layer_period:
            b = _lcm(b, self.attn_layer_period)
        return b

    # -------------------------------------------------------- accounting --
    def param_count(self) -> int:
        """Exact parameter count (embeddings included)."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # lm head
        for i in range(self.num_layers):
            n += self._mixer_params(self.layer_kind(i))
            has_ffn = self.layer_ffn(i) == "moe" or self.d_ff > 0
            if has_ffn:
                n += self._ffn_params(self.layer_ffn(i))
            n += d * (2 if has_ffn else 1)            # norms
        n += d                                        # final norm
        if self.encoder_layers:
            for _ in range(self.encoder_layers):
                n += self._attn_params(cross=False) + self._ffn_params("dense") + 2 * d
            n += d
            # decoder cross-attention blocks
            n += self.num_layers * (self._attn_params(cross=True) + d)
        if self.num_patches:
            n += d * d                                # patch merger stub proj
        return n

    def _attn_params(self, cross: bool = False) -> int:
        d = self.d_model
        hd = self.resolved_head_dim
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        b = (self.num_heads * hd + 2 * self.num_kv_heads * hd) if self.qkv_bias else 0
        return q + kv + o + b

    def _mamba_params(self) -> int:
        d = self.d_model
        di = self.ssm_expand * d
        nh = self.ssm_heads
        ns = self.ssm_state
        g = self.ssm_groups
        in_proj = d * (2 * di + 2 * g * ns + nh)      # z, x, B, C, dt
        conv = self.ssm_conv * (di + 2 * g * ns)
        out = di * d
        extras = nh * 2 + di                           # A_log, D, dt_bias... (norm)
        return in_proj + conv + out + extras

    def _mixer_params(self, kind: str) -> int:
        return self._attn_params() if kind == "attn" else self._mamba_params()

    def _ffn_params(self, kind: str) -> int:
        d = self.d_model
        mult = 3 if self.act == "swiglu" else 2
        if kind == "dense":
            return mult * d * self.d_ff
        n = self.moe_num_experts * mult * d * self.moe_d_ff   # routed
        n += d * self.moe_num_experts                         # router
        if self.moe_shared_d_ff:
            n += mult * d * self.moe_shared_d_ff              # shared expert
        return n

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top-k routed + shared)."""
        d = self.d_model
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        mult = 3 if self.act == "swiglu" else 2
        for i in range(self.num_layers):
            has_ffn = self.layer_ffn(i) == "moe" or self.d_ff > 0
            n += self._mixer_params(self.layer_kind(i)) + d * (2 if has_ffn else 1)
            if self.layer_ffn(i) == "dense":
                n += mult * d * self.d_ff
            else:
                n += self.moe_top_k * mult * d * self.moe_d_ff
                n += d * self.moe_num_experts
                if self.moe_shared_d_ff:
                    n += mult * d * self.moe_shared_d_ff
        n += d
        return n


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    base = dict(
        num_layers=max(2, cfg.block_size),
        d_model=64,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(max(cfg.num_kv_heads, 0), 2) if cfg.num_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=257,
        head_dim=16 if cfg.num_heads else 0,
    )
    if cfg.moe_num_experts:
        base.update(moe_num_experts=4, moe_top_k=min(cfg.moe_top_k, 2),
                    moe_d_ff=64,
                    moe_shared_d_ff=64 if cfg.moe_shared_d_ff else 0)
    if cfg.ssm_state:
        base.update(ssm_state=16, ssm_head_dim=16)
    if cfg.encoder_layers:
        base.update(encoder_layers=2, encoder_seq=24)
    if cfg.num_patches:
        base.update(num_patches=8)
    if cfg.attn_layer_period:
        base.update(num_layers=2 * cfg.attn_layer_period)
    base.update(overrides)
    return replace(cfg, name=cfg.name + "-smoke", **base)
