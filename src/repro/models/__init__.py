"""Model zoo: the 10 assigned architectures on one unified LM skeleton."""

from .config import ModelConfig, ShapeSpec, LM_SHAPES, reduced
from .layers import Boxed, unbox, stack_boxed
from .transformer import (init_lm, apply_lm, init_cache, decode_step,
                          prefill_cross)

__all__ = ["ModelConfig", "ShapeSpec", "LM_SHAPES", "reduced",
           "Boxed", "unbox", "stack_boxed",
           "init_lm", "apply_lm", "init_cache", "decode_step",
           "prefill_cross"]
