"""Activation-sharding policy hook.

GSPMD propagates parameter shardings well, but activation shardings can
degrade through ``scan`` + ``remat`` boundaries (the carry's sharding is
whatever the first iteration inferred).  Production frameworks pin
activations with explicit constraints; we do the same without coupling
model code to mesh axis names: the launcher installs a policy mapping
*activation kinds* to PartitionSpecs, and model code calls
``constrain(x, kind)`` at the few load-bearing points (embedding output,
block carry, logits, decode cache updates).

With no policy installed (unit tests, single-device runs) this is a no-op.
"""

from __future__ import annotations

import contextlib
from typing import Dict, Optional

import jax

_POLICY: Dict[str, object] = {}


def set_policy(policy: Optional[Dict[str, object]]) -> None:
    global _POLICY
    _POLICY = dict(policy or {})


def get_policy() -> Dict[str, object]:
    return dict(_POLICY)


@contextlib.contextmanager
def activation_policy(policy: Dict[str, object]):
    old = get_policy()
    set_policy(policy)
    try:
        yield
    finally:
        set_policy(old)


def constrain(x, kind: str):
    spec = _POLICY.get(kind)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
