"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 routed experts top-1 + 1 shared expert,
interleaved dense/MoE (every other layer MoE), early-fusion multimodal
backbone (text side here) [hf:meta-llama/Llama-4-*; unverified].
~400B total / ~17B active."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    moe_num_experts=128, moe_top_k=1, moe_every=2, moe_offset=1,
    moe_d_ff=8192, moe_shared_d_ff=8192,
    rope_theta=500_000.0,
)
