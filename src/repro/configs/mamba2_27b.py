"""mamba2-2.7b [ssm] — 64L d_model=2560 attention-free, ssm_state=128,
vocab=50280, SSD (state-space duality) mixers, no FFN blocks
[arXiv:2405.21060; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    tie_embeddings=True,
)
