"""whisper-large-v3 [audio] — enc-dec, 32L decoder (+32L encoder)
d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866, GELU FFN, layernorm,
conv audio frontend is a STUB per the assignment (input_specs provides
precomputed frame embeddings [B, 1500, d]) [arXiv:2212.04356; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866,
    act="gelu", qkv_bias=True,
    encoder_layers=32, encoder_seq=1500,
)
