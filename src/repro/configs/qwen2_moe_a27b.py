"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (MHA kv=16) expert
d_ff=1408, 60 routed experts top-4 + shared expert (4x1408=5632), every
layer MoE [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].  ~14.3B total / ~2.7B active."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    moe_num_experts=60, moe_top_k=4, moe_every=1, moe_offset=0,
    moe_d_ff=1408, moe_shared_d_ff=5632,
    qkv_bias=True,
)
