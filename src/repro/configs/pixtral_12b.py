"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) head_dim=128
d_ff=14336 vocab=131072 (mistral-nemo text backbone); pixtral-ViT vision
tower is a STUB per the assignment (input_specs provides precomputed patch
embeddings) [hf:mistralai/Pixtral-12B-2409; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=131072,
    rope_theta=1_000_000.0,
    num_patches=256,
)
