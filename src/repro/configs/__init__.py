# One module per assigned architecture (exact public-literature configs)
# plus base.py (registry + input specs).  CLI ids use the assignment
# spelling ("--arch yi-9b"); module names are import-safe.
from .base import ARCH_IDS, ALIASES, get_config, input_specs, shape_supported
