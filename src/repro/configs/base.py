"""Config registry + input specs for the assigned (arch x shape) grid.

Each ``src/repro/configs/<id>.py`` exports ``CONFIG`` with the exact
assignment numbers.  ``input_specs`` builds the ShapeDtypeStruct stand-ins
the dry-run lowers against (no allocation); ``shape_supported`` encodes the
assignment's skip rules (long_500k only for sub-quadratic archs; decode
shapes only for archs with a decoder — all ten have one).
"""

from __future__ import annotations

import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import LM_SHAPES, ModelConfig, ShapeSpec

ARCH_IDS = [
    "stablelm_12b",
    "qwen15_4b",
    "yi_9b",
    "qwen2_05b",
    "llama4_maverick",
    "qwen2_moe_a27b",
    "whisper_large_v3",
    "jamba_v01_52b",
    "mamba2_27b",
    "pixtral_12b",
]

# assignment ids (cli) -> module names
ALIASES = {
    "stablelm-12b": "stablelm_12b",
    "qwen1.5-4b": "qwen15_4b",
    "yi-9b": "yi_9b",
    "qwen2-0.5b": "qwen2_05b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "qwen2-moe-a2.7b": "qwen2_moe_a27b",
    "whisper-large-v3": "whisper_large_v3",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "mamba2-2.7b": "mamba2_27b",
    "pixtral-12b": "pixtral_12b",
}


def get_config(arch: str) -> ModelConfig:
    mod = ALIASES.get(arch, arch).replace("-", "_").replace(".", "")
    m = importlib.import_module(f"repro.configs.{mod}")
    return m.CONFIG


def shape_supported(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(supported, reason-if-not). Encodes DESIGN.md §5 skip rules."""
    spec = LM_SHAPES[shape]
    if spec.name == "long_500k":
        subquad = cfg.family in ("ssm", "hybrid")
        if not subquad:
            return False, ("pure full-attention arch: 500k-token KV decode "
                           "needs sub-quadratic attention (assignment skip)")
    return True, ""


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: str,
                per_device_batch: Optional[int] = None) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: {tokens, labels(train), extra_embeds?}
    decode:        {tokens[B,1]} (+ cache built separately, see dryrun)
    """
    spec = LM_SHAPES[shape]
    B, S = spec.global_batch, spec.seq_len
    out: Dict = {}
    if spec.kind in ("train", "prefill"):
        out["tokens"] = _struct((B, S), jnp.int32)
        if spec.kind == "train":
            out["labels"] = _struct((B, S), jnp.int32)
        if cfg.family == "encdec":
            out["extra_embeds"] = _struct((B, cfg.encoder_seq, cfg.d_model),
                                          jnp.bfloat16)
        elif cfg.family == "vlm":
            out["extra_embeds"] = _struct((B, cfg.num_patches, cfg.d_model),
                                          jnp.bfloat16)
    else:  # decode: one new token against a seq_len-deep cache
        out["tokens"] = _struct((B, 1), jnp.int32)
    return out
