"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba:attention 7:1 interleave (1 attn layer per period of 8,
offset 4), MoE 16 experts top-2 every other layer [arXiv:2403.19887; hf].
Jamba-v0.1 uses Mamba-1 internally; we adapt to the SSD (Mamba-2) form —
MXU-friendly — per DESIGN.md hardware-adaptation notes."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    moe_num_experts=16, moe_top_k=2, moe_every=2, moe_offset=1,
    moe_d_ff=14336,
    attn_layer_period=8, attn_layer_offset=4,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
)
