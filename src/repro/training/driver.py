"""Fault-tolerant training driver.

Production-shaped loop: deterministic data (restart-exact), checkpoint
every N steps with atomic publish, automatic resume from LATEST, a
straggler watchdog (step-time EMA; slow steps fire a callback that a fleet
controller would use to evict/replace the slow host), and a failure
injector used by tests to prove restart-exactness.

On a real fleet this process runs per host under `jax.distributed`
(launch/train.py wires that); everything here is host-count agnostic.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import BatchPipeline
from .optimizer import AdamW
from .step import make_train_step


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps slower than ``threshold`` x EMA(step_time).

    The paper-scale deployment story: the controller collects these events
    over all hosts; a host that flags persistently gets drained and its
    data-parallel shard re-assigned (elastic re-mesh,
    distributed/elastic.py).  Here we implement detection + callback.
    """
    threshold: float = 3.0
    alpha: float = 0.1
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    _ema: float = 0.0
    events: int = 0

    def observe(self, step: int, dt: float) -> bool:
        if self._ema == 0.0:
            self._ema = dt
            return False
        slow = dt > self.threshold * self._ema
        if slow:
            self.events += 1
            if self.on_straggler:
                self.on_straggler(step, dt, self._ema)
        # EMA excludes outliers so one hiccup doesn't mask the next
        if not slow:
            self._ema = (1 - self.alpha) * self._ema + self.alpha * dt
        return slow


class FailureInjector:
    """Deterministic crash at a given step (tests restart-exactness)."""

    def __init__(self, at_step: Optional[int] = None):
        self.at_step = at_step
        self.fired = False

    def maybe_fail(self, step: int) -> None:
        if self.at_step is not None and step == self.at_step and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected failure at step {step}")


def train(cfg, params, opt: AdamW, pipeline: BatchPipeline, *,
          steps: int, ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          train_step: Optional[Callable] = None,
          watchdog: Optional[StragglerWatchdog] = None,
          injector: Optional[FailureInjector] = None,
          log_every: int = 10,
          log: Callable[[str], None] = print) -> Dict[str, Any]:
    """Run (or resume) a training job.  Returns final state + history."""
    step_fn = train_step or jax.jit(make_train_step(cfg, opt),
                                    donate_argnums=(0, 1))
    opt_state = opt.init(params)
    start_step = 0
    mgr = CheckpointManager(ckpt_dir, every=ckpt_every) if ckpt_dir else None

    if mgr is not None:
        restored = mgr.restore_or_none({"params": params,
                                        "opt": opt_state})
        if restored is not None:
            tree, ck_step, extra = restored
            params, opt_state = tree["params"], tree["opt"]
            start_step = ck_step
            log(f"[driver] resumed from checkpoint step {ck_step}")

    history = []
    watchdog = watchdog or StragglerWatchdog()
    for step in range(start_step, steps):
        if injector is not None:
            injector.maybe_fail(step)
        x, y = pipeline.batch_at(step)
        batch = {"tokens": jax.numpy.asarray(x),
                 "labels": jax.numpy.asarray(y)}
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])   # blocks; also the step boundary
        dt = time.perf_counter() - t0
        watchdog.observe(step, dt)
        history.append(loss)
        if step % log_every == 0:
            log(f"[driver] step {step} loss {loss:.4f} "
                f"({dt*1e3:.0f} ms/step)")
        if mgr is not None:
            mgr.maybe_save(step + 1, {"params": params, "opt": opt_state},
                           extra={"pipeline_step": step + 1})
    return {"params": params, "opt_state": opt_state, "history": history,
            "straggler_events": watchdog.events, "last_step": steps}
