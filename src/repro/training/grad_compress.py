"""Gradient compression for cross-pod all-reduce (distributed-optimization
substrate for 1000+-node scale).

Two standard schemes, both with exactness hooks tested on CPU:

* **top-k sparsification with error feedback** (Deep Gradient Compression):
  keep the k largest-|g| entries per tensor, accumulate the residual into a
  local error buffer added back next step.  Cross-pod traffic drops by
  ~(1 - k/n); convergence is preserved by the error feedback (momentum-
  correctness tested in tests/test_training.py).
* **int8 quantization** with per-tensor scale (1 byte/entry + 4-byte scale):
  4x traffic reduction, unbiased stochastic rounding optional.

Placement: these transform the *gradient pytree before the cross-pod
reduction*.  In the pjit data path XLA owns the all-reduce, so compression
applies in the shard_map/manual-collective training mode
(``distributed/pipeline.py``) and in the hierarchical pod-boundary reduce —
exactly where the expensive (ICI -> DCN) hop happens.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------- top-k --
def topk_compress(grads, error, k_frac: float = 0.01):
    """Returns (sparse_grads, new_error).  sparse_grads has the same dense
    shape (zeros off-support) — the wire format would send (idx, val) pairs;
    we keep dense for the JAX math and count wire bytes separately."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        n = g.size
        k = max(1, int(n * k_frac))
        flat = g.reshape(-1)
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = jnp.abs(flat) >= thresh
        kept = jnp.where(mask, flat, 0.0)
        return kept.reshape(g.shape), (flat - kept).reshape(g.shape)

    out = jax.tree.map(one, grads, error)
    sparse = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return sparse, new_err


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def topk_wire_bytes(params, k_frac: float) -> int:
    """Bytes on the wire per step for (int32 idx, f32 val) pairs."""
    total = 0
    for p in jax.tree.leaves(params):
        k = max(1, int(p.size * k_frac))
        total += k * 8
    return total


# ------------------------------------------------------------------ int8 --
def int8_quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def int8_roundtrip(grads):
    """Quantize+dequantize a pytree (what the wire sees)."""
    def one(g):
        q, s = int8_quantize(g)
        return int8_dequantize(q, s).astype(g.dtype)
    return jax.tree.map(one, grads)
