"""Loss + train/eval step factories (pure functions for pjit).

``make_train_step`` builds the function the launcher jits with
in/out_shardings.  Microbatch gradient accumulation is a ``lax.scan`` over
the leading batch split — compute per microbatch overlaps XLA's gradient
reduce-scatter of the previous one (latency hiding comes from XLA's async
collectives; the schedule is what we control here).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import apply_lm
from repro.models.config import ModelConfig
from .optimizer import AdamW, AdamWState

Z_LOSS = 1e-4
MOE_AUX = 1e-2


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean CE over valid tokens + z-loss.  logits f32 [B,S,V].

    The gold logit is extracted with a one-hot contraction, NOT
    take_along_axis: with the vocab dim sharded over the model axis
    (DESIGN.md §4), a gather would all-gather the full logits
    (B*S*V*4 bytes of collective traffic); the one-hot product reduces
    shard-locally and psums a [B,S] scalar field instead."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = lse - gold
    z = jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(nll)
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / denom, (z * mask).sum() / denom


def make_loss_fn(cfg: ModelConfig, remat: bool = True,
                 unroll: bool = False) -> Callable:
    def loss_fn(params, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        logits, aux = apply_lm(cfg, params, batch["tokens"],
                               extra_embeds=batch.get("extra_embeds"),
                               remat=remat, unroll=unroll)
        labels = batch["labels"]
        if cfg.family == "vlm" and "extra_embeds" in batch:
            # patches occupy the prefix; loss on text positions only
            logits = logits[:, -labels.shape[1]:, :]
        ce, z = cross_entropy(logits, labels)
        loss = ce + Z_LOSS * z + MOE_AUX * aux
        return loss, {"ce": ce, "z": z, "moe_aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, opt: AdamW, remat: bool = True,
                    microbatches: int = 1, unroll: bool = False) -> Callable:
    loss_fn = make_loss_fn(cfg, remat=remat, unroll=unroll)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: AdamWState, batch: Dict):
        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc(carry, mb_batch):
                gsum, lsum = carry
                (loss, m), g = grad_fn(params, mb_batch)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + loss), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (gsum, lsum), ms = jax.lax.scan(acc, (g0, jnp.float32(0)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        params, opt_state, opt_m = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_m)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    loss_fn = make_loss_fn(cfg, remat=False)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return dict(metrics, loss=loss)

    return eval_step
