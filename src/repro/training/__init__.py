"""Training stack: optimizer, loss/step factories, gradient compression,
fault-tolerant driver."""

from .optimizer import AdamW, AdamWState
from .step import make_train_step, make_eval_step, make_loss_fn, cross_entropy
from .grad_compress import (topk_compress, init_error, topk_wire_bytes,
                            int8_roundtrip, int8_quantize, int8_dequantize)
from .driver import train, StragglerWatchdog, FailureInjector

__all__ = ["AdamW", "AdamWState", "make_train_step", "make_eval_step",
           "make_loss_fn", "cross_entropy", "topk_compress", "init_error",
           "topk_wire_bytes", "int8_roundtrip", "int8_quantize",
           "int8_dequantize", "train", "StragglerWatchdog",
           "FailureInjector"]
