"""AdamW + gradient clipping in pure JAX (no optax in this environment).

Moments are float32 regardless of param dtype (bf16 training standard).
The optimizer state pytree mirrors the param tree, so the same sharding
rules apply (FSDP shards moments exactly like params — DESIGN.md §4).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 0
    schedule: str = "constant"       # constant | cosine
    total_steps: int = 0

    def init(self, params) -> AdamWState:
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            count=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(f32, params),
            nu=jax.tree.map(f32, params),
        )

    def _lr_at(self, step: jnp.ndarray) -> jnp.ndarray:
        lr = jnp.float32(self.lr)
        if self.warmup_steps:
            lr = lr * jnp.minimum(1.0, (step + 1) / self.warmup_steps)
        if self.schedule == "cosine" and self.total_steps:
            frac = jnp.clip((step - self.warmup_steps) /
                            max(self.total_steps - self.warmup_steps, 1),
                            0.0, 1.0)
            lr = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return lr

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState, dict]:
        # global-norm clip (f32 accumulation)
        leaves = jax.tree.leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in leaves))
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9)) \
            if self.clip_norm else jnp.float32(1.0)

        step = state.count
        lr = self._lr_at(step)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** (step.astype(jnp.float32) + 1)
        c2 = 1.0 - b2 ** (step.astype(jnp.float32) + 1)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        return (new_params,
                AdamWState(count=step + 1, mu=new_mu, nu=new_nu),
                {"grad_norm": gnorm, "lr": lr})
