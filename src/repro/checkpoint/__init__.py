"""Checkpointing: sharded npz + manifest, atomic publish, restart/elastic."""

from .ckpt import (save_checkpoint, restore_checkpoint, latest_step,
                   save_corpus, restore_corpus, CheckpointManager)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "save_corpus", "restore_corpus", "CheckpointManager"]
