"""Fault-tolerant checkpointing (no orbax in this environment).

Layout per step::

    <dir>/step_000123/
        manifest.json     # step, pipeline state, tree structure, shard map
        shard_00000.npz   # flat {leaf_id: array} (chunked by size budget)
    <dir>/LATEST          # atomic pointer file (rename-published)

Guarantees engineered for restartability at fleet scale:
  * atomic publish: a checkpoint is visible only after its LATEST pointer
    renames in — a killed writer never corrupts the previous checkpoint;
  * self-describing: the manifest stores the pytree structure, so restore
    works without constructing a template (elastic restarts can reshard);
  * keep-last-k garbage collection;
  * host-agnostic: arrays are saved unsharded here (test scale); the
    production path would write per-host shards of the same layout — the
    manifest's shard map is already plural for that reason.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SHARD_BUDGET = 1 << 30     # 1 GiB per npz shard


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    # jax.tree.flatten_with_path only exists on newer jax; tree_util has it
    # everywhere this repo supports.
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[Dict] = None, keep: int = 3) -> str:
    flat = _flatten_with_paths(tree)
    _, treedef = jax.tree.flatten(tree)
    step_dir = os.path.join(directory, f"step_{step:09d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)

    shards: List[Dict[str, np.ndarray]] = [{}]
    sizes = [0]
    shard_map: Dict[str, int] = {}
    for key, leaf in flat:
        arr = np.asarray(leaf)
        if sizes[-1] + arr.nbytes > _SHARD_BUDGET and shards[-1]:
            shards.append({})
            sizes.append(0)
        sid = len(shards) - 1
        shards[sid][key] = arr
        sizes[sid] += arr.nbytes
        shard_map[key] = sid

    for sid, shard in enumerate(shards):
        # npz keys cannot contain '/': escape
        np.savez(os.path.join(tmp_dir, f"shard_{sid:05d}.npz"),
                 **{k.replace("/", "|"): v for k, v in shard.items()})
    manifest = {
        "step": step,
        "keys": [k for k, _ in flat],
        "shard_map": shard_map,
        "dtypes": {k: str(np.asarray(v).dtype) for k, v in flat},
        "extra": extra or {},
    }
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.replace(tmp_dir, step_dir)
    # atomic LATEST pointer
    ptr_tmp = os.path.join(directory, "LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(f"{step}\n")
    os.replace(ptr_tmp, os.path.join(directory, "LATEST"))
    _gc(directory, keep)
    return step_dir


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        return int(f.read().strip())


def restore_checkpoint(directory: str, template: Any,
                       step: Optional[int] = None
                       ) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``template`` (values replaced).
    Returns (tree, step, extra)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    step_dir = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    cache: Dict[int, Any] = {}

    def shard(sid: int):
        if sid not in cache:
            cache[sid] = np.load(os.path.join(step_dir,
                                              f"shard_{sid:05d}.npz"))
        return cache[sid]

    flat = _flatten_with_paths(template)
    values = []
    for key, leaf in flat:
        sid = manifest["shard_map"][key]
        arr = shard(sid)[key.replace("/", "|")]
        values.append(arr)
    _, treedef = jax.tree.flatten(template)
    tree = jax.tree.unflatten(treedef, values)
    return tree, manifest["step"], manifest.get("extra", {})


def save_corpus(directory: str, step: int, corpus,
                keep: int = 3) -> str:
    """Checkpoint a :class:`~repro.data.store.CompressedCorpus` mid-ingest.

    The grammar arrays and the file table ride the standard sharded-npz
    tree; scalar metadata (vocab/file/rule/level counts) and the ingest
    ``epoch`` ride the manifest's ``extra`` blob, so a snapshot taken
    between two ``append_files`` calls restores at the exact same epoch —
    artifacts derived before the snapshot stay distinguishable from ones
    derived after the restore (the staleness guard keeps working across a
    restart).  Lazy import keeps checkpoint importable below the data
    layer."""
    from repro.data.store import _ARRAY_FIELDS, _META_FIELDS
    tree = {
        "ga": {name: getattr(corpus.ga, name) for name in _ARRAY_FIELDS},
        "files": {"file_starts": corpus.file_starts,
                  "file_lens": corpus.file_lens},
    }
    extra = {
        "kind": "compressed_corpus",
        "epoch": int(corpus.epoch),
        "meta": {name: int(getattr(corpus.ga, name))
                 for name in _META_FIELDS},
    }
    return save_checkpoint(directory, step, tree, extra, keep)


def restore_corpus(directory: str, step: Optional[int] = None):
    """Restore a :func:`save_corpus` snapshot.  Returns
    ``(CompressedCorpus, step)``; the corpus resumes at its saved epoch
    with an empty weight cache (memos are derived state — recomputed, and
    epoch-stamped, on first use) and no live compressor state (rebuilt by
    replay on the first post-restore ``append_files``)."""
    from repro.data.store import (_ARRAY_FIELDS, CompressedCorpus,
                                  GrammarArrays)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    # vet the manifest BEFORE restoring: a non-corpus checkpoint has a
    # different leaf set and would fail with an opaque KeyError otherwise
    with open(os.path.join(directory, f"step_{step:09d}",
                           "manifest.json")) as f:
        kind = json.load(f).get("extra", {}).get("kind")
    if kind != "compressed_corpus":
        raise ValueError(f"checkpoint at {directory} step {step} is not a "
                         f"corpus snapshot (kind={kind!r})")
    template = {
        "ga": {name: np.zeros(0) for name in _ARRAY_FIELDS},
        "files": {"file_starts": np.zeros(0), "file_lens": np.zeros(0)},
    }
    tree, step, extra = restore_checkpoint(directory, template, step)
    ga = GrammarArrays(**tree["ga"], **extra["meta"])
    corpus = CompressedCorpus(ga=ga,
                              file_starts=tree["files"]["file_starts"],
                              file_lens=tree["files"]["file_lens"],
                              epoch=int(extra["epoch"]))
    return corpus, step


def _gc(directory: str, keep: int) -> None:
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)


class CheckpointManager:
    """Every-N-steps save + resume + async-friendly interface."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def maybe_save(self, step: int, tree: Any,
                   extra: Optional[Dict] = None) -> bool:
        if step % self.every:
            return False
        save_checkpoint(self.directory, step, tree, extra, self.keep)
        return True

    def restore_or_none(self, template: Any):
        if latest_step(self.directory) is None:
            return None
        return restore_checkpoint(self.directory, template)
