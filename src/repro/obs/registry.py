"""Thread-safe metrics registry: counters, gauges, fixed-bucket histograms.

One :class:`MetricsRegistry` holds named metric *families*; a family with
label names fans out into per-label-value children, a label-less family IS
its single child (``registry.counter("x", "...").inc()`` just works).  All
mutation goes through one re-entrant lock, so ``inc``/``observe`` from many
threads never lose updates (tests/test_obs.py hammers this).

Two exposition formats, both computed under the lock from live state:

* :meth:`MetricsRegistry.snapshot` — a JSON-safe dict (histograms carry
  count / sum / p50 / p95 / p99 and the cumulative bucket table);
* :meth:`MetricsRegistry.render_prometheus` — Prometheus text format
  (``# HELP`` / ``# TYPE``, ``_bucket{le="..."}`` / ``_sum`` / ``_count``
  for histograms, label values escaped per the spec).

The ``enabled`` flag is deliberately asymmetric: **counters and gauges
always record** — serving *policy* reads them (rejected/shed accounting,
flush-reason counts, queue depth), so disabling them would change
behaviour, not just visibility — while **histograms (and the span tracing
built on top in obs/tracing.py) become no-ops** when ``enabled=False``.
That disabled mode is the baseline the ≤5 % instrumentation-overhead floor
is measured against (benchmarks/bench_load.py ``metrics_overhead``).

The clock is injectable (mirroring ``serving/queue.py``) so latency-
producing callers and the registry agree on a time domain in
simulated-clock tests; the registry itself stores no timestamps.
"""

from __future__ import annotations

import math
import re
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

__all__ = ["MetricsRegistry", "DEFAULT_BUCKETS", "global_registry"]

#: Default latency buckets (seconds): log-spaced from 100 us to 60 s, the
#: range between "one cached dispatch" and "a cold jit compile", + +Inf.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, math.inf)

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _escape_label(v: str) -> str:
    """Prometheus label-value escaping: backslash, double-quote, newline."""
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return format(v, ".10g")


class _Counter:
    """Monotonic counter child.  ``set`` exists for the thin attribute
    views in serving (``stats.x += 1`` reads then writes) — it must never
    move the value backwards."""

    __slots__ = ("_lock", "_value")

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increments must be >= 0, got {n}")
        with self._lock:
            self._value += n

    def set(self, v: float) -> None:
        with self._lock:
            if v < self._value:
                raise ValueError(
                    f"counter cannot move backwards ({self._value} -> {v})")
            self._value = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def _reset(self) -> None:
        self._value = 0.0

    def _sample(self) -> dict:
        return {"value": self._value}


class _Gauge:
    """Free-moving instantaneous value (queue depth, drop counts)."""

    __slots__ = ("_lock", "_value")

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def _reset(self) -> None:
        self._value = 0.0

    def _sample(self) -> dict:
        return {"value": self._value}


class _Histogram:
    """Fixed-bucket histogram with interpolated percentile summaries.

    ``observe`` is gated on the owning registry's ``enabled`` flag (the
    module docstring's asymmetry); percentiles interpolate linearly within
    the bucket containing the target rank, so they are bucket-resolution
    estimates — exactly what a Prometheus ``histogram_quantile`` would
    compute from the same buckets."""

    __slots__ = ("_lock", "_registry", "_uppers", "_counts", "_sum",
                 "_count")

    def __init__(self, lock: threading.RLock, registry: "MetricsRegistry",
                 buckets: Tuple[float, ...]):
        self._lock = lock
        self._registry = registry
        self._uppers = buckets
        self._counts = [0] * len(buckets)
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._count += 1
            self._sum += v
            for i, ub in enumerate(self._uppers):
                if v <= ub:
                    self._counts[i] += 1
                    break

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, q: float) -> float:
        """Interpolated q-th percentile (nan when empty)."""
        with self._lock:
            counts = list(self._counts)
            total = self._count
        if total == 0:
            return float("nan")
        rank = (q / 100.0) * total
        cum = 0
        lo = 0.0
        for ub, c in zip(self._uppers, counts):
            prev = cum
            cum += c
            if cum >= rank and c > 0:
                if math.isinf(ub):
                    return lo          # open-ended last bucket: lower bound
                frac = (rank - prev) / c
                return lo + (ub - lo) * frac
            if not math.isinf(ub):
                lo = ub
        return lo

    def _reset(self) -> None:
        self._counts = [0] * len(self._uppers)
        self._sum = 0.0
        self._count = 0

    def _sample(self) -> dict:
        cum, table = 0, []
        for ub, c in zip(self._uppers, self._counts):
            cum += c
            table.append([ub if not math.isinf(ub) else "+Inf", cum])
        out = {"count": self._count, "sum": self._sum, "buckets": table}
        for q in (50, 95, 99):
            out[f"p{q}"] = self.percentile(q)
        return out


_KINDS = {"counter": _Counter, "gauge": _Gauge, "histogram": _Histogram}


class _Family:
    """One named metric family; children keyed by label-value tuples.

    A label-less family proxies the metric API straight to its single
    ``()`` child, so callers never special-case "no labels"."""

    def __init__(self, registry: "MetricsRegistry", kind: str, name: str,
                 help_: str, labelnames: Tuple[str, ...],
                 buckets: Tuple[float, ...]):
        self.registry = registry
        self.kind = kind
        self.name = name
        self.help = help_
        self.labelnames = labelnames
        self.buckets = buckets
        self._children: Dict[Tuple[str, ...], object] = {}
        if not labelnames:
            self.labels()                      # materialize the bare child

    def _make_child(self):
        if self.kind == "histogram":
            return _Histogram(self.registry._lock, self.registry,
                              self.buckets)
        return _KINDS[self.kind](self.registry._lock)

    def labels(self, *values: str):
        """The child for one label-value combination (created on first
        use; values coerced to str)."""
        if len(values) != len(self.labelnames):
            raise ValueError(f"{self.name} takes {len(self.labelnames)} "
                             f"label values {self.labelnames}, "
                             f"got {values!r}")
        key = tuple(str(v) for v in values)
        with self.registry._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._make_child()
            return child

    def remove(self, *values: str) -> None:
        with self.registry._lock:
            self._children.pop(tuple(str(v) for v in values), None)

    def children(self) -> List[Tuple[Tuple[str, ...], object]]:
        with self.registry._lock:
            return sorted(self._children.items())

    # ---- label-less proxy: the family IS its single child ----
    def _bare(self):
        if self.labelnames:
            raise ValueError(f"{self.name} has labels {self.labelnames}; "
                             f"use .labels(...)")
        return self._children[()]

    def inc(self, n: float = 1.0) -> None:
        self._bare().inc(n)

    def dec(self, n: float = 1.0) -> None:
        self._bare().dec(n)

    def set(self, v: float) -> None:
        self._bare().set(v)

    def observe(self, v: float) -> None:
        self._bare().observe(v)

    @property
    def value(self) -> float:
        return self._bare().value

    @property
    def count(self) -> int:
        return self._bare().count

    @property
    def sum(self) -> float:
        return self._bare().sum

    def percentile(self, q: float) -> float:
        return self._bare().percentile(q)


class MetricsRegistry:
    """Named metric families behind one lock; see the module docstring."""

    def __init__(self, enabled: bool = True,
                 clock: Callable[[], float] = time.monotonic):
        self.enabled = enabled
        self.clock = clock
        self._lock = threading.RLock()
        self._families: Dict[str, _Family] = {}

    # ------------------------------------------------------- registration --
    def _register(self, kind: str, name: str, help_: str,
                  labelnames: Iterable[str],
                  buckets: Optional[Iterable[float]] = None) -> _Family:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        labelnames = tuple(labelnames)
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r} on {name}")
        bks = DEFAULT_BUCKETS if buckets is None else tuple(buckets)
        if kind == "histogram":
            if list(bks) != sorted(bks) or len(set(bks)) != len(bks):
                raise ValueError(f"histogram buckets must be strictly "
                                 f"increasing, got {bks}")
            if not math.isinf(bks[-1]):
                bks = bks + (math.inf,)
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                # idempotent re-registration: the same family handed back,
                # a *conflicting* one refused loudly
                if fam.kind != kind or fam.labelnames != labelnames:
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.kind}{fam.labelnames}, cannot re-register "
                        f"as {kind}{labelnames}")
                return fam
            fam = _Family(self, kind, name, help_, labelnames, bks)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help_: str = "",
                labelnames: Iterable[str] = ()) -> _Family:
        return self._register("counter", name, help_, labelnames)

    def gauge(self, name: str, help_: str = "",
              labelnames: Iterable[str] = ()) -> _Family:
        return self._register("gauge", name, help_, labelnames)

    def histogram(self, name: str, help_: str = "",
                  labelnames: Iterable[str] = (),
                  buckets: Optional[Iterable[float]] = None) -> _Family:
        return self._register("histogram", name, help_, labelnames, buckets)

    def reset(self) -> None:
        """Zero every child in place (views/handles stay valid)."""
        with self._lock:
            for fam in self._families.values():
                for _, child in fam._children.items():
                    child._reset()

    # --------------------------------------------------------- exposition --
    def snapshot(self) -> dict:
        """JSON-safe dump of every family's current state."""
        out: Dict[str, dict] = {}
        with self._lock:
            for name, fam in sorted(self._families.items()):
                out[name] = {
                    "type": fam.kind,
                    "help": fam.help,
                    "labelnames": list(fam.labelnames),
                    "samples": [
                        {"labels": dict(zip(fam.labelnames, key)),
                         **child._sample()}
                        for key, child in fam.children()],
                }
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: List[str] = []
        with self._lock:
            for name, fam in sorted(self._families.items()):
                lines.append(f"# HELP {name} {fam.help}")
                lines.append(f"# TYPE {name} {fam.kind}")
                for key, child in fam.children():
                    base = ",".join(
                        f'{ln}="{_escape_label(v)}"'
                        for ln, v in zip(fam.labelnames, key))
                    if fam.kind != "histogram":
                        suffix = f"{{{base}}}" if base else ""
                        lines.append(
                            f"{name}{suffix} {_fmt(child.value)}")
                        continue
                    cum = 0
                    for ub, c in zip(child._uppers, child._counts):
                        cum += c
                        le = f'le="{_fmt(ub)}"'
                        lbl = f"{base},{le}" if base else le
                        lines.append(f"{name}_bucket{{{lbl}}} {cum}")
                    suffix = f"{{{base}}}" if base else ""
                    lines.append(f"{name}_sum{suffix} {_fmt(child._sum)}")
                    lines.append(f"{name}_count{suffix} {child._count}")
        return "\n".join(lines) + "\n"


#: Process-global registry for library-level metrics that have no server to
#: hang off: kernel dispatch decisions (kernels/ops.py), store memo traffic
#: (data/store.py), ingest throughput (core/sequitur.py), plan builds
#: (obs/tracing.py plan_stage).  Per-server metrics live on the server's
#: own registry so test processes stay isolated.
_GLOBAL = MetricsRegistry()


def global_registry() -> MetricsRegistry:
    return _GLOBAL
