"""Span-based tracing of a query's lifecycle, plus the bounded event logs.

A :class:`Span` is one named interval with attributes and children; the
serving layer builds one tree per query — submit → (queue_wait) → flush →
chunk → pack_build → plan:* → compile-or-execute — carried on
``Query.trace`` / ``FlushEvent.span`` and appended to the owning server's
bounded ``trace_log``.  Trees may *share* subtrees: a flush that answers
five queries is one flush span appearing under five query roots, which is
exactly the batching the engine performed.

Propagation is ambient: :func:`span` (and :func:`activate`) push the
current span **and its clock** onto a :class:`contextvars.ContextVar`, so
instrumented library code (``plan_stage`` in core/batch.py,
search/engine.py) attaches children to whatever query is executing without
any parameter threading — and reads time from the same injectable clock
domain as the server that opened the root (simulated-clock tests stay
deterministic).  Context vars are per-thread, so concurrent flushes build
disjoint trees.

When no span is active, ``plan_stage`` still feeds the global
``repro_plan_build_seconds`` histogram and costs one contextvar read
otherwise — instrumentation must be safe to leave on everywhere.
"""

from __future__ import annotations

import math
import time
from collections import deque
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from .registry import global_registry

__all__ = ["Span", "span", "activate", "current", "current_clock",
           "plan_stage", "BoundedLog", "span_problems"]


@dataclass
class Span:
    """One named interval in a query's lifecycle tree."""
    name: str
    t0: float
    t1: float = math.nan               # nan until finish()
    attrs: Dict[str, object] = field(default_factory=dict)
    children: List["Span"] = field(default_factory=list)

    def finish(self, t: float) -> "Span":
        if not self.finished:
            self.t1 = t
        return self

    @property
    def finished(self) -> bool:
        return not math.isnan(self.t1)

    @property
    def duration(self) -> float:
        return (self.t1 - self.t0) if self.finished else math.nan

    def walk(self) -> Iterator["Span"]:
        """Pre-order traversal of the tree rooted here."""
        stack = [self]
        while stack:
            s = stack.pop()
            yield s
            stack.extend(reversed(s.children))

    def find(self, name: str) -> List["Span"]:
        return [s for s in self.walk() if s.name == name]

    def to_dict(self) -> dict:
        """JSON-safe rendering (shared subtrees are duplicated)."""
        return {"name": self.name, "t0": self.t0,
                "t1": None if not self.finished else self.t1,
                "attrs": dict(self.attrs),
                "children": [c.to_dict() for c in self.children]}


# (active span, its clock) — per-thread/task via contextvars
_ACTIVE: ContextVar[Optional[Tuple[Span, Callable[[], float]]]] = \
    ContextVar("repro_obs_active_span", default=None)


def current() -> Optional[Span]:
    """The ambient span, or None outside any instrumented scope."""
    top = _ACTIVE.get()
    return None if top is None else top[0]


def current_clock() -> Callable[[], float]:
    """The clock of the ambient span (``time.monotonic`` outside one)."""
    top = _ACTIVE.get()
    return time.monotonic if top is None else top[1]


@contextmanager
def activate(s: Span, clock: Callable[[], float]):
    """Make an *externally managed* span ambient: children attach to it,
    but entering/exiting does not start/finish it (the serving layer opens
    query roots at submit time and finishes them when futures resolve)."""
    token = _ACTIVE.set((s, clock))
    try:
        yield s
    finally:
        _ACTIVE.reset(token)


@contextmanager
def span(name: str, clock: Optional[Callable[[], float]] = None,
         attrs: Optional[dict] = None):
    """Open a child of the ambient span (or a root), finish it on exit.
    Without an explicit ``clock`` the parent's clock domain is inherited."""
    parent = _ACTIVE.get()
    clk = clock if clock is not None else (
        parent[1] if parent is not None else time.monotonic)
    s = Span(name, clk(), attrs=dict(attrs) if attrs else {})
    if parent is not None:
        parent[0].children.append(s)
    token = _ACTIVE.set((s, clk))
    try:
        yield s
    finally:
        _ACTIVE.reset(token)
        s.finish(clk())


@contextmanager
def plan_stage(plan: str):
    """Instrument one host-side plan construction (the lazy pack memos:
    ``ell`` / ``sequence`` / ``search_stats``).  Attaches a ``plan:<name>``
    child to the ambient span when one is active, and always feeds the
    global ``repro_plan_build_seconds{plan=...}`` histogram — plan builds
    happen inside cached properties, so which *query* paid the build cost
    is visible only through this hook."""
    parent = _ACTIVE.get()
    clk = parent[1] if parent is not None else time.monotonic
    t0 = clk()
    s: Optional[Span] = None
    if parent is not None:
        s = Span(f"plan:{plan}", t0)
        parent[0].children.append(s)
    try:
        yield s
    finally:
        t1 = clk()
        if s is not None:
            s.finish(t1)
        global_registry().histogram(
            "repro_plan_build_seconds",
            "host-side plan construction per lazy pack memo",
            ("plan",)).labels(plan).observe(t1 - t0)


class BoundedLog:
    """``deque(maxlen=n)`` with drop accounting: appending past capacity
    evicts the oldest entry and counts it (optionally into a gauge), so
    truncation under overload is visible instead of silent — the fix for
    the queue's raw ``flush_log`` ring."""

    def __init__(self, maxlen: int, gauge=None):
        if maxlen < 1:
            raise ValueError("maxlen must be >= 1")
        self._d: deque = deque(maxlen=maxlen)
        self._gauge = gauge
        self.dropped = 0

    @property
    def maxlen(self) -> int:
        return self._d.maxlen

    def append(self, item) -> None:
        if len(self._d) == self._d.maxlen:
            self.dropped += 1
            if self._gauge is not None:
                self._gauge.set(float(self.dropped))
        self._d.append(item)

    def clear(self) -> None:
        self._d.clear()

    def __len__(self) -> int:
        return len(self._d)

    def __getitem__(self, i):
        return self._d[i]

    def __iter__(self):
        return iter(self._d)

    def __repr__(self) -> str:
        return (f"BoundedLog(len={len(self._d)}, "
                f"maxlen={self._d.maxlen}, dropped={self.dropped})")


def span_problems(root: Span, require: Tuple[str, ...] = (),
                  eps: float = 1e-6) -> List[str]:
    """Structural validation of one span tree — the test harness for the
    'no stage gaps' acceptance bar.  Checks every span is finished and
    non-negative, children stay inside their parent's interval and start
    in order, and each ``require`` name appears somewhere in the tree.
    Returns human-readable problems ([] == clean)."""
    problems: List[str] = []
    names: List[str] = []

    def walk(s: Span, lo: Optional[float], hi: Optional[float]) -> None:
        names.append(s.name)
        if not s.finished:
            problems.append(f"span {s.name!r} never finished")
        else:
            if s.t1 < s.t0 - eps:
                problems.append(f"span {s.name!r} ends before it starts "
                                f"({s.t0} -> {s.t1})")
            if lo is not None and (s.t0 < lo - eps or s.t1 > hi + eps):
                problems.append(
                    f"span {s.name!r} [{s.t0}, {s.t1}] escapes its "
                    f"parent [{lo}, {hi}]")
        prev = None
        for c in s.children:
            if prev is not None and c.t0 < prev - eps:
                problems.append(f"children of {s.name!r} start out of "
                                f"order at {c.name!r}")
            prev = c.t0
            if s.finished:
                walk(c, s.t0, s.t1)
            else:
                walk(c, None, None)

    walk(root, None, None)
    for r in require:
        if r not in names:
            problems.append(f"missing required span {r!r} "
                            f"(tree has {sorted(set(names))})")
    return problems
