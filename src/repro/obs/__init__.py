"""Unified observability layer: metrics registry + lifecycle tracing.

``registry`` — thread-safe counters/gauges/fixed-bucket histograms with
JSON (:meth:`MetricsRegistry.snapshot`) and Prometheus-text
(:meth:`MetricsRegistry.render_prometheus`) exposition; ``tracing`` —
span trees following a query from submit to result, with ambient
(contextvar) propagation so library code attaches children without
parameter threading.  ``global_registry()`` holds library-level metrics
(kernel dispatch, store memos, ingest, plan builds); each
:class:`~repro.serving.AnalyticsServer` owns a private registry for its
serving metrics.  See docs/observability.md for the metric catalog and
span model.
"""

from .registry import DEFAULT_BUCKETS, MetricsRegistry, global_registry
from .tracing import (BoundedLog, Span, activate, current, current_clock,
                      plan_stage, span, span_problems)

__all__ = ["MetricsRegistry", "DEFAULT_BUCKETS", "global_registry",
           "Span", "span", "activate", "current", "current_clock",
           "plan_stage", "BoundedLog", "span_problems"]
