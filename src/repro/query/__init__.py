"""Composable compressed-query operators: filter / aggregate / phrase.

The query tier generalizes the fixed analytics menu into a small
composable operator set executed directly on the grammars (the
"SQL-style query surface" ROADMAP item): predicate filters with AND/OR
composition over per-file term counts, grouped sum/max aggregations over
term sets, and exact phrase counts via the paper's §IV-D sequence
support — each compiled to one jitted program per pack, with statistics
drawn from the same memoized per-file traversal the search subsystem
uses, and served through the same grouping/flush machinery (query kinds
``filter_count`` / ``agg_terms`` / ``phrase_count``).  Every path is
bit-equal to the decompress-then-scan numpy oracle.
"""

from .ops import (AGG_OPS, and_, normalize_agg, normalize_phrase,
                  normalize_predicate, or_, predicate_leaves,
                  predicate_mask, predicate_structure, term_pred)
from .engine import (QUERY_KINDS, agg_corpus, batched_agg, batched_filter,
                     batched_phrase, filter_corpus, phrase_corpus,
                     query_corpus, run_batched_query)
from .frontend import (lookup_term, phrase_from_text, predicate_from_text,
                       terms_from_text)

__all__ = [
    "QUERY_KINDS", "AGG_OPS",
    "term_pred", "and_", "or_", "normalize_predicate", "normalize_agg",
    "normalize_phrase", "predicate_leaves", "predicate_structure",
    "predicate_mask",
    "batched_filter", "batched_agg", "batched_phrase",
    "filter_corpus", "agg_corpus", "phrase_corpus",
    "run_batched_query", "query_corpus",
    "lookup_term", "terms_from_text", "phrase_from_text",
    "predicate_from_text",
]
