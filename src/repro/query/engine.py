"""Jitted execution of the query operators over :class:`GrammarBatch`.

One call evaluates an operator for every corpus in a pack, in ONE
program, entirely in the compressed domain:

* **filter / aggregate** draw per-file term counts from
  :func:`repro.search.engine.batch_search_stats` — the memoized batched
  per-file traversal the search subsystem already pays for, keyed on the
  pack's plan cache.  Recurring query traffic against a cached pack (the
  serving layer's case) never re-traverses.
* **filter** gathers every predicate leaf's tf column in one
  ``take_along_axis``, compares against the per-leaf thresholds, and
  folds the AND/OR tree (a hashable jit static — one compiled program
  per (pack signature, predicate structure)) with jnp logical ops.
* **aggregate** accumulates the gathered columns with a ``fori_loop``
  over term slots (sum) or a running ``maximum`` (max) — the loop over a
  materialized contribution tensor keeps each add an exactly-specified
  IEEE op, the same discipline as the search scorer.
* **phrase** reuses the pack's memoized sequence plans
  (``core.batch._padded_sequence_plans`` → ``core/sequence.py``
  ``plan_head_tail``/``plan_stream``): window tokens are gathered exactly
  like ``batched_sequence_count``'s counting program, matched against
  the phrase, and the matching windows' rule weights are summed.  The
  paper's §IV-D sequence support — no decompression anywhere.

Sharded packs (``gb.mesh``) run the same programs through ``shard_map``
(:func:`repro.core.batch._sharded_program`): each device evaluates its
own corpus rows, nothing crosses shards, and the host slice drops shard
padding via ``real_gas`` — bit-identical to the unsharded program.

Everything is integer-valued float32 (< 2**24), so every reduce is exact
in any order and each path is bit-equal to the decompress-then-scan
numpy oracle (``tests/_oracle.py``), the repo's standing discipline.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analytics as _analytics
from repro.core.batch import (GrammarBatch, _padded_sequence_plans,
                              _sharded_program, batched_top_down_weights)
from repro.core.grammar import pow2_bucket
from repro.core.sequence import _K_HEAD, _K_LIT, _K_TAIL
from repro.search.engine import batch_search_stats
from repro.search.index import base_method

from .ops import (normalize_agg, normalize_phrase, normalize_predicate,
                  predicate_leaves, predicate_mask, predicate_structure)

__all__ = ["QUERY_KINDS", "batched_filter", "batched_agg", "batched_phrase",
           "filter_corpus", "agg_corpus", "phrase_corpus",
           "run_batched_query", "query_corpus"]

# Serving kinds of the query tier (see serving/analytics_server.py):
#   filter_count — predicate filter, per-corpus matching file ids
#   agg_terms    — per-file + cross-corpus sum/max over a term set
#   phrase_count — exact phrase occurrences via sequence plans
QUERY_KINDS = ("filter_count", "agg_terms", "phrase_count")


# ----------------------------------------------------------------------- #
# The jitted programs (shard_map-compatible: batch-only leading axes)       #
# ----------------------------------------------------------------------- #
def _filter_impl(tv, fvalid, terms, tvalid, thresh, structure=None):
    """bool [n, F] file mask.  ``terms [n, P]`` are pre-clipped leaf term
    ids, ``tvalid`` zeroes counts of out-of-range leaves, ``thresh`` the
    per-leaf minimum counts; ``structure`` is the AND/OR tree over leaf
    slots (static)."""
    cnt = jnp.take_along_axis(tv, terms[:, None, :], axis=2) \
        * tvalid[:, None, :]                                # [n, F, P]
    leaf = cnt >= thresh[:, None, :]

    def fold(node):
        if node[0] == "leaf":
            return leaf[:, :, node[1]]
        kids = [fold(c) for c in node[1]]
        out = kids[0]
        for k in kids[1:]:
            out = (out & k) if node[0] == "and" else (out | k)
        return out

    return fold(structure) & fvalid


_filter = jax.jit(_filter_impl, static_argnames=("structure",))


def _agg_impl(tv, fvalid, terms, tvalid, op=None):
    """(per_file [n, F], total [n]) float32 aggregates of the term set.

    Padded term slots contribute exactly +0.0 (sum) or never win (max —
    all counts are >= 0); padded files are zeroed before the cross-corpus
    reduce, which is exact for integer-valued float32 in any order.
    """
    cnt = jnp.take_along_axis(tv, terms[:, None, :], axis=2) \
        * tvalid[:, None, :]                                # [n, F, P]
    contrib = jnp.moveaxis(cnt, 2, 0)                       # [P, n, F]
    zeros = jnp.zeros(tv.shape[:2], jnp.float32)
    if op == "sum":
        pf = jax.lax.fori_loop(0, contrib.shape[0],
                               lambda j, s: s + contrib[j], zeros)
        pf = jnp.where(fvalid, pf, 0.0)
        total = jnp.sum(pf, axis=1)
    else:  # "max"
        pf = jax.lax.fori_loop(0, contrib.shape[0],
                               lambda j, s: jnp.maximum(s, contrib[j]),
                               zeros)
        pf = jnp.where(fvalid, pf, 0.0)
        total = jnp.max(pf, axis=1)
    return pf, total


_agg = jax.jit(_agg_impl, static_argnames=("op",))


def _phrase_impl(head, tail, weights, st_kind, st_lit, st_src, st_idx,
                 st_symj, win_start, win_rule, win_valid, phrase, l=None):
    """float32 [n] exact phrase counts from the pack's sequence plans.

    Window token gather + validity are op-for-op the counting program of
    ``core.batch._count_windows_batched``; instead of the distinct-gram
    segment reduce, matching windows' rule weights are summed directly.
    """
    def one(head, tail, w, kind, lit, src, idx, symj, ws, wr, wv, ph):
        tok = jnp.where(kind == _K_LIT, lit,
                        jnp.where(kind == _K_HEAD, head[src, idx],
                                  jnp.where(kind == _K_TAIL,
                                            tail[src, idx], lit)))
        pos = ws[:, None] + jnp.arange(l)[None, :]
        wtok = tok[pos]                                   # [Nw, l]
        wsym = symj[pos]
        valid = (wtok >= 0).all(axis=1) & (wsym[:, 0] != wsym[:, -1]) & wv
        match = valid & (wtok == ph[None, :]).all(axis=1)
        return jnp.sum(jnp.where(match, w[wr], jnp.float32(0.0)))

    return jax.vmap(one)(head, tail, weights, st_kind, st_lit, st_src,
                         st_idx, st_symj, win_start, win_rule, win_valid,
                         phrase)


_phrase = jax.jit(_phrase_impl, static_argnames=("l",))


# ----------------------------------------------------------------------- #
# Host prep                                                                 #
# ----------------------------------------------------------------------- #
def _leaf_arrays(leaves: Sequence[Tuple[int, int]], vocab: int
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """pow2-padded clipped leaf term ids [P], validity mask [P] float32,
    thresholds [P] float32.  Out-of-vocab leaves gather a padded column
    masked to 0 — their comparison sees the true count of 0."""
    P = pow2_bucket(max(len(leaves), 1))
    t = np.full(P, -1, np.int64)
    th = np.zeros(P, np.float32)
    for j, (term, min_count) in enumerate(leaves):
        t[j] = term
        th[j] = min_count
    ok = (t >= 0) & (t < vocab)
    t_clip = np.clip(t, 0, max(vocab - 1, 0)).astype(np.int32)
    return t_clip, ok.astype(np.float32), th


def _tile(gb: GrammarBatch, row: np.ndarray) -> jnp.ndarray:
    """Broadcast one host row to every pack row, with pack placement."""
    return gb._place(np.tile(row[None, :], (gb.n, 1)))


# ----------------------------------------------------------------------- #
# Batched entry points                                                      #
# ----------------------------------------------------------------------- #
def batched_filter(gb: GrammarBatch, predicate,
                   method: str = "frontier") -> List[np.ndarray]:
    """Per real corpus: ascending int32 file ids satisfying the predicate."""
    pred = normalize_predicate(predicate)
    st = batch_search_stats(gb, method)
    t_clip, ok, th = _leaf_arrays(predicate_leaves(pred), gb.V_pad)
    structure = predicate_structure(pred)
    args = (st.tv, st.fvalid, _tile(gb, t_clip), _tile(gb, ok),
            _tile(gb, th))
    if gb.mesh is not None:
        mask = _sharded_program(_filter_impl, gb.mesh, (3, 2, 2, 2, 2), 2,
                                static=(("structure", structure),))(*args)
    else:
        mask = _filter(*args, structure)
    mask_h = np.asarray(mask)
    return [np.flatnonzero(mask_h[i, : ga.num_files]).astype(np.int32)
            for i, ga in enumerate(gb.real_gas)]


def batched_agg(gb: GrammarBatch, terms: Sequence[int], op: str = "sum",
                method: str = "frontier"
                ) -> List[Tuple[np.ndarray, np.float32]]:
    """Per real corpus: (per_file [num_files] float32, total float32)."""
    op = normalize_agg(op)
    leaves = [(int(t), 0) for t in terms]
    if not leaves:
        raise ValueError("agg queries need a non-empty terms sequence")
    if any(t < 0 for t, _ in leaves):
        raise ValueError(f"negative term ids are invalid: {tuple(terms)}")
    st = batch_search_stats(gb, method)
    t_clip, ok, _ = _leaf_arrays(leaves, gb.V_pad)
    args = (st.tv, st.fvalid, _tile(gb, t_clip), _tile(gb, ok))
    if gb.mesh is not None:
        pf, total = _sharded_program(_agg_impl, gb.mesh, (3, 2, 2, 2),
                                     (2, 1), static=(("op", op),))(*args)
    else:
        pf, total = _agg(*args, op)
    pf_h = np.asarray(pf)
    total_h = np.asarray(total)
    return [(pf_h[i, : ga.num_files], np.float32(total_h[i]))
            for i, ga in enumerate(gb.real_gas)]


def batched_phrase(gb: GrammarBatch, phrase: Sequence[int],
                   method: str = "frontier") -> List[np.float32]:
    """Per real corpus: exact float32 occurrence count of the phrase."""
    phrase = normalize_phrase(phrase)
    l = len(phrase)
    weights = batched_top_down_weights(gb, method=method)
    head, tail, stream = _padded_sequence_plans(gb, l)
    ph = gb._place(np.tile(np.asarray(phrase, np.int32)[None, :],
                           (gb.n, 1)))
    if gb.mesh is not None:
        counts = _sharded_program(
            _phrase_impl, gb.mesh,
            (3, 3, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2), 1,
            static=(("l", l),))(head, tail, weights, *stream, ph)
    else:
        counts = _phrase(head, tail, weights, *stream, ph, l)
    counts_h = np.asarray(counts)
    return [np.float32(counts_h[i]) for i in range(gb.real)]


# ----------------------------------------------------------------------- #
# Single-corpus entry points                                                #
# ----------------------------------------------------------------------- #
def _corpus_tv(source, method: str) -> Tuple[np.ndarray, object]:
    """Dense [F, V] float32 term vector of one corpus; ``source`` is a
    ``GrammarArrays`` or a ``CompressedCorpus`` — the latter's memoized
    per-file traversal weights are reused (same memo the search index
    shares)."""
    m = base_method(method)
    if hasattr(source, "per_file_weights"):
        ga = source.ga
        fw = source.per_file_weights(m)
        tv = _analytics.term_vector(ga, method=m, file_weights=fw)
    else:
        ga = source
        tv = _analytics.term_vector(ga, method=m)
    return np.asarray(tv, np.float32), ga


def filter_corpus(source, predicate,
                  method: str = "frontier") -> np.ndarray:
    """Ascending int32 file ids of one corpus satisfying the predicate —
    bit-identical to the corpus's row in a batched pack."""
    pred = normalize_predicate(predicate)
    tv, _ = _corpus_tv(source, method)
    return np.flatnonzero(predicate_mask(pred, tv)).astype(np.int32)


def agg_corpus(source, terms: Sequence[int], op: str = "sum",
               method: str = "frontier") -> Tuple[np.ndarray, np.float32]:
    """(per_file [num_files] float32, total float32) for one corpus."""
    op = normalize_agg(op)
    terms = tuple(int(t) for t in terms)
    if not terms:
        raise ValueError("agg queries need a non-empty terms sequence")
    if any(t < 0 for t in terms):
        raise ValueError(f"negative term ids are invalid: {terms}")
    tv, ga = _corpus_tv(source, method)
    F, V = tv.shape
    pf = np.zeros(F, np.float32)
    # mirror the device fori_loop: sequential accumulation over term
    # slots in query order (exact for integer-valued float32 regardless)
    for t in terms:
        cnt = tv[:, t] if t < V else np.zeros(F, np.float32)
        pf = pf + cnt if op == "sum" else np.maximum(pf, cnt)
    if op == "sum":
        total = np.float32(pf.sum(dtype=np.float32))
    else:
        total = np.float32(pf.max()) if F else np.float32(0.0)
    return pf, total


def phrase_corpus(source, phrase: Sequence[int],
                  method: str = "frontier") -> np.float32:
    """Exact float32 phrase count of one corpus, via the single-corpus
    sequence plans (``core/sequence.py``) — reusing the store-memoized
    top-down traversal weights when ``source`` is a CompressedCorpus."""
    phrase = normalize_phrase(phrase)
    l = len(phrase)
    if hasattr(source, "top_down_weights"):
        ga = source.ga
        w = source.top_down_weights(method)
    else:
        ga = source
        w = None
    grams, cnts = _analytics.sequence_count(ga, l=l, method=method,
                                            weights=w)
    grams = np.asarray(grams)
    cnts = np.asarray(cnts, np.float32)
    if grams.size:
        hit = np.nonzero((grams == np.asarray(phrase, grams.dtype))
                         .all(axis=1))[0]
        if hit.size:
            return np.float32(cnts[hit[0]])
    return np.float32(0.0)


# ----------------------------------------------------------------------- #
# Kind dispatchers (serving + distributed layers)                           #
# ----------------------------------------------------------------------- #
def run_batched_query(gb: GrammarBatch, kind: str, predicate=None,
                      terms=None, agg=None,
                      method: str = "frontier") -> List:
    """Dispatch one query kind over the whole pack; per-corpus results
    shaped exactly like the single-corpus functions."""
    if kind == "filter_count":
        return batched_filter(gb, predicate, method=method)
    if kind == "agg_terms":
        return batched_agg(gb, terms, op=normalize_agg(agg), method=method)
    if kind == "phrase_count":
        return batched_phrase(gb, terms, method=method)
    raise ValueError(f"unknown query kind {kind!r}; "
                     f"expected one of {QUERY_KINDS}")


def query_corpus(source, kind: str, predicate=None, terms=None, agg=None,
                 method: str = "frontier"):
    """Single-corpus dispatch, mirroring :func:`run_batched_query`."""
    if kind == "filter_count":
        return filter_corpus(source, predicate, method=method)
    if kind == "agg_terms":
        return agg_corpus(source, terms, op=normalize_agg(agg),
                          method=method)
    if kind == "phrase_count":
        return phrase_corpus(source, terms, method=method)
    raise ValueError(f"unknown query kind {kind!r}; "
                     f"expected one of {QUERY_KINDS}")
