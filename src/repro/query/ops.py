"""Composable query operators over compressed corpora — the operator IR.

The query tier speaks three operator families (G-TADOC's sequence-support
argument promoted to a small SQL-ish surface, after the Microsoft
"GPU Acceleration of SQL Analytics on Compressed Data" direction):

* **filter** — ``files WHERE count(term) >= t``, with arbitrary AND/OR
  composition over term predicates;
* **aggregate** — per-file and cross-corpus ``sum``/``max`` of term
  counts over a term set;
* **phrase** — exact l-gram counts via the paper's sequence-support
  plans (``core/sequence.py``), never via decompression.

Predicates are canonicalized to nested tuples so they are hashable
(frozen ``Query`` dataclass fields, serving group keys, jit static
arguments all want value identity):

* ``("term", term_id, min_count)`` — leaf, true for files whose count of
  ``term_id`` is ``>= min_count``;
* ``("and", (child, ...))`` / ``("or", (child, ...))`` — composition,
  arbitrarily nested, at least one child each.

``predicate_leaves`` / ``predicate_structure`` split a canonical
predicate into its term/threshold table (device data) and its pure
combination tree with leaf slot indices (a hashable jit static) — the
engine gathers every leaf's counts in one vocab gather and folds the
tree with jnp logical ops.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

__all__ = [
    "AGG_OPS", "term_pred", "and_", "or_", "normalize_predicate",
    "predicate_leaves", "predicate_structure", "predicate_mask",
    "normalize_agg", "normalize_phrase",
]

AGG_OPS = ("sum", "max")


# ----------------------------------------------------------------------- #
# Constructors (sugar over the canonical tuple encoding)                    #
# ----------------------------------------------------------------------- #
def term_pred(term: int, min_count: int = 1) -> Tuple:
    """``count(term) >= min_count`` over each file."""
    return normalize_predicate(("term", term, min_count))


def and_(*preds) -> Tuple:
    return normalize_predicate(("and", tuple(preds)))


def or_(*preds) -> Tuple:
    return normalize_predicate(("or", tuple(preds)))


# ----------------------------------------------------------------------- #
# Canonicalization / validation                                             #
# ----------------------------------------------------------------------- #
def normalize_predicate(pred) -> Tuple:
    """Canonical hashable nested-tuple form of a filter predicate.

    Accepts lists/tuples interchangeably and coerces numerics to ints;
    rejects malformed nodes, negative term ids, negative thresholds and
    empty AND/OR — a predicate that validates here is exactly one the
    engine (and the numpy oracle) can evaluate.
    """
    if not isinstance(pred, (tuple, list)) or not pred:
        raise ValueError(f"predicate nodes are tuples, got {pred!r}")
    tag = pred[0]
    if tag == "term":
        if len(pred) != 3:
            raise ValueError(f"term predicate wants (term, min_count), "
                             f"got {pred!r}")
        term, min_count = int(pred[1]), int(pred[2])
        if term < 0:
            raise ValueError(f"negative term id in predicate: {term}")
        if min_count < 0:
            raise ValueError(f"negative min_count in predicate: {min_count}")
        return ("term", term, min_count)
    if tag in ("and", "or"):
        if len(pred) != 2 or not isinstance(pred[1], (tuple, list)):
            raise ValueError(f"{tag!r} predicate wants a child sequence, "
                             f"got {pred!r}")
        kids = tuple(normalize_predicate(c) for c in pred[1])
        if not kids:
            raise ValueError(f"{tag!r} predicate needs at least one child")
        return (tag, kids)
    raise ValueError(f"unknown predicate node {tag!r}; "
                     f"expected 'term' / 'and' / 'or'")


def predicate_leaves(pred) -> List[Tuple[int, int]]:
    """``(term, min_count)`` leaves in left-to-right order — the slot
    order ``predicate_structure`` indexes into."""
    out: List[Tuple[int, int]] = []

    def walk(node):
        if node[0] == "term":
            out.append((node[1], node[2]))
        else:
            for c in node[1]:
                walk(c)

    walk(normalize_predicate(pred))
    return out


def predicate_structure(pred) -> Tuple:
    """The combination tree with leaves replaced by slot indices:
    ``("leaf", i)`` / ``("and", (...))`` / ``("or", (...))``.  Hashable —
    it is the jit static argument; two predicates with the same structure
    share one compiled filter program per pack."""
    counter = [0]

    def walk(node):
        if node[0] == "term":
            i = counter[0]
            counter[0] += 1
            return ("leaf", i)
        return (node[0], tuple(walk(c) for c in node[1]))

    return walk(normalize_predicate(pred))


def predicate_mask(pred, tv: np.ndarray) -> np.ndarray:
    """Evaluate a canonical predicate against a dense ``[F, V]`` term
    vector on host — bool ``[F]``.  Out-of-vocab terms count 0 (matching
    the batched program's padded-column gather); every comparison is on
    exact integer-valued float32, so this is bit-identical to the device
    path."""
    pred = normalize_predicate(pred)
    F, V = tv.shape

    def ev(node):
        if node[0] == "term":
            _, t, c = node
            cnt = tv[:, t] if t < V else np.zeros(F, np.float32)
            return cnt >= np.float32(c)
        masks = [ev(ch) for ch in node[1]]
        out = masks[0]
        for m in masks[1:]:
            out = (out & m) if node[0] == "and" else (out | m)
        return out

    return ev(pred)


def normalize_agg(op) -> str:
    """Canonical aggregation op; ``None`` defaults to ``sum``."""
    if op is None:
        return "sum"
    if op not in AGG_OPS:
        raise ValueError(f"unknown aggregation {op!r}; "
                         f"expected one of {AGG_OPS}")
    return op


def normalize_phrase(phrase: Sequence[int]) -> Tuple[int, ...]:
    """Canonical phrase-token tuple: ints, order preserved, length >= 2
    (a 1-gram is a word count, not a sequence query)."""
    if phrase is None:
        raise ValueError("phrase queries need a token sequence")
    out = tuple(int(t) for t in phrase)
    if len(out) < 2:
        raise ValueError(f"phrase queries need at least 2 tokens, "
                         f"got {out!r}")
    if any(t < 0 for t in out):
        raise ValueError(f"negative token ids are invalid: {out}")
    return out
