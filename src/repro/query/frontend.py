"""Text → term-id frontend: queries arrive as raw strings.

The serving layer (and `examples/query.py`) speaks words; the engine
speaks term ids.  This module bridges through
:class:`repro.data.tokenizer.Tokenizer` — the same dictionary the corpus
was compressed with — WITHOUT mutating it: lookups on unknown words map
to ``UNK`` instead of growing the vocab (a query must never change the
compressed data's dictionary).

Filter expressions use a tiny grammar (uppercase keywords so corpus
words stay words)::

    expr := conj ("OR" conj)*
    conj := atom ("AND" atom)*
    atom := "(" expr ")" | WORD (">=" INT)?

``WORD`` alone means ``count(WORD) >= 1`` — presence.
"""

from __future__ import annotations

import re
from typing import List, Tuple

from repro.data.tokenizer import UNK, Tokenizer

from .ops import normalize_phrase, normalize_predicate

__all__ = ["lookup_term", "terms_from_text", "phrase_from_text",
           "predicate_from_text"]

_LEX = re.compile(r"\(|\)|>=|\w+", re.UNICODE)
_WORD = re.compile(r"\w+", re.UNICODE)


def lookup_term(tok: Tokenizer, word: str) -> int:
    """The word's term id, ``UNK`` when absent — never grows the vocab."""
    return tok.word_to_id.get(word, UNK)


def terms_from_text(tok: Tokenizer, text: str) -> Tuple[int, ...]:
    """Term ids of every word in ``text``, in order (agg term sets)."""
    words = _WORD.findall(text)
    if not words:
        raise ValueError(f"no words in query text {text!r}")
    return tuple(lookup_term(tok, w) for w in words)


def phrase_from_text(tok: Tokenizer, text: str) -> Tuple[int, ...]:
    """Adjacent-token phrase from ``text`` (>= 2 words)."""
    return normalize_phrase(terms_from_text(tok, text))


def predicate_from_text(tok: Tokenizer, text: str):
    """Parse a filter expression into the canonical predicate tuples."""
    toks = _LEX.findall(text)
    pos = [0]

    def peek():
        return toks[pos[0]] if pos[0] < len(toks) else None

    def take():
        t = peek()
        if t is None:
            raise ValueError(f"unexpected end of filter expression {text!r}")
        pos[0] += 1
        return t

    def atom():
        t = take()
        if t == "(":
            node = expr()
            if take() != ")":
                raise ValueError(f"unbalanced parentheses in {text!r}")
            return node
        if t in (")", ">=", "AND", "OR"):
            raise ValueError(f"unexpected {t!r} in filter expression "
                             f"{text!r}")
        min_count = 1
        if peek() == ">=":
            take()
            n = take()
            if not n.isdigit():
                raise ValueError(f"'>=' wants an integer, got {n!r} "
                                 f"in {text!r}")
            min_count = int(n)
        return ("term", lookup_term(tok, t), min_count)

    def conj():
        kids: List = [atom()]
        while peek() == "AND":
            take()
            kids.append(atom())
        return kids[0] if len(kids) == 1 else ("and", tuple(kids))

    def expr():
        kids: List = [conj()]
        while peek() == "OR":
            take()
            kids.append(conj())
        return kids[0] if len(kids) == 1 else ("or", tuple(kids))

    node = expr()
    if peek() is not None:
        raise ValueError(f"trailing tokens {toks[pos[0]:]!r} in filter "
                         f"expression {text!r}")
    return normalize_predicate(node)
