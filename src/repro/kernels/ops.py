"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to auto: real lowering on TPU, interpret mode on CPU
(the assignment's validation mode).  Both wrappers fall back to the jnp
reference for degenerate shapes where a kernel launch is pure overhead; the
dispatch predicates are exposed (``bincount_use_ref`` / ``ell_use_ref``) so
tests can assert the routing — including the VMEM-limit branch — without
allocating the big inputs that trigger it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .bincount import weighted_bincount_pallas
from .propagate import ell_row_sums_pallas

# Below these sizes a kernel launch is pure overhead.
BINCOUNT_MIN_N = 64
BINCOUNT_MIN_BINS = 8
ELL_MIN_ROWS = 64
# The ELL kernel keeps the whole weight vector VMEM-resident (~16 MB);
# above ~3.5M rules it cannot fit and the jnp reference takes over.
ELL_VMEM_WEIGHT_LIMIT = 3 << 20


def bincount_use_ref(n: int, nbins: int) -> bool:
    """True when weighted_bincount should route to the jnp reference."""
    return n < BINCOUNT_MIN_N or nbins < BINCOUNT_MIN_BINS


def ell_use_ref(num_weights: int, rows: int) -> bool:
    """True when ell_row_sums should route to the jnp reference (small
    shapes, or weight vectors too large for VMEM)."""
    return num_weights > ELL_VMEM_WEIGHT_LIMIT or rows < ELL_MIN_ROWS


@functools.lru_cache(None)
def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


def _interp(interpret) -> bool:
    return (not _on_tpu()) if interpret is None else bool(interpret)


def weighted_bincount(ids: jnp.ndarray, vals: jnp.ndarray, nbins: int,
                      interpret: bool | None = None) -> jnp.ndarray:
    """MXU histogram: out[b] = sum(vals[ids == b]).  See bincount.py."""
    if ids.shape[0] == 0:
        return jnp.zeros(nbins, jnp.float32)
    if bincount_use_ref(ids.shape[0], nbins):
        return ref.weighted_bincount_ref(ids, vals, nbins)
    return weighted_bincount_pallas(ids, vals, nbins,
                                    interpret=_interp(interpret))


def weighted_bincount_batched(ids: jnp.ndarray, vals: jnp.ndarray,
                              nbins: int,
                              interpret: bool | None = None) -> jnp.ndarray:
    """Batched histogram: out[i, b] = sum(vals[i][ids[i] == b]).

    The batched analytics engine's global-reduction entry point: all N rows
    are fused into ONE kernel launch by offsetting row i's ids into the
    disjoint bin range ``[i * nbins, (i+1) * nbins)`` and histogramming the
    flattened stream (same trick as packing corpora side by side in the
    pre-planned pool).  Ids outside ``[0, nbins)`` are treated as padding
    and ignored, exactly like the unbatched wrapper.
    """
    if ids.ndim != 2 or vals.shape != ids.shape:
        raise ValueError(f"expected matching [N, T] inputs, got "
                         f"{ids.shape} / {vals.shape}")
    n, t = ids.shape
    if n == 0 or t == 0:
        return jnp.zeros((n, nbins), jnp.float32)
    valid = (ids >= 0) & (ids < nbins)
    offs = (jnp.arange(n, dtype=jnp.int32) * nbins)[:, None]
    flat_ids = jnp.where(valid, ids + offs, -1).reshape(-1)
    flat = weighted_bincount(flat_ids, vals.reshape(-1), n * nbins,
                             interpret=interpret)
    return flat.reshape(n, nbins)


def ell_row_sums(weights: jnp.ndarray, src: jnp.ndarray, freq: jnp.ndarray,
                 interpret: bool | None = None) -> jnp.ndarray:
    """ELL gather row sums: the frontier-propagation hot loop."""
    if src.shape[0] == 0:
        return jnp.zeros(0, jnp.float32)
    if ell_use_ref(weights.shape[0], src.shape[0]):
        return ref.ell_row_sums_ref(weights, src, freq)
    return ell_row_sums_pallas(weights, src, freq,
                               interpret=_interp(interpret))


def ell_propagate(weights: jnp.ndarray, src: jnp.ndarray, freq: jnp.ndarray,
                  dst: jnp.ndarray, num_rules: int,
                  interpret: bool | None = None) -> jnp.ndarray:
    """delta[child] += freq * weights[parent]: one full propagation round.

    ``weights`` should already be mask-gated (weight * active) — see
    propagate.py docstring.
    """
    sums = ell_row_sums(weights, src, freq, interpret=interpret)
    return jax.ops.segment_sum(sums, dst, num_segments=num_rules)
