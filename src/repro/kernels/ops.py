"""Jit'd public wrappers + dispatch layer for the Pallas kernels.

``interpret`` defaults to auto: real lowering on TPU, interpret mode on CPU
(the assignment's validation mode).  Wrappers fall back to the jnp
reference for degenerate shapes where a kernel launch is pure overhead; the
dispatch predicates are exposed (``bincount_use_ref`` / ``ell_use_ref`` /
``ell_batched_use_ref`` / ``bincount_batch_rows``) so tests can assert the
routing without allocating the big inputs that trigger it.

DESIGN — ELL vs segment_sum dispatch: the batched traversal engine
(core/batch.py) asks ``ell_batched_use_ref`` whether a round should run on
the dense ``[N, R, K]`` ELL edge plan (gather form, no scatter — see
propagate_batched.py) or stay on the COO segment_sum path.  The predicate
is an occupancy model over (edge count, plan width K — the max in/out fan
bucketed to a power of two, batch width N): very sparse or very wide plans
waste K-proportional work, tiny batches never amortize a launch.  Within
``ell_propagate_batched`` the second routing decision is platform-shaped:
TPU lowers the Pallas kernel; CPU production traffic takes the jnp form of
the same plan (interpret-mode emulation is pure overhead — interpret=True
remains available as the validation oracle).

Weight-vector size routes nothing: both ELL kernels stream the weight
vector through VMEM in grid-blocked chunks (propagate.py DESIGN note), so
arbitrarily large rule counts run through the same kernels — dispatch
decisions here are about occupancy and platform only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .bincount import weighted_bincount_pallas
from .propagate import ell_row_sums_pallas
from .propagate_batched import ell_propagate_batched_pallas

# Below these sizes a kernel launch is pure overhead.
BINCOUNT_MIN_N = 64
BINCOUNT_MIN_BINS = 8
ELL_MIN_ROWS = 64
# weighted_bincount_batched flattens [N, T] ids into N*nbins disjoint bins;
# above this flat-bin count the batch is chunked instead (huge vocabularies
# would otherwise allocate N*V scratch bins for one [N, V] result).
BINCOUNT_BATCH_FLAT_LIMIT = 1 << 22
# Batched ELL-plan occupancy gates (see module docstring).
ELL_BATCH_MIN_ROWS = 64
ELL_BATCH_MAX_WIDTH = 2048
ELL_BATCH_MIN_FILL = 1.0 / 256.0
# Absolute dense-plan budget (N * rows * K entries, ~1 GB of src+freq at the
# limit): the safety valve for *explicit* ELL requests — a huge sparse
# grammar with one moderate hub rule passes the width gate yet would
# allocate an O(R * K) plan far beyond its COO size.
ELL_PLAN_MAX_ENTRIES = 1 << 27


def bincount_use_ref(n: int, nbins: int) -> bool:
    """True when weighted_bincount should route to the jnp reference."""
    return n < BINCOUNT_MIN_N or nbins < BINCOUNT_MIN_BINS


def bincount_batch_rows(n: int, nbins: int) -> int:
    """Rows per flattened chunk for weighted_bincount_batched.

    == n (no chunking) while n*nbins stays under BINCOUNT_BATCH_FLAT_LIMIT;
    above it, the largest row count whose flat bin range fits the limit
    (>= 1 — a single row degenerates to the per-row kernel)."""
    if n * nbins <= BINCOUNT_BATCH_FLAT_LIMIT:
        return n
    return max(1, BINCOUNT_BATCH_FLAT_LIMIT // nbins)


def ell_use_ref(num_weights: int, rows: int) -> bool:
    """True when ell_row_sums should route to the jnp reference.

    Only tiny row counts route away now; ``num_weights`` is kept for API
    compatibility but no longer matters — the blocked kernel streams weight
    vectors of any size through VMEM chunks (propagate.py)."""
    del num_weights
    return rows < ELL_MIN_ROWS


def ell_batched_use_ref(num_edges: int, n: int, rows: int, k: int,
                        shards: int = 1) -> bool:
    """True when a batched propagation round should stay on segment_sum.

    Occupancy dispatch for the dense [N, rows, K] ELL plan: reject tiny
    batches (launch overhead), very wide plans (K beyond any realistic
    in-degree bucket), and plans so sparse that the K-padded gather does
    >256x the real edge work.  ``shards`` > 1 evaluates the launch-overhead
    gate per device — a corpus-sharded pack (core/batch.py DESIGN note)
    launches one program per shard over N/shards rows, so that is the width
    the launch must amortize.  Fill is a ratio and shard-invariant."""
    shards = max(int(shards), 1)
    if (n // shards) * rows < ELL_BATCH_MIN_ROWS:
        return True
    if k > ELL_BATCH_MAX_WIDTH:
        return True
    fill = num_edges / max(n * rows * k, 1)
    return fill < ELL_BATCH_MIN_FILL


_BACKEND_CACHE: dict = {}


def _on_tpu() -> bool:
    """Cached backend probe.  NOT an lru_cache: tests monkeypatch the jax
    backend, and a process-lifetime cache would leak the first answer
    across them — reset_backend_cache() makes the memo revocable."""
    if "on_tpu" not in _BACKEND_CACHE:
        try:
            _BACKEND_CACHE["on_tpu"] = jax.devices()[0].platform == "tpu"
        except Exception:  # pragma: no cover
            _BACKEND_CACHE["on_tpu"] = False
    return _BACKEND_CACHE["on_tpu"]


def reset_backend_cache() -> None:
    """Drop the memoized backend probe (call after changing jax backends).

    Caveat: routing decisions are made at trace time, so programs that are
    already jit-compiled keep whatever branch they baked in — also call
    ``jax.clear_caches()`` if compiled routing must change too."""
    _BACKEND_CACHE.clear()


def _interp(interpret) -> bool:
    return (not _on_tpu()) if interpret is None else bool(interpret)


def weighted_bincount(ids: jnp.ndarray, vals: jnp.ndarray, nbins: int,
                      interpret: bool | None = None) -> jnp.ndarray:
    """MXU histogram: out[b] = sum(vals[ids == b]).  See bincount.py."""
    if ids.shape[0] == 0:
        return jnp.zeros(nbins, jnp.float32)
    if bincount_use_ref(ids.shape[0], nbins):
        return ref.weighted_bincount_ref(ids, vals, nbins)
    return weighted_bincount_pallas(ids, vals, nbins,
                                    interpret=_interp(interpret))


def weighted_bincount_batched(ids: jnp.ndarray, vals: jnp.ndarray,
                              nbins: int,
                              interpret: bool | None = None) -> jnp.ndarray:
    """Batched histogram: out[i, b] = sum(vals[i][ids[i] == b]).

    The batched analytics engine's global-reduction entry point: rows are
    fused into ONE kernel launch by offsetting row i's ids into the
    disjoint bin range ``[i * nbins, (i+1) * nbins)`` and histogramming the
    flattened stream (same trick as packing corpora side by side in the
    pre-planned pool).  Ids outside ``[0, nbins)`` are treated as padding
    and ignored, exactly like the unbatched wrapper.

    Huge vocabularies would make the flat bin range N*nbins blow up, so the
    batch is processed in row chunks of ``bincount_batch_rows(n, nbins)``
    (each chunk's flat range stays under BINCOUNT_BATCH_FLAT_LIMIT; a
    single-row chunk degenerates to the per-row kernel).
    """
    if ids.ndim != 2 or vals.shape != ids.shape:
        raise ValueError(f"expected matching [N, T] inputs, got "
                         f"{ids.shape} / {vals.shape}")
    n, t = ids.shape
    if n == 0 or t == 0:
        return jnp.zeros((n, nbins), jnp.float32)

    def flat_chunk(ids_c: jnp.ndarray, vals_c: jnp.ndarray) -> jnp.ndarray:
        rows = ids_c.shape[0]
        valid = (ids_c >= 0) & (ids_c < nbins)
        offs = (jnp.arange(rows, dtype=jnp.int32) * nbins)[:, None]
        flat_ids = jnp.where(valid, ids_c + offs, -1).reshape(-1)
        flat = weighted_bincount(flat_ids, vals_c.reshape(-1), rows * nbins,
                                 interpret=interpret)
        return flat.reshape(rows, nbins)

    rows = bincount_batch_rows(n, nbins)
    if rows >= n:
        return flat_chunk(ids, vals)
    return jnp.concatenate(
        [flat_chunk(ids[s: s + rows], vals[s: s + rows])
         for s in range(0, n, rows)], axis=0)


def masked_top_k(scores: jnp.ndarray, valid: jnp.ndarray, k: int):
    """Top-k over the trailing axis with invalid slots masked out.

    The search subsystem's ranking primitive: ``scores [..., M]`` and a
    ``valid`` mask of the same shape; masked slots become ``-inf`` so any
    finite real score outranks them.  Returns ``(values, indices)`` of the
    ``k`` largest per row, values descending; ``jax.lax.top_k`` resolves
    equal values toward the LOWER index, which is exactly the
    deterministic file-id tie-break the retrieval layer promises (and the
    numpy oracle's stable argsort reproduces).  ``k`` is static and must
    not exceed the trailing dimension.
    """
    k = int(k)
    if k < 1:
        raise ValueError(f"masked_top_k needs k >= 1, got {k}")
    if k > scores.shape[-1]:
        raise ValueError(f"k={k} exceeds the candidate axis "
                         f"({scores.shape[-1]})")
    masked = jnp.where(valid, scores, -jnp.inf)
    vals, idx = jax.lax.top_k(masked, k)
    return vals, idx                # a real tuple (shard_map out_specs)


def ell_row_sums(weights: jnp.ndarray, src: jnp.ndarray, freq: jnp.ndarray,
                 interpret: bool | None = None) -> jnp.ndarray:
    """ELL gather row sums: the frontier-propagation hot loop."""
    if src.shape[0] == 0:
        return jnp.zeros(0, jnp.float32)
    if ell_use_ref(weights.shape[0], src.shape[0]):
        return ref.ell_row_sums_ref(weights, src, freq)
    return ell_row_sums_pallas(weights, src, freq,
                               interpret=_interp(interpret))


def ell_propagate_batched(weights: jnp.ndarray, active: jnp.ndarray,
                          src: jnp.ndarray, freq: jnp.ndarray,
                          interpret: bool | None = None):
    """One fused propagation round over the dense [N, rows, K] ELL plan.

    Returns ``(delta, seen)`` — both [N, rows] float32; see
    propagate_batched.py for the exact semantics.  Routing: TPU lowers the
    Pallas kernel; on CPU (interpret=None) the jnp form of the same plan is
    the production path, and interpret=True forces the interpret-mode
    kernel as the validation oracle.
    """
    if src.ndim != 3 or freq.shape != src.shape:
        raise ValueError(f"expected matching [N, rows, K] plans, got "
                         f"{src.shape} / {freq.shape}")
    n, rows, k = src.shape
    if n == 0 or rows == 0 or k == 0:
        z = jnp.zeros((n, rows), jnp.float32)
        return z, z
    if interpret is None and not _on_tpu():
        return ref.ell_propagate_batched_ref(weights, active, src, freq)
    return ell_propagate_batched_pallas(weights, active, src, freq,
                                        interpret=_interp(interpret))
