"""Jit'd public wrappers + dispatch layer for the Pallas kernels.

``interpret`` defaults to auto: real lowering on TPU, interpret mode on CPU
(the assignment's validation mode).  Wrappers fall back to the jnp
reference for degenerate shapes where a kernel launch is pure overhead; the
dispatch predicates are exposed (``bincount_use_ref`` / ``ell_use_ref`` /
``ell_batched_use_ref`` / ``bincount_batch_rows``) so tests can assert the
routing without allocating the big inputs that trigger it.

DESIGN — ELL vs segment_sum dispatch: the batched traversal engine
(core/batch.py) asks ``ell_batched_use_ref`` whether a round should run on
the dense ``[N, R, K]`` ELL edge plan (gather form, no scatter — see
propagate_batched.py) or stay on the COO segment_sum path.  The predicate
is an occupancy model over (edge count, plan width K — the max in/out fan
bucketed to a power of two, batch width N): very sparse or very wide plans
waste K-proportional work, tiny batches never amortize a launch.  Within
``ell_propagate_batched`` the second routing decision is platform-shaped:
TPU lowers the Pallas kernel; CPU production traffic takes the jnp form of
the same plan (interpret-mode emulation is pure overhead — interpret=True
remains available as the validation oracle).

Weight-vector size routes nothing: both ELL kernels stream the weight
vector through VMEM in grid-blocked chunks (propagate.py DESIGN note), so
arbitrarily large rule counts run through the same kernels — dispatch
decisions here are about occupancy and platform only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.obs import global_registry

from . import autotune, ref
from ._common import (DEFAULT_BR, DEFAULT_WC, force_interpret,
                      on_tpu as _on_tpu, reset_backend_cache,
                      resolve_interpret as _interp)
from .bincount import weighted_bincount_pallas
from .propagate import ell_row_sums_pallas
from .propagate_batched import ell_propagate_batched_pallas
from .propagate_fused import ell_frontier_fused_pallas
from .propagate_vector import ell_propagate_vector_pallas

__all__ = [
    "weighted_bincount", "weighted_bincount_batched", "masked_top_k",
    "ell_row_sums", "ell_propagate_batched", "ell_propagate_vector",
    "ell_frontier_fused", "bincount_use_ref", "bincount_batch_rows",
    "ell_use_ref", "ell_batched_use_ref", "ell_fused_use_kernel",
    "ell_vector_plan_ok", "reset_backend_cache", "force_interpret",
]

# Below these sizes a kernel launch is pure overhead.
BINCOUNT_MIN_N = 64
BINCOUNT_MIN_BINS = 8
ELL_MIN_ROWS = 64
# weighted_bincount_batched flattens [N, T] ids into N*nbins disjoint bins;
# above this flat-bin count the batch is chunked instead (huge vocabularies
# would otherwise allocate N*V scratch bins for one [N, V] result).
BINCOUNT_BATCH_FLAT_LIMIT = 1 << 22
# Batched ELL-plan occupancy gates (see module docstring).
ELL_BATCH_MIN_ROWS = 64
ELL_BATCH_MAX_WIDTH = 2048
ELL_BATCH_MIN_FILL = 1.0 / 256.0
# Absolute dense-plan budget (N * rows * K entries, ~1 GB of src+freq at the
# limit): the safety valve for *explicit* ELL requests — a huge sparse
# grammar with one moderate hub rule passes the width gate yet would
# allocate an O(R * K) plan far beyond its COO size.
ELL_PLAN_MAX_ENTRIES = 1 << 27
# The fused multi-round kernel keeps the WHOLE frontier state (six [R_pad]
# float32 vectors) VMEM-resident per corpus — ~24 B/rule.  Above this rule
# count the engines fall back to the per-round streaming path.
ELL_FUSED_MAX_RULES = 1 << 18


def _count_dispatch(decision: str, path: str) -> None:
    """Meter one dispatch decision on the process registry.  These fire at
    trace/plan time (host side), so steady-state jitted traffic does NOT
    re-count per call — the counters answer "which engine did this shape
    compile onto", which is the question the 31x campaign needs."""
    global_registry().counter(
        "repro_kernel_dispatch_total",
        "kernel dispatch decisions at trace/plan time",
        ("decision", "path")).labels(decision, path).inc()


def _count_tuned(kind: str, result: str) -> None:
    global_registry().counter(
        "repro_kernel_tuned_table_total",
        "autotune tuned-table lookups by result",
        ("kind", "result")).labels(kind, result).inc()


def _exec_path(interpret) -> str:
    """Which of the three execution modes a wrapper is about to take."""
    if _use_jnp_ref(interpret):
        return "jnp_ref"
    return "pallas_interpret" if _interp(interpret) else "pallas_compiled"


def bincount_use_ref(n: int, nbins: int) -> bool:
    """True when weighted_bincount should route to the jnp reference."""
    return n < BINCOUNT_MIN_N or nbins < BINCOUNT_MIN_BINS


def bincount_batch_rows(n: int, nbins: int) -> int:
    """Rows per flattened chunk for weighted_bincount_batched.

    == n (no chunking) while n*nbins stays under BINCOUNT_BATCH_FLAT_LIMIT;
    above it, the largest row count whose flat bin range fits the limit
    (>= 1 — a single row degenerates to the per-row kernel)."""
    if n * nbins <= BINCOUNT_BATCH_FLAT_LIMIT:
        return n
    return max(1, BINCOUNT_BATCH_FLAT_LIMIT // nbins)


def ell_use_ref(num_weights: int, rows: int) -> bool:
    """True when ell_row_sums should route to the jnp reference.

    Only tiny row counts route away now; ``num_weights`` is kept for API
    compatibility but no longer matters — the blocked kernel streams weight
    vectors of any size through VMEM chunks (propagate.py)."""
    del num_weights
    return rows < ELL_MIN_ROWS


def ell_batched_use_ref(num_edges: int, n: int, rows: int, k: int,
                        shards: int = 1) -> bool:
    """True when a batched propagation round should stay on segment_sum.

    Occupancy dispatch for the dense [N, rows, K] ELL plan: reject tiny
    batches (launch overhead), very wide plans (K beyond any realistic
    in-degree bucket), and plans so sparse that the K-padded gather does
    >256x the real edge work.  ``shards`` > 1 evaluates the launch-overhead
    gate per device — a corpus-sharded pack (core/batch.py DESIGN note)
    launches one program per shard over N/shards rows, so that is the width
    the launch must amortize.  Fill is a ratio and shard-invariant.

    A tuned table entry (kernels/autotune.py, kind ``ell_vs_seg`` — both
    engine paths actually timed on this machine at this shape bucket)
    overrides all of the static heuristics."""
    shards = max(int(shards), 1)
    tuned = autotune.tuned_use_ref(
        "ell_vs_seg", autotune.shape_bucket(max(n // shards, 1), rows, k))
    if tuned is not None:
        _count_tuned("ell_vs_seg", "hit")
        use_ref = tuned
    else:
        _count_tuned("ell_vs_seg", "miss")
        use_ref = ((n // shards) * rows < ELL_BATCH_MIN_ROWS
                   or k > ELL_BATCH_MAX_WIDTH
                   or num_edges / max(n * rows * k, 1)
                   < ELL_BATCH_MIN_FILL)
    _count_dispatch("ell_vs_seg", "segment_sum" if use_ref else "ell")
    return use_ref


def ell_fused_use_kernel(rows: int) -> bool:
    """True when the fused multi-round traversal may run device-resident:
    the whole frontier state must fit VMEM (see ELL_FUSED_MAX_RULES).
    Engines that get False fall back to the per-round frontier path —
    identical results, per-round dispatch cost."""
    fused = rows <= ELL_FUSED_MAX_RULES
    _count_dispatch("fused_vs_per_round", "fused" if fused else "per_round")
    return fused


def ell_vector_plan_ok(n: int, rows: int, k: int, f: int) -> bool:
    """True when the vector-payload [N, rows, K] x [R, F] round fits the
    dense-plan budget (the gather materializes N*rows*K*F contributions)."""
    return n * rows * k * max(f, 1) <= ELL_PLAN_MAX_ENTRIES


def _use_jnp_ref(interpret) -> bool:
    """True when production dispatch should take the jnp reference form:
    CPU with auto routing (interpret-mode kernel emulation is pure
    overhead) — unless the forced-interpret CI lane is on, which exists
    precisely to push production traffic through the Pallas code paths."""
    return interpret is None and not _on_tpu() and not force_interpret()


def _blocks(kind: str, bucket, defaults: dict) -> dict:
    """Merge tuned block sizes (autotune table) over the shipped defaults."""
    merged = dict(defaults)
    tuned = autotune.tuned_blocks(kind, bucket)
    _count_tuned(kind, "hit" if tuned else "miss")
    for key, val in tuned.items():
        if key in merged:
            merged[key] = val
    return merged


def weighted_bincount(ids: jnp.ndarray, vals: jnp.ndarray, nbins: int,
                      interpret: bool | None = None) -> jnp.ndarray:
    """MXU histogram: out[b] = sum(vals[ids == b]).  See bincount.py."""
    if ids.shape[0] == 0:
        return jnp.zeros(nbins, jnp.float32)
    if bincount_use_ref(ids.shape[0], nbins):
        return ref.weighted_bincount_ref(ids, vals, nbins)
    return weighted_bincount_pallas(ids, vals, nbins,
                                    interpret=_interp(interpret))


def weighted_bincount_batched(ids: jnp.ndarray, vals: jnp.ndarray,
                              nbins: int,
                              interpret: bool | None = None) -> jnp.ndarray:
    """Batched histogram: out[i, b] = sum(vals[i][ids[i] == b]).

    The batched analytics engine's global-reduction entry point: rows are
    fused into ONE kernel launch by offsetting row i's ids into the
    disjoint bin range ``[i * nbins, (i+1) * nbins)`` and histogramming the
    flattened stream (same trick as packing corpora side by side in the
    pre-planned pool).  Ids outside ``[0, nbins)`` are treated as padding
    and ignored, exactly like the unbatched wrapper.

    Huge vocabularies would make the flat bin range N*nbins blow up, so the
    batch is processed in row chunks of ``bincount_batch_rows(n, nbins)``
    (each chunk's flat range stays under BINCOUNT_BATCH_FLAT_LIMIT; a
    single-row chunk degenerates to the per-row kernel).
    """
    if ids.ndim != 2 or vals.shape != ids.shape:
        raise ValueError(f"expected matching [N, T] inputs, got "
                         f"{ids.shape} / {vals.shape}")
    n, t = ids.shape
    if n == 0 or t == 0:
        return jnp.zeros((n, nbins), jnp.float32)

    def flat_chunk(ids_c: jnp.ndarray, vals_c: jnp.ndarray) -> jnp.ndarray:
        rows = ids_c.shape[0]
        valid = (ids_c >= 0) & (ids_c < nbins)
        offs = (jnp.arange(rows, dtype=jnp.int32) * nbins)[:, None]
        flat_ids = jnp.where(valid, ids_c + offs, -1).reshape(-1)
        flat = weighted_bincount(flat_ids, vals_c.reshape(-1), rows * nbins,
                                 interpret=interpret)
        return flat.reshape(rows, nbins)

    rows = bincount_batch_rows(n, nbins)
    if rows >= n:
        return flat_chunk(ids, vals)
    return jnp.concatenate(
        [flat_chunk(ids[s: s + rows], vals[s: s + rows])
         for s in range(0, n, rows)], axis=0)


def masked_top_k(scores: jnp.ndarray, valid: jnp.ndarray, k: int):
    """Top-k over the trailing axis with invalid slots masked out.

    The search subsystem's ranking primitive: ``scores [..., M]`` and a
    ``valid`` mask of the same shape; masked slots become ``-inf`` so any
    finite real score outranks them.  Returns ``(values, indices)`` of the
    ``k`` largest per row, values descending; ``jax.lax.top_k`` resolves
    equal values toward the LOWER index, which is exactly the
    deterministic file-id tie-break the retrieval layer promises (and the
    numpy oracle's stable argsort reproduces).  ``k`` is static and must
    not exceed the trailing dimension.
    """
    k = int(k)
    if k < 1:
        raise ValueError(f"masked_top_k needs k >= 1, got {k}")
    if k > scores.shape[-1]:
        raise ValueError(f"k={k} exceeds the candidate axis "
                         f"({scores.shape[-1]})")
    masked = jnp.where(valid, scores, -jnp.inf)
    vals, idx = jax.lax.top_k(masked, k)
    return vals, idx                # a real tuple (shard_map out_specs)


def ell_row_sums(weights: jnp.ndarray, src: jnp.ndarray, freq: jnp.ndarray,
                 interpret: bool | None = None) -> jnp.ndarray:
    """ELL gather row sums: the frontier-propagation hot loop."""
    if src.shape[0] == 0:
        return jnp.zeros(0, jnp.float32)
    if ell_use_ref(weights.shape[0], src.shape[0]):
        return ref.ell_row_sums_ref(weights, src, freq)
    return ell_row_sums_pallas(weights, src, freq,
                               interpret=_interp(interpret))


def ell_propagate_batched(weights: jnp.ndarray, active: jnp.ndarray,
                          src: jnp.ndarray, freq: jnp.ndarray,
                          interpret: bool | None = None):
    """One fused propagation round over the dense [N, rows, K] ELL plan.

    Returns ``(delta, seen)`` — both [N, rows] float32; see
    propagate_batched.py for the exact semantics.  Routing: TPU lowers the
    Pallas kernel; on CPU (interpret=None) the jnp form of the same plan is
    the production path, and interpret=True forces the interpret-mode
    kernel as the validation oracle.
    """
    if src.ndim != 3 or freq.shape != src.shape:
        raise ValueError(f"expected matching [N, rows, K] plans, got "
                         f"{src.shape} / {freq.shape}")
    n, rows, k = src.shape
    if n == 0 or rows == 0 or k == 0:
        z = jnp.zeros((n, rows), jnp.float32)
        return z, z
    _count_dispatch("exec:ell_batched", _exec_path(interpret))
    if _use_jnp_ref(interpret):
        return ref.ell_propagate_batched_ref(weights, active, src, freq)
    blocks = _blocks("ell_batched", autotune.shape_bucket(n, rows, k),
                     {"br": DEFAULT_BR, "wc": DEFAULT_WC})
    return ell_propagate_batched_pallas(weights, active, src, freq,
                                        interpret=_interp(interpret),
                                        **blocks)


def ell_propagate_vector(W: jnp.ndarray, active: jnp.ndarray,
                         src: jnp.ndarray, freq: jnp.ndarray,
                         interpret: bool | None = None):
    """One vector-payload propagation round over the [N, rows, K] plan.

    W: [N, R, F] per-file payload; returns ``(delta [N, rows, F],
    seen [N, rows])`` — the per-file traversals' ELL round (see
    propagate_vector.py).  Routing mirrors ``ell_propagate_batched``: TPU
    lowers the Pallas kernel, CPU production takes the jnp gather form,
    interpret=True (or the forced-interpret lane) runs the interpret-mode
    kernel as the validation oracle.
    """
    if src.ndim != 3 or freq.shape != src.shape:
        raise ValueError(f"expected matching [N, rows, K] plans, got "
                         f"{src.shape} / {freq.shape}")
    if W.ndim != 3:
        raise ValueError(f"expected [N, R, F] payload, got {W.shape}")
    n, rows, k = src.shape
    if n == 0 or rows == 0 or k == 0:
        return (jnp.zeros((n, rows, W.shape[-1]), jnp.float32),
                jnp.zeros((n, rows), jnp.float32))
    _count_dispatch("exec:ell_vector", _exec_path(interpret))
    if _use_jnp_ref(interpret):
        return ref.ell_propagate_vector_ref(W, active, src, freq)
    from .propagate_vector import DEFAULT_BRV, DEFAULT_WCV
    from ._common import DEFAULT_FC
    blocks = _blocks(
        "ell_vector", autotune.shape_bucket(n, rows, k, W.shape[-1]),
        {"br": DEFAULT_BRV, "wc": DEFAULT_WCV, "fc": DEFAULT_FC})
    return ell_propagate_vector_pallas(W, active, src, freq,
                                       interpret=_interp(interpret),
                                       **blocks)


def ell_frontier_fused(weights0: jnp.ndarray, in_deg: jnp.ndarray,
                       src: jnp.ndarray, freq: jnp.ndarray,
                       max_rounds: int, with_rounds: bool = False,
                       interpret: bool | None = None):
    """The WHOLE frontier traversal in one dispatch (see propagate_fused.py).

    weights0/in_deg: [N, R]; src/freq: [N, R, K]; ``max_rounds`` must bound
    the frontier round count (the DAG's ``num_levels`` is exact).  Returns
    weights [N, R] — or ``(weights, rounds [N])`` when ``with_rounds``.
    Callers must pre-gate with ``ell_fused_use_kernel(R)`` (VMEM state
    residency); routing follows ``ell_propagate_batched``: CPU production
    runs the jitted fori_loop reference (one dispatch, no per-round
    convergence test — the same structural-tax win in jnp form), TPU and
    the interpret lanes run the Pallas kernel.
    """
    if src.ndim != 3 or freq.shape != src.shape:
        raise ValueError(f"expected matching [N, rows, K] plans, got "
                         f"{src.shape} / {freq.shape}")
    n, rows, k = src.shape
    if n == 0 or rows == 0 or k == 0:
        w = weights0.astype(jnp.float32)
        return (w, jnp.zeros(n, jnp.int32)) if with_rounds else w
    _count_dispatch("exec:ell_fused", _exec_path(interpret))
    if _use_jnp_ref(interpret):
        return ref.ell_frontier_fused_ref(weights0, in_deg, src, freq,
                                          max_rounds,
                                          with_rounds=with_rounds)
    blocks = _blocks("ell_fused",
                     autotune.shape_bucket(n, rows, k, max_rounds),
                     {"br": DEFAULT_BR})
    w, rounds = ell_frontier_fused_pallas(weights0, in_deg, src, freq,
                                          max_rounds,
                                          interpret=_interp(interpret),
                                          **blocks)
    return (w, rounds) if with_rounds else w
