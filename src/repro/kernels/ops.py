"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to auto: real lowering on TPU, interpret mode on CPU
(the assignment's validation mode).  Both wrappers fall back to the jnp
reference for degenerate shapes where a kernel launch is pure overhead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .bincount import weighted_bincount_pallas
from .propagate import ell_row_sums_pallas


@functools.lru_cache(None)
def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


def _interp(interpret) -> bool:
    return (not _on_tpu()) if interpret is None else bool(interpret)


def weighted_bincount(ids: jnp.ndarray, vals: jnp.ndarray, nbins: int,
                      interpret: bool | None = None) -> jnp.ndarray:
    """MXU histogram: out[b] = sum(vals[ids == b]).  See bincount.py."""
    if ids.shape[0] == 0:
        return jnp.zeros(nbins, jnp.float32)
    if ids.shape[0] < 64 or nbins < 8:        # launch overhead dominates
        return ref.weighted_bincount_ref(ids, vals, nbins)
    return weighted_bincount_pallas(ids, vals, nbins,
                                    interpret=_interp(interpret))


def ell_row_sums(weights: jnp.ndarray, src: jnp.ndarray, freq: jnp.ndarray,
                 interpret: bool | None = None) -> jnp.ndarray:
    """ELL gather row sums: the frontier-propagation hot loop."""
    if src.shape[0] == 0:
        return jnp.zeros(0, jnp.float32)
    # full weight vector must fit VMEM (~16MB); fall back above ~3.5M rules
    if weights.shape[0] > (3 << 20) or src.shape[0] < 64:
        return ref.ell_row_sums_ref(weights, src, freq)
    return ell_row_sums_pallas(weights, src, freq,
                               interpret=_interp(interpret))


def ell_propagate(weights: jnp.ndarray, src: jnp.ndarray, freq: jnp.ndarray,
                  dst: jnp.ndarray, num_rules: int,
                  interpret: bool | None = None) -> jnp.ndarray:
    """delta[child] += freq * weights[parent]: one full propagation round.

    ``weights`` should already be mask-gated (weight * active) — see
    propagate.py docstring.
    """
    sums = ell_row_sums(weights, src, freq, interpret=interpret)
    return jax.ops.segment_sum(sums, dst, num_segments=num_rules)
