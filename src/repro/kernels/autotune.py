"""Block-size / XLA-flag autotuner for the Pallas kernel dispatch layer.

The dispatch predicates in ops.py (``ell_batched_use_ref`` and friends) and
the kernel block sizes (row-block ``br``, weight-chunk ``wc``, the vector
kernel's F-block ``fc``) ship with static defaults.  This module measures
the real machine instead:

* ``tune_ell_batched`` / ``tune_ell_fused`` / ``tune_ell_vector`` sweep
  candidate block shapes (the jnp reference form is itself a candidate, so
  the sweep also answers the ref-vs-kernel routing question) and return a
  winner entry;
* winners persist in a small JSON cache keyed ``(backend, kind,
  shape-bucket)`` — shape buckets are the same pow2 rounding the packing
  layer uses, so one tuning run covers every pack that compiles to the
  same program;
* ops.py consults the table first (``tuned_use_ref`` / ``tuned_blocks``)
  and falls back to its static heuristics on a miss — an absent or stale
  cache can never change results, only speed;
* ``sweep_xla_flags`` times a workload under named XLA flag sets in fresh
  subprocesses (flags are process-global, so in-process sweeping is
  impossible) — the flag-set dictionary follows saxml's
  ``llm_xla_flags.py`` shape: named, per-backend, composable.  Failures
  (unknown flag on this jax build) score ``inf`` and lose, never crash;
* ``hlo_profile`` revives utils/hlo_analysis.py + launch/roofline.py as
  measurement instrumentation: op histogram, FLOP/byte estimates and
  roofline classification for any jitted workload.

benchmarks/bench_batch.py runs the sweeps on its corpus set and records
``autotune/*`` rows into BENCH_batch.json; CI uploads the cache file as an
artifact so the tuned table is inspectable per run.
"""

from __future__ import annotations

import contextlib
import json
import os
import subprocess
import sys
import time
from typing import Any, Callable, Dict, Iterable, Optional, Sequence, Tuple

from ._common import DEFAULT_BR, DEFAULT_FC, DEFAULT_WC, round_up_pow2

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
DEFAULT_CACHE_PATH = "AUTOTUNE_cache.json"
CACHE_VERSION = 1

# Opt-in profiler annotations: when this env var is set (non-empty, not
# "0"), every timed candidate runs inside a named
# ``jax.profiler.TraceAnnotation`` region, so a captured device trace
# (``jax.profiler.trace``) attributes kernel time to the sweep candidate
# that launched it.  Off by default — the annotation context has a small
# per-call cost and tuning runs are usually not being profiled.
ANNOTATE_ENV = "REPRO_PROFILE_ANNOTATIONS"


def annotations_enabled() -> bool:
    return os.environ.get(ANNOTATE_ENV, "") not in ("", "0")


def trace_annotation(name: str) -> contextlib.AbstractContextManager:
    """A context manager naming the enclosed device work in profiler
    traces; a free ``nullcontext`` unless ``REPRO_PROFILE_ANNOTATIONS``
    is set (jax import deferred so the off path stays import-free)."""
    if not annotations_enabled():
        return contextlib.nullcontext()
    import jax
    return jax.profiler.TraceAnnotation(name)

# Candidate grids.  Small on purpose: each candidate is a fresh compile.
BR_CANDIDATES = (128, 256, 512)
WC_CANDIDATES = (1 << 16, 1 << 19)
BRV_CANDIDATES = (32, 64, 128)
FC_CANDIDATES = (64, 128, 256)

# Named XLA flag sets per backend (saxml llm_xla_flags.py idiom: a flat
# name -> {flag: value} table; "default" is the empty set and always a
# candidate, so the sweep's winner can never be slower than shipping
# defaults).  TPU sets are carried for when a TPU runner executes the
# sweep; the CPU sets are conservative, widely-available flags.
XLA_FLAG_SETS: Dict[str, Dict[str, Dict[str, str]]] = {
    "cpu": {
        "default": {},
        "fast_min_max": {"xla_cpu_enable_fast_min_max": "true"},
        "no_fast_min_max": {"xla_cpu_enable_fast_min_max": "false"},
    },
    "tpu": {
        "default": {},
        "latency_hiding": {
            "xla_tpu_enable_latency_hiding_scheduler": "true",
        },
        "async_collectives": {
            "xla_enable_async_all_gather": "true",
            "xla_enable_async_collective_permute": "true",
        },
    },
}


def backend_name() -> str:
    import jax
    return jax.default_backend()


def shape_bucket(*dims: int) -> Tuple[int, ...]:
    """Pow2-bucketed shape key — the same rounding the packing layer uses,
    so every pack that shares a compiled program shares a tuning entry."""
    return tuple(round_up_pow2(int(d)) for d in dims)


def _key(kind: str, bucket: Sequence[int], backend: Optional[str]) -> str:
    b = backend if backend is not None else backend_name()
    return "|".join([b, kind, "x".join(str(int(d)) for d in bucket)])


# ----------------------------------------------------------------------- #
# The persistent table                                                     #
# ----------------------------------------------------------------------- #
def cache_path() -> str:
    return os.environ.get(CACHE_ENV) or DEFAULT_CACHE_PATH


_TABLE: Optional[Dict[str, Any]] = None
_TABLE_PATH: Optional[str] = None


def load_table(path: Optional[str] = None) -> Dict[str, Any]:
    """Load (and memoize) the tuned table; a missing/corrupt file is an
    empty table — the autotuner can only ever speed things up."""
    global _TABLE, _TABLE_PATH
    p = path or cache_path()
    if _TABLE is not None and _TABLE_PATH == p:
        return _TABLE
    table: Dict[str, Any] = {}
    try:
        with open(p) as f:
            data = json.load(f)
        if isinstance(data, dict) and data.get("version") == CACHE_VERSION:
            table = dict(data.get("entries", {}))
    except (OSError, ValueError):
        table = {}
    _TABLE, _TABLE_PATH = table, p
    return table


def save_table(path: Optional[str] = None) -> str:
    p = path or cache_path()
    table = load_table(p)
    with open(p, "w") as f:
        json.dump({"version": CACHE_VERSION, "entries": table}, f,
                  indent=1, sort_keys=True)
    return p


def reset_table() -> None:
    """Drop the in-memory table memo (tests point CACHE_ENV elsewhere)."""
    global _TABLE, _TABLE_PATH
    _TABLE, _TABLE_PATH = None, None


def put_entry(kind: str, bucket: Sequence[int], entry: Dict[str, Any],
              backend: Optional[str] = None) -> None:
    load_table()[_key(kind, bucket, backend)] = entry


def get_entry(kind: str, bucket: Sequence[int],
              backend: Optional[str] = None) -> Optional[Dict[str, Any]]:
    return load_table().get(_key(kind, bucket, backend))


def tuned_use_ref(kind: str, bucket: Sequence[int],
                  backend: Optional[str] = None) -> Optional[bool]:
    """Tuned ref-vs-kernel routing; None on a table miss (callers fall back
    to the static heuristics in ops.py)."""
    e = get_entry(kind, bucket, backend)
    if e is None or "use_ref" not in e:
        return None
    return bool(e["use_ref"])


def tuned_blocks(kind: str, bucket: Sequence[int],
                 backend: Optional[str] = None) -> Dict[str, int]:
    """Tuned block sizes ({} on a miss; callers merge over defaults)."""
    e = get_entry(kind, bucket, backend)
    if e is None:
        return {}
    return {k: int(v) for k, v in e.get("blocks", {}).items()}


# ----------------------------------------------------------------------- #
# In-process block-size sweeps                                             #
# ----------------------------------------------------------------------- #
def _time_call(fn: Callable[[], Any], repeat: int = 3,
               warmup: int = 1, name: str = "autotune") -> float:
    """Median seconds per call, steady-state (results block_until_ready).
    ``name`` labels the timed region in profiler traces when
    ``REPRO_PROFILE_ANNOTATIONS`` is on (see :func:`trace_annotation`)."""
    import jax

    def run() -> None:
        with trace_annotation(name):
            jax.block_until_ready(fn())

    for _ in range(warmup):
        run()
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        run()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _sweep(candidates: Iterable[Tuple[str, Dict[str, int],
                                      Callable[[], Any]]],
           repeat: int, warmup: int) -> Dict[str, Any]:
    """Time every (name, blocks, thunk) candidate; return the winner entry
    (a failing candidate — e.g. a block shape the backend rejects — scores
    inf and loses)."""
    table: Dict[str, float] = {}
    best: Optional[Tuple[str, Dict[str, int]]] = None
    for name, blocks, thunk in candidates:
        try:
            t = _time_call(thunk, repeat=repeat, warmup=warmup,
                           name=f"autotune:{name}")
        except Exception:
            t = float("inf")
        table[name] = t
        if best is None or t < table[best[0]]:
            best = (name, blocks)
    assert best is not None, "no candidates"
    name, blocks = best
    return {
        "winner": name,
        "blocks": blocks,
        "use_ref": name == "ref",
        "us": table[name] * 1e6,
        "default_us": table.get("default", float("inf")) * 1e6,
        "table_us": {k: v * 1e6 for k, v in table.items()},
    }


def tune_ell_batched(weights, active, src, freq,
                     brs: Sequence[int] = BR_CANDIDATES,
                     wcs: Sequence[int] = WC_CANDIDATES,
                     repeat: int = 3, warmup: int = 1,
                     save: bool = False) -> Dict[str, Any]:
    """Sweep the scalar batched ELL kernel on a real plan; persist winner."""
    from . import ref
    from .propagate_batched import ell_propagate_batched_pallas

    n, rows, k = src.shape
    cands: list = [
        ("ref", {},
         lambda: ref.ell_propagate_batched_ref(weights, active, src, freq)),
        ("default", {"br": DEFAULT_BR, "wc": DEFAULT_WC},
         lambda: ell_propagate_batched_pallas(weights, active, src, freq)),
    ]
    for br in brs:
        for wc in wcs:
            if br == DEFAULT_BR and wc == DEFAULT_WC:
                continue
            cands.append((
                f"br{br}_wc{wc}", {"br": br, "wc": wc},
                lambda br=br, wc=wc: ell_propagate_batched_pallas(
                    weights, active, src, freq, br=br, wc=wc)))
    entry = _sweep(cands, repeat, warmup)
    put_entry("ell_batched", shape_bucket(n, rows, k), entry)
    if save:
        save_table()
    return entry


def tune_ell_fused(weights0, in_deg, src, freq, max_rounds: int,
                   brs: Sequence[int] = BR_CANDIDATES,
                   repeat: int = 3, warmup: int = 1,
                   save: bool = False) -> Dict[str, Any]:
    """Sweep the fused multi-round traversal (ref fori form vs kernel)."""
    from . import ref
    from .propagate_fused import ell_frontier_fused_pallas

    n, rows, k = src.shape
    cands: list = [
        ("ref", {},
         lambda: ref.ell_frontier_fused_ref(weights0, in_deg, src, freq,
                                            max_rounds)),
        ("default", {"br": DEFAULT_BR},
         lambda: ell_frontier_fused_pallas(weights0, in_deg, src, freq,
                                           max_rounds)),
    ]
    for br in brs:
        if br == DEFAULT_BR:
            continue
        cands.append((
            f"br{br}", {"br": br},
            lambda br=br: ell_frontier_fused_pallas(
                weights0, in_deg, src, freq, max_rounds, br=br)))
    entry = _sweep(cands, repeat, warmup)
    put_entry("ell_fused", shape_bucket(n, rows, k, max_rounds), entry)
    if save:
        save_table()
    return entry


def tune_ell_vector(W, active, src, freq,
                    brs: Sequence[int] = BRV_CANDIDATES,
                    fcs: Sequence[int] = FC_CANDIDATES,
                    repeat: int = 3, warmup: int = 1,
                    save: bool = False) -> Dict[str, Any]:
    """Sweep the vector-payload kernel's (row-block, F-block) shape."""
    from . import ref
    from .propagate_vector import (DEFAULT_BRV, DEFAULT_WCV,
                                   ell_propagate_vector_pallas)

    n, rows, k = src.shape
    F = W.shape[-1]
    cands: list = [
        ("ref", {},
         lambda: ref.ell_propagate_vector_ref(W, active, src, freq)),
        ("default", {"br": DEFAULT_BRV, "wc": DEFAULT_WCV, "fc": DEFAULT_FC},
         lambda: ell_propagate_vector_pallas(W, active, src, freq)),
    ]
    for br in brs:
        for fc in fcs:
            if br == DEFAULT_BRV and fc == DEFAULT_FC:
                continue
            cands.append((
                f"br{br}_fc{fc}", {"br": br, "wc": DEFAULT_WCV, "fc": fc},
                lambda br=br, fc=fc: ell_propagate_vector_pallas(
                    W, active, src, freq, br=br, fc=fc)))
    entry = _sweep(cands, repeat, warmup)
    put_entry("ell_vector", shape_bucket(n, rows, k, F), entry)
    if save:
        save_table()
    return entry


# ----------------------------------------------------------------------- #
# XLA flag sweep (fresh subprocess per flag set — flags are process-global)#
# ----------------------------------------------------------------------- #
def _flags_to_env(flags: Dict[str, str]) -> str:
    return " ".join(f"--{k}={v}" for k, v in flags.items())


def _default_runner(workload: str, xla_flags: str) -> float:
    """Run ``workload`` (python source printing one float: seconds/call) in
    a fresh interpreter under XLA_FLAGS; inf on any failure."""
    env = dict(os.environ)
    if xla_flags:
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + xla_flags).strip()
    src_dir = os.path.join(os.path.dirname(__file__), "..", "..")
    env["PYTHONPATH"] = (os.path.abspath(src_dir) + os.pathsep
                         + env.get("PYTHONPATH", ""))
    try:
        out = subprocess.run([sys.executable, "-c", workload], env=env,
                             capture_output=True, text=True, timeout=600)
        if out.returncode != 0:
            return float("inf")
        return float(out.stdout.strip().splitlines()[-1])
    except (OSError, ValueError, IndexError, subprocess.TimeoutExpired):
        return float("inf")


def sweep_xla_flags(workload: str,
                    backend: Optional[str] = None,
                    flag_sets: Optional[Dict[str, Dict[str, str]]] = None,
                    runner: Optional[Callable[[str, str], float]] = None,
                    save: bool = False) -> Dict[str, Any]:
    """Time ``workload`` under every named flag set for ``backend``.

    ``runner(workload, xla_flags) -> seconds`` is injectable for tests; the
    default spawns a fresh interpreter per set (XLA flags are read once per
    process).  The winner persists under kind "xla_flags" keyed by a hash
    bucket of the workload source, and "default" is always a candidate so
    the tuned flags can never lose to shipping none.
    """
    b = backend or backend_name()
    sets = flag_sets if flag_sets is not None else XLA_FLAG_SETS.get(b, {})
    if "default" not in sets:
        sets = {"default": {}, **sets}
    run = runner or _default_runner
    table: Dict[str, float] = {}
    for name, flags in sets.items():
        table[name] = run(workload, _flags_to_env(flags))
    winner = min(table, key=lambda k: table[k])
    entry = {
        "winner": winner,
        "flags": sets[winner],
        "us": table[winner] * 1e6,
        "default_us": table.get("default", float("inf")) * 1e6,
        "table_us": {k: v * 1e6 for k, v in table.items()},
    }
    import zlib
    bucket = (zlib.crc32(workload.encode()) & 0xffff,)
    put_entry("xla_flags", bucket, entry, backend=b)
    if save:
        save_table()
    return entry


# ----------------------------------------------------------------------- #
# HLO instrumentation (utils/hlo_analysis + launch/roofline revived)       #
# ----------------------------------------------------------------------- #
def hlo_profile(fn: Callable[..., Any], *args: Any,
                **static: Any) -> Dict[str, Any]:
    """Compile ``fn(*args)`` and report what the autotuner is moving.

    Returns the compiled op histogram (utils.hlo_analysis.op_histogram),
    collective traffic, XLA's own FLOP/byte cost analysis, and the
    roofline classification (compute- vs bandwidth-bound against the
    launch/roofline.py machine model) — the instrumentation behind the
    autotune BENCH rows.
    """
    import jax

    from repro.launch import roofline
    from repro.utils import hlo_analysis

    lowered = jax.jit(fn, static_argnames=tuple(static) or None).lower(
        *args, **static)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    out: Dict[str, Any] = {
        "ops": hlo_analysis.op_histogram(hlo),
        "collective_bytes": hlo_analysis.total_collective_bytes(hlo),
    }
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        flops = float(cost.get("flops", 0.0))
        bytes_ = float(cost.get("bytes accessed", 0.0))
        out["flops"] = flops
        out["bytes"] = bytes_
        if bytes_ > 0:
            intensity = flops / bytes_
            ridge = roofline.PEAK_FLOPS / roofline.HBM_BW
            out["intensity"] = intensity
            out["bound"] = "compute" if intensity >= ridge else "bandwidth"
    except Exception:  # pragma: no cover - cost analysis is best-effort
        pass
    return out
