"""Pallas TPU kernel: batched ELL propagation with a VECTOR [R, F] payload.

The scalar kernel (propagate_batched.py) carries one float per rule; the
per-file traversals — `per_file_weights` and the pack-level statistics that
feed `search/` — carry a per-file row ``W[r, :]`` per rule.  Historically
those traversals silently remapped ELL methods back to their segment_sum
bases; this kernel closes that gap.  One round over the same dense
``src/freq [N, R, K]`` edge plan:

  delta[n, r, f] = sum_k freq[n, r, k] * W[n, src[n, r, k], f]
                                       * active[n, src[n, r, k]]
  seen[n, r]     = sum_k [freq[n, r, k] > 0] * active[n, src[n, r, k]]

Grid = (corpus, row-block, F-block, rule-chunk): the payload matrix streams
through VMEM as ``(wc, fc)`` tiles — the F axis is blocked exactly like the
issue's "F-axis-blocked payload" and the rule axis streams in chunks like
the scalar kernels (out blocks depend only on (n, i, f); chunk jw is the
innermost revisiting dimension with init at jw == 0), so neither rule count
nor file count holds a VMEM cliff.  ``seen`` is payload-independent and is
accumulated only on the first F-block (its out block revisits across
(jf, jw); untouched revisits keep the buffer).

Root-edge exclusion (the per-file init already accounts for root's
contributions) is the CALLER's job via the active mask: per-file frontier
masks start with ``mask[0] == 0`` forever (the root is `ever` from round
zero), and the leveled schedule zeroes the root column — no ``src != 0``
gate is needed in-kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._common import DEFAULT_FC, resolve_interpret, round_up_pow2

# Rows per block and rule-chunk length: smaller than the scalar kernel's —
# the gather materializes a [BR, K, FC] tile and the payload chunk is
# (WC, FC) f32 (4 KB/row at FC=128), so both shrink to keep VMEM bounded.
DEFAULT_BRV = 64
DEFAULT_WCV = 1 << 12


def _kernel(w_ref, a_ref, src_ref, freq_ref, delta_ref, seen_ref,
            *, wc: int, fc: int):
    jf = pl.program_id(2)                # F-block
    jw = pl.program_id(3)                # rule-chunk (innermost)

    @pl.when(jw == 0)
    def _init():
        delta_ref[...] = jnp.zeros_like(delta_ref)

    @pl.when((jf == 0) & (jw == 0))
    def _init_seen():
        seen_ref[...] = jnp.zeros_like(seen_ref)

    base = jw * wc
    w = w_ref[0]                         # [wc, fc] payload tile
    a = a_ref[0, :]                      # [wc] active-mask chunk
    src = src_ref[0]                     # [BR, K]
    freq = freq_ref[0]                   # [BR, K] float32
    loc = src - base
    in_chunk = (loc >= 0) & (loc < wc)
    idx = jnp.clip(loc, 0, wc - 1).reshape(-1)
    gw = jnp.take(w, idx, axis=0).reshape(src.shape + (fc,))   # [BR, K, fc]
    ga = jnp.take(a, idx, axis=0).reshape(src.shape)
    ga = jnp.where(in_chunk, ga, 0.0)
    delta_ref[...] += ((freq * ga)[..., None] * gw).sum(axis=1)[None]

    @pl.when(jf == 0)
    def _seen():
        seen_ref[...] += jnp.where(freq > 0, ga, 0.0).sum(axis=-1)[None, :]


def ell_propagate_vector_pallas(W: jnp.ndarray, active: jnp.ndarray,
                                src: jnp.ndarray, freq: jnp.ndarray,
                                br: int = DEFAULT_BRV, wc: int = DEFAULT_WCV,
                                fc: int = DEFAULT_FC,
                                interpret: bool | None = None):
    """(delta, seen) of one vector-payload round over the [N, R, K] plan.

    W: [N, R, F] float32 payload; active: [N, R] float32 mask; src/freq:
    [N, rows, K].  Returns ``(delta [N, rows, F], seen [N, rows])``.
    ``interpret=None`` auto-resolves outside jit (_common.resolve_interpret).
    """
    return _ell_propagate_vector_jit(W, active, src, freq, br, wc, fc,
                                     resolve_interpret(interpret))


@functools.partial(
    jax.jit, static_argnames=("br", "wc", "fc", "interpret"))
def _ell_propagate_vector_jit(W, active, src, freq,
                              br: int, wc: int, fc: int, interpret: bool):
    n, rows, k = src.shape
    R, F = W.shape[1], W.shape[2]
    pad = (-rows) % br
    src_p = jnp.pad(src.astype(jnp.int32), ((0, 0), (0, pad), (0, 0)))
    freq_p = jnp.pad(freq.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    rtot = rows + pad
    wc = min(wc, round_up_pow2(R))
    fc = min(fc, round_up_pow2(F))
    wpad = (-R) % wc
    fpad = (-F) % fc
    w_p = jnp.pad(W.astype(jnp.float32), ((0, 0), (0, wpad), (0, fpad)))
    a_p = jnp.pad(active.astype(jnp.float32), ((0, 0), (0, wpad)))
    wtot, ftot = R + wpad, F + fpad

    delta, seen = pl.pallas_call(
        functools.partial(_kernel, wc=wc, fc=fc),
        grid=(n, rtot // br, ftot // fc, wtot // wc),
        in_specs=[
            pl.BlockSpec((1, wc, fc), lambda c, i, jf, jw: (c, jw, jf)),
            pl.BlockSpec((1, wc), lambda c, i, jf, jw: (c, jw)),
            pl.BlockSpec((1, br, k), lambda c, i, jf, jw: (c, i, 0)),
            pl.BlockSpec((1, br, k), lambda c, i, jf, jw: (c, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, br, fc), lambda c, i, jf, jw: (c, i, jf)),
            pl.BlockSpec((1, br), lambda c, i, jf, jw: (c, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, rtot, ftot), jnp.float32),
            jax.ShapeDtypeStruct((n, rtot), jnp.float32),
        ],
        interpret=interpret,
    )(w_p, a_p, src_p, freq_p)
    return delta[:, :rows, :F], seen[:, :rows]
