"""Shared kernel tiling constants + backend/interpret resolution (one source
of truth — the propagate kernels' chunking must stay in sync with each other,
and every Pallas entry point must resolve ``interpret`` the same way).

Backend resolution lives HERE (not ops.py) so the kernel modules themselves
can default ``interpret=None`` and auto-detect without importing the dispatch
layer (kernels stay leaf; ops re-exports these names for its callers).

``REPRO_FORCE_INTERPRET=1`` forces interpret-mode Pallas everywhere — CI's
forced-interpret lane uses it to exercise the real kernel code paths on
CPU-only runners (where production dispatch would otherwise route to the jnp
reference forms and the kernels would never run).
"""

from __future__ import annotations

import os

DEFAULT_BR = 256        # rows per block (sublane-dim multiple of 8)
DEFAULT_WC = 1 << 19    # weight-chunk length (f32 => 2 MB VMEM per chunk)
DEFAULT_FC = 128        # file-axis block for vector-payload ELL (lane dim)

FORCE_INTERPRET_ENV = "REPRO_FORCE_INTERPRET"


def round_up_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1).  Kept semantically identical to
    core.grammar.pow2_bucket (no cross-layer import: kernels stay leaf)."""
    return 1 << max(0, (max(int(x), 1) - 1).bit_length())


_BACKEND_CACHE: dict = {}


def on_tpu() -> bool:
    """Cached backend probe.  NOT an lru_cache: tests monkeypatch the jax
    backend, and a process-lifetime cache would leak the first answer
    across them — reset_backend_cache() makes the memo revocable."""
    if "on_tpu" not in _BACKEND_CACHE:
        try:
            import jax
            _BACKEND_CACHE["on_tpu"] = jax.devices()[0].platform == "tpu"
        except Exception:  # pragma: no cover
            _BACKEND_CACHE["on_tpu"] = False
    return _BACKEND_CACHE["on_tpu"]


def reset_backend_cache() -> None:
    """Drop the memoized backend probe (call after changing jax backends).

    Caveat: routing decisions are made at trace time, so programs that are
    already jit-compiled keep whatever branch they baked in — also call
    ``jax.clear_caches()`` if compiled routing must change too."""
    _BACKEND_CACHE.clear()


def force_interpret() -> bool:
    """True when the forced-interpret CI lane is active (re-read each call:
    tests toggle the env var at runtime)."""
    return os.environ.get(FORCE_INTERPRET_ENV, "") not in ("", "0")


def resolve_interpret(interpret: bool | None) -> bool:
    """The one ``interpret`` policy for every Pallas entry point.

    None => auto: real lowering on TPU, interpret mode elsewhere (and the
    forced-interpret lane pins True regardless of backend).  Explicit
    True/False is always honored — True is the validation-oracle mode,
    False asserts real lowering."""
    if interpret is None:
        return force_interpret() or not on_tpu()
    return bool(interpret)
