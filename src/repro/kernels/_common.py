"""Shared kernel tiling constants and helpers (one source of truth — the
propagate kernels' chunking must stay in sync with each other)."""

from __future__ import annotations

DEFAULT_BR = 256        # rows per block (sublane-dim multiple of 8)
DEFAULT_WC = 1 << 19    # weight-chunk length (f32 => 2 MB VMEM per chunk)


def round_up_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1).  Kept semantically identical to
    core.grammar.pow2_bucket (no cross-layer import: kernels stay leaf)."""
    return 1 << max(0, (max(int(x), 1) - 1).bit_length())
