"""Pallas TPU kernel: fused MULTI-round ELL frontier traversal (one launch).

The per-round engines (core/batch.py `_frontier_ell_impl`) run the paper's
dependent rule-propagation loop as `lax.while_loop` → kernel → XLA: every
round pays a full dispatch round-trip, the "structural tax" G-TADOC §IV-B
eliminates by keeping the loop resident on the device.  This kernel runs the
WHOLE frontier loop inside one `pallas_call`:

  grid = (corpus, round, row-block)

with the round dimension sequential (TPU grids execute in row-major order)
and the full frontier state — weights, cumulative in-edge counter, this
round's active mask, the ever-activated set — resident in VMEM scratch for
the lifetime of a corpus's grid slice.  A round is two phases:

  phase A (every row-block i): gather this block's delta/seen from the
    state vectors into full-width accumulators at ``[i*br, (i+1)*br)``;
  phase B (last row-block only): apply the frontier update to the whole
    state — ``ready = (cur_in + seen == in_deg) & ~ever`` — bump the
    round counter, and recompute the convergence flag.

Convergence lives in SMEM as a done flag + round counter: once no rule
becomes ready, every remaining round's body is skipped via `pl.when`, so
the static round bound costs only empty grid steps.  The bound itself is
exact: callers pass ``max_rounds = num_levels`` (the DAG's longest-path
depth, core/grammar.py), which is precisely the number of rounds the
while_loop form executes — rules at level L activate in round L+1.

State residency: the six scratch vectors are [1, R_pad] float32 each, so a
corpus needs ~24 bytes/rule of VMEM — ops.py gates the fused path at
``ELL_FUSED_MAX_RULES`` and falls back to the per-round streaming kernel
above that (weight-chunk streaming cannot work here: a round reads weights
every OTHER block just wrote, so the state must be whole).

Bit-exactness: identical adds in identical order to the per-round path —
all counts are integers < 2^24, exact in float32; converged extra rounds
add literal 0.0, a no-op on non-negative values.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._common import DEFAULT_BR, resolve_interpret


def _kernel(w0_ref, ind_ref, src_ref, freq_ref, out_ref, rounds_ref,
            wgt, cur, mask, ever, delta, seen, done_ref, cnt_ref,
            *, br: int, nb: int):
    t = pl.program_id(1)                 # round (sequential middle dim)
    i = pl.program_id(2)                 # row-block (innermost)
    last_round = pl.num_programs(1) - 1

    @pl.when((t == 0) & (i == 0))
    def _init():
        # Seed state for a fresh corpus: weights = caller's W0, nothing
        # consumed yet, frontier = the zero-in-degree rules (the root; padded
        # slots have in_deg == 0 but also no out-edges, so they stay inert).
        m0 = (ind_ref[...] == 0.0).astype(jnp.float32)
        wgt[...] = w0_ref[...]
        cur[...] = jnp.zeros_like(cur)
        mask[...] = m0
        ever[...] = m0
        done_ref[0, 0] = jnp.where(jnp.any(m0 > 0), 0, 1).astype(jnp.int32)
        cnt_ref[0, 0] = 0

    @pl.when(done_ref[0, 0] == 0)
    def _round():
        @pl.when(i == 0)
        def _zero():
            delta[...] = jnp.zeros_like(delta)
            seen[...] = jnp.zeros_like(seen)

        # Phase A: this row-block's gather + row-sum into the accumulators.
        src = src_ref[0]                 # [br, K]
        freq = freq_ref[0]               # [br, K]
        idx = src.reshape(-1)
        gw = jnp.take(wgt[0, :], idx, axis=0).reshape(src.shape)
        gm = jnp.take(mask[0, :], idx, axis=0).reshape(src.shape)
        delta[0, pl.ds(i * br, br)] = (freq * gw * gm).sum(axis=-1)
        seen[0, pl.ds(i * br, br)] = jnp.where(freq > 0, gm, 0.0).sum(axis=-1)

        # Phase B: whole-state frontier update once every block contributed.
        @pl.when(i == nb - 1)
        def _apply():
            w_new = wgt[...] + delta[...]
            c_new = cur[...] + seen[...]
            ready = ((c_new == ind_ref[...]) & (ever[...] == 0.0))
            ready = ready.astype(jnp.float32)
            wgt[...] = w_new
            cur[...] = c_new
            mask[...] = ready
            ever[...] = ever[...] + ready
            cnt_ref[0, 0] = cnt_ref[0, 0] + 1
            done_ref[0, 0] = jnp.where(jnp.any(ready > 0), 0, 1)

    @pl.when((t == last_round) & (i == nb - 1))
    def _out():
        out_ref[...] = wgt[...]
        rounds_ref[0, 0] = cnt_ref[0, 0]


def ell_frontier_fused_pallas(weights0: jnp.ndarray, in_deg: jnp.ndarray,
                              src: jnp.ndarray, freq: jnp.ndarray,
                              max_rounds: int, br: int = DEFAULT_BR,
                              interpret: bool | None = None):
    """Run the whole frontier loop device-resident over the [N, R, K] plan.

    weights0/in_deg: [N, R] float32 (initial weights — 1.0 at the root for
    the scalar traversal — and per-rule in-degrees); src/freq: [N, R, K]
    ELL plan (row == destination rule).  ``max_rounds`` must be >= the
    number of frontier rounds (num_levels is exact).  Returns
    ``(weights [N, R] float32, rounds [N] int32)`` — rounds is the count of
    non-converged rounds each corpus actually executed.
    ``interpret=None`` auto-resolves outside jit (_common.resolve_interpret).
    """
    return _ell_frontier_fused_jit(weights0, in_deg, src, freq,
                                   int(max_rounds), br,
                                   resolve_interpret(interpret))


@functools.partial(
    jax.jit, static_argnames=("max_rounds", "br", "interpret"))
def _ell_frontier_fused_jit(weights0, in_deg, src, freq,
                            max_rounds: int, br: int, interpret: bool):
    n, rows, k = src.shape
    pad = (-rows) % br
    src_p = jnp.pad(src.astype(jnp.int32), ((0, 0), (0, pad), (0, 0)))
    freq_p = jnp.pad(freq.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    rtot = rows + pad
    # Padded rows must stay inert: give them in_deg = -1 so they can never
    # satisfy ``cur_in == in_deg`` (their src=0/freq=0 rows contribute no
    # weight, but in_deg == 0 would put them on the initial frontier).
    w0_p = jnp.pad(weights0.astype(jnp.float32), ((0, 0), (0, pad)))
    ind_p = jnp.pad(in_deg.astype(jnp.float32), ((0, 0), (0, pad)),
                    constant_values=-1.0)
    nb = rtot // br
    rounds = max(int(max_rounds), 1)

    out, cnt = pl.pallas_call(
        functools.partial(_kernel, br=br, nb=nb),
        grid=(n, rounds, nb),
        in_specs=[
            pl.BlockSpec((1, rtot), lambda c, t, i: (c, 0)),   # W0
            pl.BlockSpec((1, rtot), lambda c, t, i: (c, 0)),   # in_deg
            pl.BlockSpec((1, br, k), lambda c, t, i: (c, i, 0)),
            pl.BlockSpec((1, br, k), lambda c, t, i: (c, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, rtot), lambda c, t, i: (c, 0)),
            pl.BlockSpec((1, 1), lambda c, t, i: (c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, rtot), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, rtot), jnp.float32),    # weights
            pltpu.VMEM((1, rtot), jnp.float32),    # cumulative in-counter
            pltpu.VMEM((1, rtot), jnp.float32),    # this round's mask
            pltpu.VMEM((1, rtot), jnp.float32),    # ever-activated
            pltpu.VMEM((1, rtot), jnp.float32),    # delta accumulator
            pltpu.VMEM((1, rtot), jnp.float32),    # seen accumulator
            pltpu.SMEM((1, 1), jnp.int32),         # done flag
            pltpu.SMEM((1, 1), jnp.int32),         # round counter
        ],
        interpret=interpret,
    )(w0_p, ind_p, src_p, freq_p)
    return out[:, :rows], cnt[:, 0]
