"""Pallas TPU kernel: weighted histogram (the paper's global result update).

G-TADOC resolves thousands of threads atomically updating one global hash
table with a lock buffer + atomicAdd (§IV-C, Fig. 5).  TPUs have no atomics;
the idiomatic replacement (DESIGN.md §2) turns the scatter into dense MXU
work: for a tile of (id, value) pairs and a block of histogram bins, build
the one-hot matrix ``ids == bin`` and accumulate ``vals @ onehot`` on the
MXU.  Conflict-free and deterministic by construction — every (tile, bin
block) contribution is a 128-aligned matmul.

Layout:
  ids   [NT, TN] int32   (flattened input padded/reshaped by ops.py)
  vals  [NT, TN] float32
  out   [1, V]   float32 (V padded to a multiple of BV)

Grid = (V // BV, NT): for a fixed bin block i we sweep all input tiles j,
accumulating into the same VMEM-resident output block (revisiting grid
dimension; out block depends only on i).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._common import resolve_interpret


DEFAULT_TN = 512   # input tile (multiple of 128 for the MXU contraction dim)
DEFAULT_BV = 512   # bin block   (multiple of 128, lane dim)


def _kernel(ids_ref, vals_ref, out_ref, *, bv: int):
    j = pl.program_id(1)                       # input-tile index

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    i = pl.program_id(0)                       # bin-block index
    ids = ids_ref[0, :]                        # [TN]
    vals = vals_ref[0, :]                      # [TN]
    cols = i * bv + jax.lax.broadcasted_iota(jnp.int32, (1, bv), 1)[0]
    onehot = (ids[:, None] == cols[None, :]).astype(jnp.float32)   # [TN, BV]
    # [1, TN] @ [TN, BV] -> [1, BV] on the MXU
    out_ref[...] += jnp.dot(vals[None, :], onehot,
                            preferred_element_type=jnp.float32)


def weighted_bincount_pallas(ids: jnp.ndarray, vals: jnp.ndarray, nbins: int,
                             tn: int = DEFAULT_TN, bv: int = DEFAULT_BV,
                             interpret: bool | None = None) -> jnp.ndarray:
    """out[b] = sum(vals[ids == b]) for b in [0, nbins).

    ids outside [0, nbins) are ignored (ops.py uses id == -1 as padding).
    ``interpret=None`` auto-resolves outside jit (_common.resolve_interpret).
    """
    return _weighted_bincount_jit(ids, vals, nbins, tn, bv,
                                  resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("nbins", "tn", "bv", "interpret"))
def _weighted_bincount_jit(ids, vals, nbins: int, tn: int, bv: int,
                           interpret: bool) -> jnp.ndarray:
    n = ids.shape[0]
    n_pad = (-n) % tn
    ids_p = jnp.pad(ids.astype(jnp.int32), (0, n_pad), constant_values=-1)
    vals_p = jnp.pad(vals.astype(jnp.float32), (0, n_pad))
    nt = ids_p.shape[0] // tn
    ids2 = ids_p.reshape(nt, tn)
    vals2 = vals_p.reshape(nt, tn)
    v_pad = (-nbins) % bv
    vtot = nbins + v_pad

    out = pl.pallas_call(
        functools.partial(_kernel, bv=bv),
        grid=(vtot // bv, nt),
        in_specs=[
            pl.BlockSpec((1, tn), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tn), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bv), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, vtot), jnp.float32),
        interpret=interpret,
    )(ids2, vals2)
    return out[0, :nbins]
