"""Pallas TPU kernel: fused batched ELL propagation (one round, one launch).

DESIGN — the dense ELL *edge plan* (see also core/batch.py): the batched
engine lays every corpus's in-edges out as ``src/freq [N, R, K]`` where row
``r`` of corpus ``n`` lists the parents of rule ``r`` (K = max in-degree
across the batch, bucketed to a power of two; padding is src=0 / freq=0).
Because the row index IS the destination rule, one masked round of the
paper's ``topDownKernel`` collapses to a pure gather + row-sum with no
scatter at all:

  delta[n, r] = sum_k freq[n, r, k] * weight[n, src[n, r, k]]
                                    * active[n, src[n, r, k]]
  seen[n, r]  = sum_k [freq[n, r, k] > 0] * active[n, src[n, r, k]]

``delta`` is the weight update and ``seen`` the per-rule count of in-edges
that became visible this round (the frontier bookkeeping) — both emitted by
the SAME launch, so the gather of ``src`` is paid once per round instead of
twice (the segment_sum path runs two scatters per round).

Grid = (corpus, row-block, weight-chunk): the weight/active vectors stream
through VMEM in ``wc``-length chunks exactly like propagate.py (out blocks
depend only on (n, i); chunk j is the innermost revisiting dimension with
init at j == 0), so the VMEM footprint is fixed and rule count holds no
cliff.  Gathers lower via Mosaic dynamic-gather; CPU validation
runs through ``interpret=True`` (ops.py routes CPU *production* traffic to
the jnp form of the same plan — interpret-mode emulation is pure overhead).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._common import DEFAULT_BR, DEFAULT_WC, resolve_interpret, round_up_pow2


def _kernel(w_ref, a_ref, src_ref, freq_ref, delta_ref, seen_ref, *, wc: int):
    j = pl.program_id(2)                 # weight-chunk index (innermost)

    @pl.when(j == 0)
    def _init():
        delta_ref[...] = jnp.zeros_like(delta_ref)
        seen_ref[...] = jnp.zeros_like(seen_ref)

    base = j * wc
    w = w_ref[0, :]                      # [wc] weight chunk
    a = a_ref[0, :]                      # [wc] active-mask chunk (0/1 float)
    src = src_ref[0]                     # [BR, K]
    freq = freq_ref[0]                   # [BR, K] float32
    loc = src - base
    in_chunk = (loc >= 0) & (loc < wc)
    idx = jnp.clip(loc, 0, wc - 1).reshape(-1)
    gw = jnp.take(w, idx, axis=0).reshape(src.shape)
    gact = jnp.take(a, idx, axis=0).reshape(src.shape)
    gact = jnp.where(in_chunk, gact, 0.0)
    delta_ref[...] += (freq * gw * gact).sum(axis=-1)[None, :]
    seen_ref[...] += jnp.where(freq > 0, gact, 0.0).sum(axis=-1)[None, :]


def ell_propagate_batched_pallas(weights: jnp.ndarray, active: jnp.ndarray,
                                 src: jnp.ndarray, freq: jnp.ndarray,
                                 br: int = DEFAULT_BR, wc: int = DEFAULT_WC,
                                 interpret: bool | None = None):
    """(delta, seen) of one fused propagation round over the [N, R, K] plan.

    weights/active: [N, R] float32; src/freq: [N, rows, K] (rows == R for
    the per-rule plan, but any row count works).  Returns two [N, rows]
    float32 arrays.

    ``interpret=None`` auto-resolves (real lowering on TPU, interpret mode
    elsewhere).  Resolution happens HERE, outside jit, so a mutable backend
    probe never gets frozen into a compile-cache entry.
    """
    return _ell_propagate_batched_jit(weights, active, src, freq, br, wc,
                                      resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("br", "wc", "interpret"))
def _ell_propagate_batched_jit(weights, active, src, freq,
                               br: int, wc: int, interpret: bool):
    n, rows, k = src.shape
    pad = (-rows) % br
    src_p = jnp.pad(src.astype(jnp.int32), ((0, 0), (0, pad), (0, 0)))
    freq_p = jnp.pad(freq.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    rtot = rows + pad
    R = weights.shape[1]
    wc = min(wc, round_up_pow2(R))
    wpad = (-R) % wc
    w_p = jnp.pad(weights.astype(jnp.float32), ((0, 0), (0, wpad)))
    a_p = jnp.pad(active.astype(jnp.float32), ((0, 0), (0, wpad)))
    wtot = R + wpad

    delta, seen = pl.pallas_call(
        functools.partial(_kernel, wc=wc),
        grid=(n, rtot // br, wtot // wc),
        in_specs=[
            pl.BlockSpec((1, wc), lambda c, i, j: (c, j)),    # weight chunk
            pl.BlockSpec((1, wc), lambda c, i, j: (c, j)),    # active chunk
            pl.BlockSpec((1, br, k), lambda c, i, j: (c, i, 0)),
            pl.BlockSpec((1, br, k), lambda c, i, j: (c, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, br), lambda c, i, j: (c, i)),
            pl.BlockSpec((1, br), lambda c, i, j: (c, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, rtot), jnp.float32),
            jax.ShapeDtypeStruct((n, rtot), jnp.float32),
        ],
        interpret=interpret,
    )(w_p, a_p, src_p, freq_p)
    return delta[:, :rows], seen[:, :rows]
