"""Pure-jnp oracles for the Pallas kernels (the allclose references)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def weighted_bincount_ref(ids: jnp.ndarray, vals: jnp.ndarray,
                          nbins: int) -> jnp.ndarray:
    """out[b] = sum(vals[ids == b]); ids outside [0, nbins) ignored."""
    ids = ids.astype(jnp.int32)
    valid = (ids >= 0) & (ids < nbins)
    safe = jnp.where(valid, ids, 0)
    v = jnp.where(valid, vals.astype(jnp.float32), 0.0)
    return jax.ops.segment_sum(v, safe, num_segments=nbins)


def ell_row_sums_ref(weights: jnp.ndarray, src: jnp.ndarray,
                     freq: jnp.ndarray) -> jnp.ndarray:
    """row_sums[r] = sum_k freq[r, k] * weights[src[r, k]]."""
    return (weights.astype(jnp.float32)[src] *
            freq.astype(jnp.float32)).sum(axis=1)


def ell_propagate_batched_ref(weights: jnp.ndarray, active: jnp.ndarray,
                              src: jnp.ndarray, freq: jnp.ndarray):
    """(delta, seen) of one fused round over the [N, R, K] edge plan.

    delta[n, r] = sum_k freq[n,r,k] * weights[n, src[n,r,k]]
                                    * active[n, src[n,r,k]]
    seen[n, r]  = sum_k [freq[n,r,k] > 0] * active[n, src[n,r,k]]

    This gather form doubles as the fast CPU production path: it touches
    each plan entry once with no scatter (the segment_sum path runs two
    scatters per round), which is also why the ELL plan wins on CPU.
    """
    n = src.shape[0]
    flat = src.reshape(n, -1).astype(jnp.int32)
    w = weights.astype(jnp.float32)
    a = active.astype(jnp.float32)
    f = freq.astype(jnp.float32)
    gw = jnp.take_along_axis(w, flat, axis=1).reshape(src.shape)
    ga = jnp.take_along_axis(a, flat, axis=1).reshape(src.shape)
    delta = (f * gw * ga).sum(axis=-1)
    seen = jnp.where(f > 0, ga, 0.0).sum(axis=-1)
    return delta, seen


def ell_propagate_vector_ref(W: jnp.ndarray, active: jnp.ndarray,
                             src: jnp.ndarray, freq: jnp.ndarray):
    """(delta, seen) of one vector-payload round over the [N, R, K] plan.

    delta[n, r, f] = sum_k freq[n,r,k] * W[n, src[n,r,k], f]
                                       * active[n, src[n,r,k]]
    seen[n, r]     = sum_k [freq[n,r,k] > 0] * active[n, src[n,r,k]]

    Gather form, the CPU production path for the per-file ELL traversals
    (propagate_vector.py is the TPU lowering of the same plan).  The
    gathered intermediate is [N, rows*K, F] — ops.py gates plan sizes so
    this stays within the dense-plan budget.
    """
    n, rows, k = src.shape
    flat = src.reshape(n, -1).astype(jnp.int32)
    w = W.astype(jnp.float32)
    a = active.astype(jnp.float32)
    f = freq.astype(jnp.float32)
    gw = jnp.take_along_axis(w, flat[:, :, None], axis=1)
    gw = gw.reshape(src.shape + (W.shape[-1],))            # [N, rows, K, F]
    ga = jnp.take_along_axis(a, flat, axis=1).reshape(src.shape)
    delta = ((f * ga)[..., None] * gw).sum(axis=2)         # [N, rows, F]
    seen = jnp.where(f > 0, ga, 0.0).sum(axis=-1)
    return delta, seen


def ell_frontier_fused_ref(weights0: jnp.ndarray, in_deg: jnp.ndarray,
                           src: jnp.ndarray, freq: jnp.ndarray,
                           max_rounds: int, with_rounds: bool = False):
    """Whole frontier loop over the ELL plan as ONE jitted fori_loop.

    The jnp production form of propagate_fused.py: a static ``max_rounds``
    trip count (num_levels is exact — see the kernel docstring) with no
    per-round convergence test, so the per-round host round-trip AND the
    while_loop's cond evaluation both disappear.  Converged extra rounds
    are exact no-ops (delta == 0.0 and ``x + 0.0 == x`` on non-negative
    float32 counts).  Returns weights [N, R] — or ``(weights, rounds)``
    with the per-corpus non-converged round count when ``with_rounds``
    (rounds costs a per-round reduction, so production leaves it off).
    """
    return _ell_frontier_fused_ref_jit(weights0, in_deg, src, freq,
                                       int(max_rounds), bool(with_rounds))


@functools.partial(jax.jit, static_argnames=("max_rounds", "with_rounds"))
def _ell_frontier_fused_ref_jit(weights0, in_deg, src, freq,
                                max_rounds: int, with_rounds: bool):
    n = src.shape[0]
    w0 = weights0.astype(jnp.float32)
    ind = in_deg.astype(jnp.int32)
    mask0 = (ind == 0).astype(jnp.float32)
    rounds0 = jnp.zeros(n, jnp.int32)

    def body(_, state):
        w, cur, mask, ever, rounds = state
        if with_rounds:
            rounds = rounds + jnp.any(mask > 0, axis=1).astype(jnp.int32)
        delta, seen = ell_propagate_batched_ref(w, mask, src, freq)
        w = w + delta
        cur = cur + seen.astype(jnp.int32)
        ready = ((cur == ind) & (ever == 0.0)).astype(jnp.float32)
        return w, cur, ready, ever + ready, rounds

    state = (w0, jnp.zeros_like(ind), mask0, mask0, rounds0)
    w, _, _, _, rounds = jax.lax.fori_loop(0, max(max_rounds, 1), body, state)
    return (w, rounds) if with_rounds else w
