"""Pure-jnp oracles for the Pallas kernels (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_bincount_ref(ids: jnp.ndarray, vals: jnp.ndarray,
                          nbins: int) -> jnp.ndarray:
    """out[b] = sum(vals[ids == b]); ids outside [0, nbins) ignored."""
    ids = ids.astype(jnp.int32)
    valid = (ids >= 0) & (ids < nbins)
    safe = jnp.where(valid, ids, 0)
    v = jnp.where(valid, vals.astype(jnp.float32), 0.0)
    return jax.ops.segment_sum(v, safe, num_segments=nbins)


def ell_row_sums_ref(weights: jnp.ndarray, src: jnp.ndarray,
                     freq: jnp.ndarray) -> jnp.ndarray:
    """row_sums[r] = sum_k freq[r, k] * weights[src[r, k]]."""
    return (weights.astype(jnp.float32)[src] *
            freq.astype(jnp.float32)).sum(axis=1)


def ell_propagate_batched_ref(weights: jnp.ndarray, active: jnp.ndarray,
                              src: jnp.ndarray, freq: jnp.ndarray):
    """(delta, seen) of one fused round over the [N, R, K] edge plan.

    delta[n, r] = sum_k freq[n,r,k] * weights[n, src[n,r,k]]
                                    * active[n, src[n,r,k]]
    seen[n, r]  = sum_k [freq[n,r,k] > 0] * active[n, src[n,r,k]]

    This gather form doubles as the fast CPU production path: it touches
    each plan entry once with no scatter (the segment_sum path runs two
    scatters per round), which is also why the ELL plan wins on CPU.
    """
    n = src.shape[0]
    flat = src.reshape(n, -1).astype(jnp.int32)
    w = weights.astype(jnp.float32)
    a = active.astype(jnp.float32)
    f = freq.astype(jnp.float32)
    gw = jnp.take_along_axis(w, flat, axis=1).reshape(src.shape)
    ga = jnp.take_along_axis(a, flat, axis=1).reshape(src.shape)
    delta = (f * gw * ga).sum(axis=-1)
    seen = jnp.where(f > 0, ga, 0.0).sum(axis=-1)
    return delta, seen
