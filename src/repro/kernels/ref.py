"""Pure-jnp oracles for the Pallas kernels (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_bincount_ref(ids: jnp.ndarray, vals: jnp.ndarray,
                          nbins: int) -> jnp.ndarray:
    """out[b] = sum(vals[ids == b]); ids outside [0, nbins) ignored."""
    ids = ids.astype(jnp.int32)
    valid = (ids >= 0) & (ids < nbins)
    safe = jnp.where(valid, ids, 0)
    v = jnp.where(valid, vals.astype(jnp.float32), 0.0)
    return jax.ops.segment_sum(v, safe, num_segments=nbins)


def ell_row_sums_ref(weights: jnp.ndarray, src: jnp.ndarray,
                     freq: jnp.ndarray) -> jnp.ndarray:
    """row_sums[r] = sum_k freq[r, k] * weights[src[r, k]]."""
    return (weights.astype(jnp.float32)[src] *
            freq.astype(jnp.float32)).sum(axis=1)
