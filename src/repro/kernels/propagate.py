"""Pallas TPU kernel: ELL gather row sums (single-corpus form).

The generic building block

  row_sums[row] = sum_k freq[row, k] * weight[src[row, k]]

over a uniform-width ELL layout (padding: src=0, freq=0).  Masking is
folded into the input: callers pass ``weight * mask`` so inactive sources
contribute zero — the mask never enters the kernel.  The traversal engines
run the fused per-rule variant (propagate_batched.py, where the row index
IS the destination rule); this kernel remains the scalar row-sums surface.

DESIGN — blocked weight streaming: the kernel is tiled over a second grid
dimension of weight *chunks*, so the gather ``weight[src]`` never needs a
VMEM-resident copy of the full weight vector (which would cap the grammar
at a few million rules): grid step (i, j) gathers block i's rows from weight chunk
``[j*wc, (j+1)*wc)`` only, masking out-of-chunk sources to zero, and
accumulates into the same output block (revisiting grid dimension — the
out BlockSpec depends only on i, with init at j == 0).  Every source index
falls in exactly one chunk, so the chunk sweep partitions the row sum and
arbitrarily large weight vectors stream through a fixed VMEM footprint.
Gathers from VMEM lower via Mosaic's dynamic-gather support; we validate
through ``interpret=True`` on CPU per the assignment.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._common import DEFAULT_BR, DEFAULT_WC, resolve_interpret, round_up_pow2


def _kernel(w_ref, src_ref, freq_ref, out_ref, *, wc: int):
    j = pl.program_id(1)                 # weight-chunk index (innermost)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    base = j * wc
    w = w_ref[0, :]                      # [wc] weight chunk (VMEM)
    src = src_ref[...]                   # [BR, W]
    freq = freq_ref[...]                 # [BR, W] float32
    loc = src - base
    in_chunk = (loc >= 0) & (loc < wc)
    idx = jnp.clip(loc, 0, wc - 1).reshape(-1)
    gathered = jnp.take(w, idx, axis=0).reshape(src.shape)
    gated = jnp.where(in_chunk, freq, 0.0)
    out_ref[...] += (gathered * gated).sum(axis=1, keepdims=True)  # [BR, 1]


def ell_row_sums_pallas(weights: jnp.ndarray, src: jnp.ndarray,
                        freq: jnp.ndarray, br: int = DEFAULT_BR,
                        wc: int = DEFAULT_WC,
                        interpret: bool | None = None) -> jnp.ndarray:
    """row_sums[r] = sum_k freq[r, k] * weights[src[r, k]].

    src/freq: [rows, W] ELL arrays (padding: src=0, freq=0).  ``wc`` is the
    VMEM weight-chunk length; weight vectors of any size are streamed
    through it (small vectors collapse to a single chunk).
    ``interpret=None`` auto-resolves outside jit (_common.resolve_interpret).
    """
    return _ell_row_sums_jit(weights, src, freq, br, wc,
                             resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("br", "wc", "interpret"))
def _ell_row_sums_jit(weights, src, freq, br: int, wc: int, interpret: bool):
    rows, w = src.shape
    pad = (-rows) % br
    src_p = jnp.pad(src.astype(jnp.int32), ((0, pad), (0, 0)))
    freq_p = jnp.pad(freq.astype(jnp.float32), ((0, pad), (0, 0)))
    rtot = rows + pad
    R = weights.shape[0]
    wc = min(wc, round_up_pow2(R))
    wpad = (-R) % wc
    wvec = jnp.pad(weights.astype(jnp.float32), (0, wpad))[None, :]  # [1, Wt]
    wtot = R + wpad

    out = pl.pallas_call(
        functools.partial(_kernel, wc=wc),
        grid=(rtot // br, wtot // wc),
        in_specs=[
            pl.BlockSpec((1, wc), lambda i, j: (0, j)),   # weight chunk
            pl.BlockSpec((br, w), lambda i, j: (i, 0)),
            pl.BlockSpec((br, w), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rtot, 1), jnp.float32),
        interpret=interpret,
    )(wvec, src_p, freq_p)
    return out[:rows, 0]
