"""Pallas TPU kernel: ELL frontier propagation (the traversal hot spot).

One masked round of the paper's ``topDownKernel`` (Algorithm 1) is, per
in-edge of each rule, ``delta[child] += freq * weight[parent]`` for parents
active this round.  grammar.py lays in-edges out in ELL format — uniform
width rows, oversized rules split across rows (the paper's 16x thread-group
threshold becomes row splitting, DESIGN.md §2) — so a round is:

  row_sums[row] = sum_k freq[row, k] * weight[src[row, k]]      (this kernel)
  delta         = segment_sum(row_sums, dst)                    (ops.py)

Masking is folded into the input: the wrapper passes ``weight * mask`` so
inactive parents contribute zero — the mask never enters the kernel.

The gather ``weight[src]`` runs from a VMEM-resident copy of the full weight
vector (BlockSpec maps the whole vector into every grid step; the grammar's
rule count must fit VMEM — ~4M rules at f32.  Beyond that the wrapper falls
back to the jnp path.)  Gathers from VMEM lower via Mosaic's dynamic-gather
support; we validate through ``interpret=True`` on CPU per the assignment.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BR = 256   # rows per block (sublane-dim multiple of 8)


def _kernel(w_ref, src_ref, freq_ref, out_ref):
    w = w_ref[0, :]                      # [R] full weight vector (VMEM)
    src = src_ref[...]                   # [BR, W]
    freq = freq_ref[...]                 # [BR, W] float32
    gathered = jnp.take(w, src.reshape(-1), axis=0).reshape(src.shape)
    out_ref[...] = (gathered * freq).sum(axis=1, keepdims=True)  # [BR, 1]


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def ell_row_sums_pallas(weights: jnp.ndarray, src: jnp.ndarray,
                        freq: jnp.ndarray, br: int = DEFAULT_BR,
                        interpret: bool = True) -> jnp.ndarray:
    """row_sums[r] = sum_k freq[r, k] * weights[src[r, k]].

    src/freq: [rows, W] ELL arrays (padding: src=0, freq=0).
    """
    rows, w = src.shape
    pad = (-rows) % br
    src_p = jnp.pad(src.astype(jnp.int32), ((0, pad), (0, 0)))
    freq_p = jnp.pad(freq.astype(jnp.float32), ((0, pad), (0, 0)))
    rtot = rows + pad
    wvec = weights.astype(jnp.float32)[None, :]      # [1, R]

    out = pl.pallas_call(
        _kernel,
        grid=(rtot // br,),
        in_specs=[
            pl.BlockSpec((1, wvec.shape[1]), lambda i: (0, 0)),  # full weights
            pl.BlockSpec((br, w), lambda i: (i, 0)),
            pl.BlockSpec((br, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rtot, 1), jnp.float32),
        interpret=interpret,
    )(wvec, src_p, freq_p)
    return out[:rows, 0]
