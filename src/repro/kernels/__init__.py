# Pallas TPU kernels for the paper's compute hot spots:
#   bincount.py          — global result reduction (replaces §IV-C atomic
#                          hash tables)
#   propagate.py         — ELL row sums with blocked weight streaming
#                          (replaces §IV-B per-thread rule walk)
#   propagate_batched.py — fused batched ELL propagation round (delta+seen
#                          in one launch over the [N, R, K] edge plan)
# ops.py: jit'd wrappers + ELL-vs-segment_sum dispatch (auto interpret on
# CPU); ref.py: pure-jnp oracles (and the fast CPU production path for the
# batched ELL plan).
from . import ops, ref  # noqa: F401
