# Pallas TPU kernels for the paper's two compute hot spots:
#   bincount.py  — global result reduction (replaces §IV-C atomic hash tables)
#   propagate.py — ELL frontier propagation (replaces §IV-B per-thread rule walk)
# ops.py: jit'd wrappers (auto interpret on CPU); ref.py: pure-jnp oracles.
from . import ops, ref  # noqa: F401
