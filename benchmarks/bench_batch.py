"""Batched multi-corpus engine vs sequential per-corpus loop.

Emits ``batch/<app>/<mode>`` rows (us per full sweep over the batch) plus a
``batch/<app>/speedup`` row.  The sequential mode is the pre-batching
serving story: one jitted call per corpus (each with its own shapes, its
own dispatch).  The batched mode packs all corpora into one
:class:`GrammarBatch` and runs ONE program.  Steady-state timing (both
modes fully warmed/compiled before measurement).

Also emits ``batch/traversal/{segment_sum,ell,ell_speedup}``: the batched
frontier rounds on the COO segment_sum path vs the dense ELL edge plan
(scatter-free gather form — core/batch.py DESIGN note); and
``batch/traversal/{fused,fused_speedup}``: the fused multi-round traversal
(kernels/propagate_fused.py — the whole frontier loop in ONE dispatch)
against the per-round while_loop ELL path it replaces.  The fused floor
(docs/benchmarks.md) binds on this row: one dispatch must never lose to
num_levels dispatches.

``autotune/*`` rows run the kernels/autotune.py block-size sweeps on the
pack's real ELL plan (the jnp reference form is itself a candidate, so the
sweep also answers ref-vs-kernel routing), record each kind's winner and
its winner-vs-default ratio, seed an ``ell_vs_seg`` routing entry from the
segment_sum/ELL timings above, and persist the tuned table to
AUTOTUNE_cache.json (CI uploads it as an artifact).

``search/<scheme>/{sequential,batched,speedup}`` rows time compressed
BM25/TF-IDF top-k ranking (repro/search): one jitted per-corpus scoring
call per corpus (prebuilt SearchIndex each) vs ONE batched program over
the whole pack — the retrieval analogue of the batched-vs-sequential
analytics story (index builds excluded; both sides warmed).

``query/<op>/{sequential,batched,speedup}`` rows time the composable
query operators (repro/query): an AND/OR predicate filter, a term-set
sum aggregation and a sequence-plan phrase count, each as one
single-corpus engine call per corpus vs ONE jitted program over the
pack (whose per-file stats and sequence plans are memoized on the pack,
like recurring serving traffic).

``shard/*`` rows time the device-sharded pack (distributed/shard_batch.py)
against the single-device pack on the same corpora: ``shard/<app>/single``
vs ``shard/<app>/sharded`` plus a ``speedup`` row, and the ``devices``
field records how many devices the mesh actually spanned (1 = no mesh
visible, rows then measure the transparent fallback and speedup ~1).  Run
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise a
real 8-way mesh on CPU — CI's multidevice lane does.

``ingest/*`` rows time the streaming ingestion tier (data/store.py
``append_files``): appending a tail of files onto an existing corpus's
live Sequitur state vs recompressing the whole concatenated file list
from scratch.  ``ingest/append`` and ``ingest/rebuild`` are seconds per
ingest of the same tail, ``ingest/speedup`` is rebuild/append (the floor
in docs/benchmarks.md binds on it: incremental must beat recompression),
and ``ingest/append_tokens_per_s`` is the tail-token throughput of the
incremental path.  Append mutates the corpus, so each repetition clones a
fresh base corpus outside the timed region.

``run`` returns the full timing dict; ``benchmarks.run`` serializes it to
BENCH_batch.json so CI tracks the perf trajectory across PRs.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (GrammarArrays, GrammarBatch, batched_term_vector,
                        batched_top_down_weights, batched_word_count,
                        compress_files, flatten, term_vector, word_count)
from repro.distributed.shard_batch import corpus_mesh, mesh_size, shard_batch
from repro.query import (agg_corpus, batched_agg, batched_filter,
                         batched_phrase, filter_corpus, phrase_corpus)
from repro.search import (batched_search, build_search_index,
                          search_index_topk)

from repro.data import CompressedCorpus

from .common import emit, timeit


def make_ragged_corpora(n: int, seed: int = 7) -> List[GrammarArrays]:
    """n small corpora of deliberately different R/V/F (ragged batch)."""
    rng = np.random.default_rng(seed)
    gas = []
    for i in range(n):
        vocab = int(rng.integers(40, 400))
        n_files = int(rng.integers(1, 7))
        size = int(rng.integers(150, 900))
        phrase = rng.integers(0, vocab, int(rng.integers(4, 9)))
        files = []
        for _ in range(n_files):
            parts, total = [], 0
            while total < size:
                p = (phrase if rng.random() < 0.5
                     else rng.integers(0, vocab, int(rng.integers(3, 12))))
                parts.append(p)
                total += len(p)
            files.append(np.concatenate(parts)[:size])
        g, nf = compress_files(files, vocab)
        gas.append(flatten(g, vocab, nf))
    return gas


def _autotune_rows(gb: GrammarBatch, n: int, t_seg: float, t_ell: float,
                   smoke: bool) -> dict:
    """Run the block-size sweeps on the pack's real ELL plan and emit
    ``autotune/<kind>/{winner,winner_speedup}`` rows.

    Candidate grids shrink at smoke scale (every candidate is a fresh
    compile; the smoke lane only checks the harness executes end to end).
    The ``ell_vs_seg`` routing entry is seeded from the segment_sum/ELL
    timings the traversal section already measured — both engine paths
    actually timed on this machine — and the whole table persists to the
    cache file (AUTOTUNE_cache.json unless REPRO_AUTOTUNE_CACHE points
    elsewhere)."""
    from repro.kernels import autotune

    src, freq, _, num_levels = gb.ell_plan()
    in_deg = gb.in_deg
    w0 = jnp.zeros(in_deg.shape, jnp.float32).at[:, 0].set(1.0)
    active0 = (in_deg == 0).astype(jnp.float32)
    Wv = jnp.zeros((*in_deg.shape, gb.F_pad),
                   jnp.float32).at[:, 0, 0].set(1.0)
    if smoke:
        kw = dict(repeat=1, warmup=0)
        entries = {
            "ell_batched": autotune.tune_ell_batched(
                w0, active0, src, freq, brs=(128,), wcs=(1 << 16,), **kw),
            "ell_fused": autotune.tune_ell_fused(
                w0, in_deg.astype(jnp.float32), src, freq, num_levels,
                brs=(128,), **kw),
            "ell_vector": autotune.tune_ell_vector(
                Wv, active0, src, freq, brs=(32,), fcs=(64,), **kw),
        }
    else:
        entries = {
            "ell_batched": autotune.tune_ell_batched(w0, active0, src, freq),
            "ell_fused": autotune.tune_ell_fused(
                w0, in_deg.astype(jnp.float32), src, freq, num_levels),
            "ell_vector": autotune.tune_ell_vector(Wv, active0, src, freq),
        }
    autotune.put_entry(
        "ell_vs_seg",
        autotune.shape_bucket(n, gb.R_pad, gb.ell_plan_width()),
        {"use_ref": bool(t_ell > t_seg), "us": min(t_seg, t_ell) * 1e6,
         "default_us": t_seg * 1e6})
    cache = autotune.save_table()
    out = {"cache": cache, "kinds": {}}
    for kind, e in entries.items():
        ratio = e["default_us"] / max(e["us"], 1e-9)
        emit(f"autotune/{kind}/winner", e["us"] / 1e6, e["winner"])
        emit(f"autotune/{kind}/winner_speedup", 0.0, f"{ratio:.2f}x")
        out["kinds"][kind] = {
            "winner": e["winner"], "winner_us": e["us"],
            "default_us": e["default_us"], "winner_vs_default": ratio}
    return out


def _ingest_rows(smoke: bool) -> dict:
    """Time the streaming ingestion tier: incremental ``append_files`` of a
    tail onto an existing corpus vs recompressing the concatenation from
    scratch.  Appending mutates the corpus, so a fresh base is built
    (untimed) for every timed repetition; the base's compressor state is
    live, so the append measures exactly the marginal Sequitur work plus
    one re-export — the cost an online ingest pipeline actually pays."""
    rng = np.random.default_rng(23)
    vocab = 120
    n_base, n_tail = (4, 2) if smoke else (16, 4)
    phrase = rng.integers(0, vocab, 8)

    def mk_file(size: int) -> np.ndarray:
        parts, total = [], 0
        while total < size:
            p = (phrase if rng.random() < 0.5
                 else rng.integers(0, vocab, int(rng.integers(3, 12))))
            parts.append(p)
            total += len(p)
        return np.concatenate(parts)[:size]

    base = [mk_file(400) for _ in range(n_base)]
    tail = [mk_file(400) for _ in range(n_tail)]
    repeat, warmup = (2, 1) if smoke else (5, 1)

    fresh = iter([CompressedCorpus.build(base, vocab)
                  for _ in range(repeat + warmup)])
    t_append = timeit(lambda: next(fresh).append_files(tail),
                      repeat=repeat, warmup=warmup)
    t_rebuild = timeit(lambda: CompressedCorpus.build(base + tail, vocab),
                       repeat=repeat, warmup=warmup)
    speedup = t_rebuild / max(t_append, 1e-12)
    tail_tokens = int(sum(len(f) for f in tail))
    tok_per_s = tail_tokens / max(t_append, 1e-12)
    emit("ingest/append", t_append, f"base={n_base};tail={n_tail}")
    emit("ingest/rebuild", t_rebuild, f"files={n_base + n_tail}")
    emit("ingest/speedup", 0.0, f"{speedup:.2f}x")
    emit("ingest/append_tokens_per_s", 0.0, f"{tok_per_s:.0f}")
    return {"base_files": n_base, "tail_files": n_tail,
            "tail_tokens": tail_tokens,
            "append_us": t_append * 1e6, "rebuild_us": t_rebuild * 1e6,
            "speedup": speedup, "append_tokens_per_s": tok_per_s}


def run(smoke: bool = False) -> dict:
    n = 4 if smoke else 16
    gas = make_ragged_corpora(n)
    gb = GrammarBatch.build(gas)

    def seq_word_count():
        for ga in gas:
            jax.block_until_ready(word_count(ga, method="frontier"))

    def bat_word_count():
        jax.block_until_ready(batched_word_count(gb))

    def seq_term_vector():
        for ga in gas:
            jax.block_until_ready(term_vector(ga, method="frontier"))

    def bat_term_vector():
        jax.block_until_ready(batched_term_vector(gb))

    out = {"n": n, "batched_vs_sequential": {}, "ell_vs_segment_sum": {}}
    for app, seq, bat in (("word_count", seq_word_count, bat_word_count),
                          ("term_vector", seq_term_vector, bat_term_vector)):
        t_seq = timeit(seq, repeat=3, warmup=1)
        t_bat = timeit(bat, repeat=3, warmup=1)
        speedup = t_seq / max(t_bat, 1e-12)
        emit(f"batch/{app}/sequential", t_seq, f"n={n}")
        emit(f"batch/{app}/batched", t_bat, f"n={n}")
        emit(f"batch/{app}/speedup", 0.0, f"{speedup:.2f}x")
        out["batched_vs_sequential"][app] = {
            "sequential_us": t_seq * 1e6, "batched_us": t_bat * 1e6,
            "speedup": speedup}

    def trav_seg():
        jax.block_until_ready(batched_top_down_weights(gb, method="frontier"))

    def trav_ell():
        jax.block_until_ready(
            batched_top_down_weights(gb, method="frontier_ell"))

    def trav_fused():
        jax.block_until_ready(
            batched_top_down_weights(gb, method="frontier_fused"))

    t_seg = timeit(trav_seg, repeat=3, warmup=1)
    t_ell = timeit(trav_ell, repeat=3, warmup=1)
    t_fused = timeit(trav_fused, repeat=5, warmup=2)
    ell_speedup = t_seg / max(t_ell, 1e-12)
    fused_speedup = t_ell / max(t_fused, 1e-12)
    emit("batch/traversal/segment_sum", t_seg, f"n={n}")
    emit("batch/traversal/ell", t_ell, f"n={n}")
    emit("batch/traversal/ell_speedup", 0.0, f"{ell_speedup:.2f}x")
    emit("batch/traversal/fused", t_fused, f"n={n}")
    emit("batch/traversal/fused_speedup", 0.0, f"{fused_speedup:.2f}x")
    out["ell_vs_segment_sum"] = {
        "segment_sum_us": t_seg * 1e6, "ell_us": t_ell * 1e6,
        "speedup": ell_speedup}
    out["traversal_fused"] = {
        "ell_us": t_ell * 1e6, "fused_us": t_fused * 1e6,
        "speedup": fused_speedup,
        "vs_segment_sum": t_seg / max(t_fused, 1e-12)}

    out["autotune"] = _autotune_rows(gb, n, t_seg, t_ell, smoke)

    # ----- compressed search: batched vs per-corpus sequential ranking ---
    # sequential = the pre-batching retrieval story: one jitted scoring
    # call per corpus against its (prebuilt, memoized) SearchIndex;
    # batched = one program ranking every corpus in the pack (pack-level
    # statistics memoized, like recurring serving traffic).  Index builds
    # are excluded from both sides — this times the ranking hot path.
    terms = tuple(int(t) for t in
                  np.random.default_rng(11).integers(0, 40, 8))
    indexes = [build_search_index(ga) for ga in gas]
    out["search"] = {"n": n, "terms": len(terms), "schemes": {}}
    for scheme in ("bm25", "tfidf"):
        def seq_search(scheme=scheme):
            for si in indexes:
                search_index_topk(si, terms, k=10, scheme=scheme)

        def bat_search(scheme=scheme):
            batched_search(gb, terms, k=10, scheme=scheme)

        t_seq = timeit(seq_search, repeat=3, warmup=1)
        t_bat = timeit(bat_search, repeat=3, warmup=1)
        s_speedup = t_seq / max(t_bat, 1e-12)
        emit(f"search/{scheme}/sequential", t_seq, f"n={n}")
        emit(f"search/{scheme}/batched", t_bat, f"n={n}")
        emit(f"search/{scheme}/speedup", 0.0, f"{s_speedup:.2f}x")
        out["search"]["schemes"][scheme] = {
            "sequential_us": t_seq * 1e6, "batched_us": t_bat * 1e6,
            "speedup": s_speedup}

    # ----- query operators: batched vs per-corpus sequential -------------
    # sequential = the pre-batching story again: one single-corpus engine
    # call per corpus, each re-traversing for its own stats; batched = ONE
    # jitted program over the pack, whose per-file stats / sequence plans
    # are memoized on the pack like recurring serving traffic.
    qrng = np.random.default_rng(13)
    pred = ("or", (("and", (("term", 3, 1), ("term", 7, 2))),
                   ("term", 11, 1)))
    agg_terms = tuple(int(t) for t in qrng.integers(0, 40, 6))
    phrase = tuple(int(t) for t in qrng.integers(0, 40, 3))
    out["query"] = {"n": n, "ops": {}}
    for op, seq_fn, bat_fn in (
            ("filter",
             lambda: [filter_corpus(ga, pred) for ga in gas],
             lambda: batched_filter(gb, pred)),
            ("agg",
             lambda: [agg_corpus(ga, agg_terms, "sum") for ga in gas],
             lambda: batched_agg(gb, agg_terms, "sum")),
            ("phrase",
             lambda: [phrase_corpus(ga, phrase) for ga in gas],
             lambda: batched_phrase(gb, phrase))):
        t_seq = timeit(seq_fn, repeat=3, warmup=1)
        t_bat = timeit(bat_fn, repeat=3, warmup=1)
        q_speedup = t_seq / max(t_bat, 1e-12)
        emit(f"query/{op}/sequential", t_seq, f"n={n}")
        emit(f"query/{op}/batched", t_bat, f"n={n}")
        emit(f"query/{op}/speedup", 0.0, f"{q_speedup:.2f}x")
        out["query"]["ops"][op] = {
            "sequential_us": t_seq * 1e6, "batched_us": t_bat * 1e6,
            "speedup": q_speedup}

    # ----- device-sharded pack vs single-device pack (same corpora) -----
    mesh = corpus_mesh()
    devices = mesh_size(mesh)
    gb_sh = shard_batch(gas, mesh)      # == gb placement when mesh is None
    out["sharded"] = {"devices": devices, "n": n, "apps": {}}
    for app, one_fn, sh_fn in (
            ("word_count",
             lambda: jax.block_until_ready(batched_word_count(gb)),
             lambda: jax.block_until_ready(batched_word_count(gb_sh))),
            ("traversal",
             lambda: jax.block_until_ready(
                 batched_top_down_weights(gb, method="frontier")),
             lambda: jax.block_until_ready(
                 batched_top_down_weights(gb_sh, method="frontier"))),
            ("term_vector",
             lambda: jax.block_until_ready(batched_term_vector(gb)),
             lambda: jax.block_until_ready(batched_term_vector(gb_sh)))):
        t_one = timeit(one_fn, repeat=3, warmup=1)
        t_sh = timeit(sh_fn, repeat=3, warmup=1)
        sh_speedup = t_one / max(t_sh, 1e-12)
        emit(f"shard/{app}/single", t_one, f"n={n}")
        emit(f"shard/{app}/sharded", t_sh, f"n={n};devices={devices}")
        emit(f"shard/{app}/speedup", 0.0, f"{sh_speedup:.2f}x")
        out["sharded"]["apps"][app] = {
            "single_us": t_one * 1e6, "sharded_us": t_sh * 1e6,
            "speedup": sh_speedup}

    out["ingest"] = _ingest_rows(smoke)
    return out


if __name__ == "__main__":
    import sys
    run(smoke="--smoke" in sys.argv)
