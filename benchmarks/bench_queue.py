"""Async serving queue under a Poisson-ish synthetic arrival trace.

Replays a deterministic open-loop trace — exponential inter-arrival times,
mixed analytics kinds, a random half of the queries carrying deadlines —
against :class:`AsyncAnalyticsServer` (inline polling, real clock), and
emits ``queue/*`` rows:

* median / p95 submit-to-result latency (us) and end-to-end throughput
  (the mean also lands in the JSON — it carries any residual compile tail);
* flush counts by reason (max_batch / deadline / idle / max_wait / drain)
  — the policy's fingerprint on this mix.  Shedding (the orthogonal
  ``n_shed`` dimension on each flush) stays at zero here: deadlines in
  this trace are comfortably feasible, so any shed would flag a policy
  regression.  ``bench_load`` is where shedding is exercised on purpose;
* the engine-call amplification (flushes per query: < 1 means batching).

Everything is warmed (compiled) before the trace so the numbers are
steady-state queue/policy overhead + batched execution, not compile time.
``run`` returns the dict that ``benchmarks.run`` merges into
BENCH_batch.json (the CI perf artifact).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import compress_files, flatten
from repro.core.batch import _round_up_pow2
from repro.serving import AnalyticsServer, AsyncAnalyticsServer, Query

from .common import emit

KINDS = ("word_count", "sort", "term_vector", "sequence_count")


def _bucket_key(ga):
    """The corpus's per-dim pow2 buckets (mirrors GrammarBatch.build): any
    pack of corpora sharing this key has the same compilation signature."""
    return (_round_up_pow2(ga.num_rules), _round_up_pow2(ga.num_edges),
            _round_up_pow2(len(ga.tw_rule)),
            _round_up_pow2(ga.num_files, 1), _round_up_pow2(ga.vocab_size),
            _round_up_pow2(len(ga.fedge_file), 1),
            _round_up_pow2(len(ga.fword_file), 1))


def make_uniform_corpora(n: int, seed: int = 13, size: int = 500):
    """n corpora whose padded dims land in the same pow2 buckets: steady
    serving traffic, where every flush subset of equal width hits ONE
    compiled program per kind (ragged sizes would measure XLA compiles, not
    the queue).  Corpora falling into other buckets are re-drawn."""
    rng = np.random.default_rng(seed)
    gas, want = [], None
    for _ in range(50 * n):
        vocab = 160
        phrase = rng.integers(0, vocab, 6)
        files = []
        for _ in range(3):
            parts, total = [], 0
            while total < size:
                p = (phrase if rng.random() < 0.5
                     else rng.integers(0, vocab, int(rng.integers(3, 12))))
                parts.append(p)
                total += len(p)
            files.append(np.concatenate(parts)[:size])
        g, nf = compress_files(files, vocab)
        ga = flatten(g, vocab, nf)
        if want is None:
            want = _bucket_key(ga)
        if _bucket_key(ga) == want:
            gas.append(ga)
            if len(gas) == n:
                return gas
    raise RuntimeError("could not draw enough same-bucket corpora")


def _make_trace(rng, names, n_queries: int, mean_gap_s: float):
    arrivals = np.cumsum(rng.exponential(mean_gap_s, n_queries))
    trace = []
    for at in arrivals:
        kind = KINDS[int(rng.integers(len(KINDS)))]
        q = Query(names[int(rng.integers(len(names)))], kind, l=3)
        rel_deadline = (float(rng.uniform(0.01, 0.05))
                        if rng.random() < 0.5 else None)
        trace.append((float(at), q, rel_deadline))
    return trace


def _replay(eng, trace):
    """Replay one trace against a fresh queue on the shared engine; returns
    (latencies, flushes-by-reason delta, wall seconds)."""
    aq = AsyncAnalyticsServer(eng, idle_timeout=0.004, poll_interval=0.001)
    flushes_before = dict(eng.stats.flushes)
    lat = {}
    t0 = time.monotonic()

    def _now() -> float:
        return time.monotonic() - t0

    futs = []
    for at, q, rel_dl in trace:
        while _now() < at:
            aq.poll()
            time.sleep(0.0002)
        dl = None if rel_dl is None else t0 + at + rel_dl
        submitted = _now()       # before submit: max_batch flushes execute
        fut = aq.submit(q, deadline=dl)      # inside the submit call itself
        fut.add_done_callback(
            lambda _f, s=submitted, k=len(futs): lat.__setitem__(
                k, _now() - s))
        futs.append(fut)
    while not all(f.done() for f in futs):
        aq.poll()
        time.sleep(0.0005)
    wall = _now()
    aq.close()
    lats = np.array([lat[k] for k in sorted(lat)])
    flushes = {k: v - flushes_before.get(k, 0)
               for k, v in eng.stats.flushes.items()
               if v - flushes_before.get(k, 0)}
    return lats, flushes, wall


def run(smoke: bool = False) -> dict:
    n_corpora = 4 if smoke else 8
    n_queries = 24 if smoke else 96
    rng = np.random.default_rng(17)
    gas = make_uniform_corpora(n_corpora, seed=13)
    eng = AnalyticsServer(max_batch=4)
    names = []
    for i, ga in enumerate(gas):
        name = f"q{i}"
        eng.register(name, ga)
        names.append(name)

    # warm the full-pack shapes and seed the latency EWMA ...
    for kind in KINDS:
        eng.run([Query(n, kind, l=3) for n in names])

    trace = _make_trace(rng, names, n_queries,
                        mean_gap_s=0.02 if smoke else 0.01)
    # ... then replay to compile the partial-pack shapes the flush policy
    # actually produces, and report the steady-state final pass
    _replay(eng, trace)
    _replay(eng, trace)
    lats, flushes, wall = _replay(eng, trace)
    n_flushes = max(sum(flushes.values()), 1)
    emit("queue/median_latency", float(np.median(lats)), f"n={n_queries}")
    emit("queue/p95_latency", float(np.percentile(lats, 95)),
         f"n={n_queries}")
    emit("queue/throughput", 0.0, f"{n_queries / wall:.0f} q/s")
    emit("queue/flushes", 0.0,
         ";".join(f"{k}={v}" for k, v in sorted(flushes.items()))
         + f";per_query={n_flushes / n_queries:.2f}")
    return {"queue": {
        "n_corpora": n_corpora,
        "n_queries": n_queries,
        "mean_latency_us": float(lats.mean() * 1e6),
        "median_latency_us": float(np.median(lats) * 1e6),
        "p95_latency_us": float(np.percentile(lats, 95) * 1e6),
        "throughput_qps": float(n_queries / wall),
        "flushes": flushes,
        "flushes_per_query": n_flushes / n_queries,
        "max_queue_depth": eng.stats.max_queue_depth,
        "latency_estimates_s": {
            f"{kind}": eng.stats.estimate_latency(kind) for kind in KINDS},
    }}


if __name__ == "__main__":
    import sys
    run(smoke="--smoke" in sys.argv)
