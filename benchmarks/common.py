"""Shared benchmark utilities: timing, corpora, CSV emission."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.data import CompressedCorpus, synthetic

ROWS: List[str] = []


def timeit(fn: Callable, repeat: int = 3, warmup: int = 1) -> float:
    """Median wall-time in seconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = "") -> None:
    us = seconds * 1e6
    row = f"{name},{us:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


_CORPora: Dict[str, tuple] = {}


def get_corpus(name: str):
    """(files, CompressedCorpus) for a Table-II-analogue dataset.

    "R" is an extra high-redundancy corpus (compression ratio ~10-20x) that
    exposes TADOC's computation-reuse scaling — the paper's datasets are
    web/text dumps with much higher redundancy than small synthetic data.
    """
    if name in _CORPora:
        return _CORPora[name]
    if name == "R":
        rng = np.random.default_rng(9)
        base = rng.integers(0, 800, 2_000)
        files = [np.concatenate([base] * 10 + [rng.integers(0, 800, 500)])
                 for _ in range(4)]
        vocab = 800
    else:
        spec = synthetic.TABLE2[name]
        files = synthetic.make_table2_corpus(name)
        vocab = spec.vocab
    cc = CompressedCorpus.build(files, vocab_size=vocab)
    _CORPora[name] = (files, cc)
    return files, cc
