"""Benchmark entry point: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig9/*     — Fig. 9 analogue: six analytics, TADOC vs direct
  fig10/*    — Fig. 10 analogue: init vs traversal phase split
  vi_c/*     — §VI-C analogue: top-down vs bottom-up + engine variants
  pipeline/* — compressed-store batch feed throughput
  batch/*    — batched multi-corpus engine vs sequential per-corpus loop
  queue/*    — async deadline-aware queue under a Poisson-ish trace
  load/*     — open-loop saturation sweep + overload degradation
  roofline/* — summary rows from the dry-run roofline table (if present)

``--smoke`` runs a minimal fast subset (CI's sanity check that the
benchmark harness still executes end to end).

After writing BENCH_batch.json the documented performance floors
(docs/benchmarks.md) are asserted: a violation prints every failing floor
and exits non-zero, which fails CI's bench-smoke job.  Floors that need a
scale the current run did not reach (16 corpora, an 8-device mesh) are
skipped, not faked — each rule carries its own applicability predicate.
"""

from __future__ import annotations

import json
import sys
from typing import List


def _write_batch_json(data: dict, path: str = "BENCH_batch.json") -> None:
    """Persist the batch-engine + serving timings (batched vs sequential,
    ELL vs segment_sum, queue latency/flush mix, load sweep) — CI uploads
    this as an artifact, and the latest snapshot is committed in-repo to
    track the perf trajectory across PRs."""
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    print(f"wrote {path}", flush=True)


def check_floors(data: dict, smoke: bool = False) -> List[str]:
    """Documented floors from docs/benchmarks.md against one run's data.

    Returns the list of violations (empty = all floors hold).  Smoke runs
    use the looser smoke thresholds where documented — CI boxes are noisy
    and smoke scales are small; the full-scale floors bind in the
    scheduled full sweep.
    """
    v: List[str] = []

    def need(cond: bool, msg: str) -> None:
        if not cond:
            v.append(msg)

    # batched >= 2x sequential at 16 corpora (1.5x at smoke scale)
    floor = 1.5 if smoke else 2.0
    for app, row in data.get("batched_vs_sequential", {}).items():
        need(row["speedup"] >= floor,
             f"batch/{app}/speedup {row['speedup']:.2f}x < {floor}x")

    # fused multi-round traversal >= the per-round while_loop ELL path it
    # replaces: ONE dispatch must never lose to num_levels dispatches.
    # Smoke scale gets noise headroom (tiny packs, shared CI boxes); the
    # 1x floor binds in the full sweep.
    tf = data.get("traversal_fused")
    if tf is not None:
        floor = 0.9 if smoke else 1.0
        need(tf["speedup"] >= floor,
             f"batch/traversal/fused_speedup {tf['speedup']:.2f}x "
             f"< {floor}x vs per-round while_loop")

    # autotune winners can never lose to the shipped defaults — "default"
    # is itself a candidate in every sweep, so a ratio below ~1 means the
    # sweep harness is broken, not that the machine is slow
    for kind, row in data.get("autotune", {}).get("kinds", {}).items():
        need(row["winner_vs_default"] >= 0.99,
             f"autotune/{kind}/winner_speedup "
             f"{row['winner_vs_default']:.2f}x < 1x vs default")

    # search batched >= 2x sequential (both scales clear this easily)
    for scheme, row in data.get("search", {}).get("schemes", {}).items():
        need(row["speedup"] >= 2.0,
             f"search/{scheme}/speedup {row['speedup']:.2f}x < 2.0x")

    # query operators batched >= 2x sequential: the single-corpus side
    # re-traverses per call while the pack memoizes its stats and
    # sequence plans, so both scales clear this easily (docs/benchmarks.md)
    for op, row in data.get("query", {}).get("ops", {}).items():
        need(row["speedup"] >= 2.0,
             f"query/{op}/speedup {row['speedup']:.2f}x < 2.0x")

    # sharded >= 1.5x on word_count + traversal — only meaningful at the
    # documented scale: 16 corpora spread over a real 8-device mesh
    sh = data.get("sharded", {})
    if sh.get("devices", 1) >= 8 and sh.get("n", 0) >= 16:
        for app in ("word_count", "traversal"):
            row = sh.get("apps", {}).get(app)
            if row is not None:
                need(row["speedup"] >= 1.5,
                     f"shard/{app}/speedup {row['speedup']:.2f}x < 1.5x")

    # streaming ingest: appending a tail must beat recompressing the
    # concatenation from scratch — the whole point of the incremental
    # tier.  At smoke scale (4-file base) the base work the rebuild
    # repeats is small, so only a token advantage is demanded; the 1.5x
    # floor binds at the documented 16-file scale.
    ing = data.get("ingest")
    if ing is not None:
        floor = 1.0 if smoke else 1.5
        need(ing["speedup"] >= floor,
             f"ingest/speedup {ing['speedup']:.2f}x < {floor}x "
             f"(append must beat from-scratch rebuild)")

    # load harness: saturation throughput, overload degradation contract
    load = data.get("load")
    if load is not None:
        sat_floor = 40.0 if smoke else 150.0
        need(load["saturation_qps"] >= sat_floor,
             f"load/saturation_qps {load['saturation_qps']:.0f} "
             f"< {sat_floor:.0f} q/s")
        need(load["slo_attainment"] >= 0.2,
             f"load/slo_attainment {load['slo_attainment']:.3f} < 0.2 "
             f"at the healthy load point")
        need(load["cache_hit_rate"] >= 0.3,
             f"load/cache_hit_rate {load['cache_hit_rate']:.3f} < 0.3 "
             f"under zipf skew")
        over = load["overload"]
        need(over["shed"] + over["rejected"] > 0,
             "load/overload shed no load at ~2x saturation "
             f"(shed={over['shed']} rejected={over['rejected']})")
        need(over["errors"] == 0,
             f"load/overload errors={over['errors']} (must degrade "
             f"gracefully, never fail queries with engine errors)")
        need(over["completed"] > 0,
             "load/overload served nothing — shedding must degrade, "
             "not blackhole")
        # observability overhead: full instrumentation (histograms + span
        # trees) vs the registry-disabled baseline on the same trace.  The
        # documented ≤5 % floor binds in the full sweep; smoke medians are
        # tens of microseconds on shared CI boxes, so smoke only guards
        # against gross regressions (docs/observability.md)
        ratio = load.get("metrics_overhead_ratio")
        if ratio is not None:
            ceil = 1.5 if smoke else 1.05
            need(ratio <= ceil,
                 f"load/metrics_overhead ratio {ratio:.3f} > {ceil} "
                 f"(instrumentation must stay within the documented "
                 f"overhead budget)")
    return v


def _enforce_floors(data: dict, smoke: bool) -> None:
    violations = check_floors(data, smoke=smoke)
    if violations:
        print("\nBENCH FLOOR VIOLATIONS:", flush=True)
        for msg in violations:
            print(f"  FAIL {msg}", flush=True)
        sys.exit(1)
    print("all documented bench floors hold", flush=True)


def main() -> None:
    quick = "--quick" in sys.argv
    smoke = "--smoke" in sys.argv

    from . import bench_batch, bench_load, bench_queue

    if smoke:
        data = bench_batch.run(smoke=True)
        data.update(bench_queue.run(smoke=True))
        data.update(bench_load.run(smoke=True))
        _write_batch_json(data)
        _enforce_floors(data, smoke=True)
        return

    datasets = ("D", "R") if quick else ("A", "B", "D", "R")

    from . import bench_speedups, bench_phases, bench_traversal, \
        bench_pipeline
    bench_speedups.run(datasets)
    bench_phases.run(datasets)
    bench_traversal.run(datasets)
    bench_pipeline.run(("D", "R") if quick else ("B", "R"))
    data = bench_batch.run()
    data.update(bench_queue.run())
    data.update(bench_load.run())
    _write_batch_json(data)

    # roofline summary (reads dry-run artifacts if the sweep has run)
    try:
        from repro.launch import roofline
        rows = roofline.load_all()
        for r in rows:
            if "skipped" in r:
                continue
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
                  f"{r['bound_s'] * 1e6:.1f},"
                  f"dominant={r['dominant']};frac={r['roofline_frac']:.3f}")
    except Exception as e:  # sweep not run yet
        print(f"roofline/unavailable,0,{e!r}")

    _enforce_floors(data, smoke=False)


if __name__ == "__main__":
    main()
