"""Benchmark entry point: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig9/*     — Fig. 9 analogue: six analytics, TADOC vs direct
  fig10/*    — Fig. 10 analogue: init vs traversal phase split
  vi_c/*     — §VI-C analogue: top-down vs bottom-up + engine variants
  pipeline/* — compressed-store batch feed throughput
  batch/*    — batched multi-corpus engine vs sequential per-corpus loop
  queue/*    — async deadline-aware queue under a Poisson-ish trace
  roofline/* — summary rows from the dry-run roofline table (if present)

``--smoke`` runs a minimal fast subset (CI's sanity check that the
benchmark harness still executes end to end).
"""

from __future__ import annotations

import json
import sys


def _write_batch_json(data: dict, path: str = "BENCH_batch.json") -> None:
    """Persist the batch-engine + serving-queue timings (batched vs
    sequential, ELL vs segment_sum, queue latency/flush mix) — CI uploads
    this as an artifact to track the perf trajectory across PRs."""
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    print(f"wrote {path}", flush=True)


def main() -> None:
    quick = "--quick" in sys.argv
    smoke = "--smoke" in sys.argv

    from . import bench_batch, bench_queue

    if smoke:
        data = bench_batch.run(smoke=True)
        data.update(bench_queue.run(smoke=True))
        _write_batch_json(data)
        return

    datasets = ("D", "R") if quick else ("A", "B", "D", "R")

    from . import bench_speedups, bench_phases, bench_traversal, \
        bench_pipeline
    bench_speedups.run(datasets)
    bench_phases.run(datasets)
    bench_traversal.run(datasets)
    bench_pipeline.run(("D", "R") if quick else ("B", "R"))
    data = bench_batch.run()
    data.update(bench_queue.run())
    _write_batch_json(data)

    # roofline summary (reads dry-run artifacts if the sweep has run)
    try:
        from repro.launch import roofline
        rows = roofline.load_all()
        for r in rows:
            if "skipped" in r:
                continue
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
                  f"{r['bound_s'] * 1e6:.1f},"
                  f"dominant={r['dominant']};frac={r['roofline_frac']:.3f}")
    except Exception as e:  # sweep not run yet
        print(f"roofline/unavailable,0,{e!r}")


if __name__ == "__main__":
    main()
