"""Client-side trace synthesis for the open-loop load harness.

Deliberately jax-free: :func:`client_trace` runs inside ``multiprocessing``
*spawn* workers (one per simulated client), and a worker that only needs
numpy starts in milliseconds — importing the serving stack (and jax) there
would cost seconds per process and buy nothing.  ``bench_load`` imports
this module for the same definitions on the parent side.
"""

from __future__ import annotations

import numpy as np

#: analytics + search mix, weighted toward cheap point lookups
KIND_WEIGHTS = (
    ("word_count", 0.30),
    ("term_vector", 0.20),
    ("sort", 0.15),
    ("sequence_count", 0.10),
    ("search_bm25", 0.15),
    ("search_tfidf", 0.10),
)


def zipf_popularity(n: int, s: float) -> np.ndarray:
    """Normalized rank-zipf pmf over ``n`` corpora: p_r ∝ 1/(r+1)^s."""
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** s
    return w / w.sum()


def client_trace(args: tuple) -> list:
    """One client's arrival schedule — runs inside the worker pool.

    Returns ``[(at_s, corpus_idx, kind, rel_deadline | None), ...]`` with
    arrivals from a burst-modulated Poisson process: phase lengths are
    exponential, burst phases scale the instantaneous rate by
    ``burst_factor``, calm phases compensate so the long-run mean rate
    stays ``rate_qps`` (offered load is what the spec says it is).
    """
    (seed, duration_s, rate_qps, n_corpora, zipf_s, deadline_frac,
     dl_lo, dl_hi, burst_factor, burst_frac, mean_phase_s) = args
    rng = np.random.default_rng(seed)
    pop = zipf_popularity(n_corpora, zipf_s)
    kinds = [k for k, _ in KIND_WEIGHTS]
    kw = np.array([w for _, w in KIND_WEIGHTS])
    kw = kw / kw.sum()
    # calm rate chosen so  burst_frac*burst + (1-burst_frac)*calm == rate
    calm_rate = rate_qps / (1.0 - burst_frac + burst_frac * burst_factor)
    burst_rate = calm_rate * burst_factor
    out = []
    t = 0.0
    in_burst = rng.random() < burst_frac
    phase_end = float(rng.exponential(mean_phase_s))
    while t < duration_s:
        rate = burst_rate if in_burst else calm_rate
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        while t >= phase_end:                  # cross into the next phase
            in_burst = not in_burst
            phase_end += float(rng.exponential(mean_phase_s))
        if t >= duration_s:
            break
        c = int(rng.choice(n_corpora, p=pop))
        kind = kinds[int(rng.choice(len(kinds), p=kw))]
        rel_dl = (float(rng.uniform(dl_lo, dl_hi))
                  if rng.random() < deadline_frac else None)
        out.append((t, c, kind, rel_dl))
    return out
