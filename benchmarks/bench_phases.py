"""Paper Fig. 10 analogue: initialization vs traversal phase timing.

Phase 1 (init): static layout + memory-bound planning + head/tail plan —
everything the paper's `initialization phase` does (data-structure prep,
light scans).  Phase 2 (traversal): the masked-frontier DAG traversal +
global reduce."""

from __future__ import annotations

import numpy as np

from repro.core import (flatten, compress_files, plan_local_tables,
                        top_down_weights, word_count)
from repro.core.sequence import plan_head_tail, plan_stream, resolve_head_tail
from .common import emit, get_corpus, timeit


def run(datasets=("A", "B", "D", "R")) -> None:
    for ds in datasets:
        files, cc = get_corpus(ds)
        ga = cc.ga

        def phase1():
            plan_local_tables(ga)
            htp = plan_head_tail(ga, 3)
            plan_stream(ga, 3)
            resolve_head_tail(ga, htp)

        def phase2():
            np.asarray(word_count(ga))

        t1 = timeit(phase1)
        t2 = timeit(phase2)
        emit(f"fig10/{ds}/phase1_init", t1, f"rules={ga.num_rules}")
        emit(f"fig10/{ds}/phase2_traversal", t2,
             f"depth={ga.num_levels}")


if __name__ == "__main__":
    run()
