"""Data-plane benchmark: random-access window expansion throughput
(tokens/s out of the compressed store) and batch pipeline rate — the
training-feed path (paper [3]'s random access claim, system-level)."""

from __future__ import annotations

import numpy as np

from repro.data import BatchPipeline
from .common import emit, get_corpus, timeit


def run(datasets=("B", "R")) -> None:
    for ds in datasets:
        files, cc = get_corpus(ds)
        seq = 128
        bsz = 16

        def expand():
            rng = np.random.default_rng(0)
            tot = 0
            for _ in range(32):
                f = int(rng.integers(len(cc.file_lens)))
                off = int(rng.integers(max(int(cc.file_lens[f]) - seq, 1)))
                tot += len(cc.window(f, off, seq))
            return tot

        t = timeit(expand)
        emit(f"pipeline/{ds}/window_expand", t,
             f"tokens_per_s={32 * seq / t:.0f}")

        pl = BatchPipeline(cc, global_batch=bsz, seq_len=seq, seed=0,
                           prefetch=0)
        t = timeit(lambda: pl.batch_at(3))
        emit(f"pipeline/{ds}/batch", t,
             f"tokens_per_s={bsz * seq / t:.0f}")


if __name__ == "__main__":
    run()
