"""Paper §VI-C analogue: top-down vs bottom-up + engine variants.

* term_vector via batched per-file top-down vs bottom-up local tables
  (the dataset A vs B story: many files favour bottom-up, few favour
  top-down) + what the selector picked.
* word_count across the three engines: paper-faithful masked frontier,
  beyond-paper leveled schedule, Pallas-ELL frontier.
"""

from __future__ import annotations

import numpy as np

from repro.core import (bottom_up_tables, per_file_weights, select_direction,
                        top_down_weights, word_count)
from .common import emit, get_corpus, timeit


def run(datasets=("A", "B", "D", "R")) -> None:
    for ds in datasets:
        files, cc = get_corpus(ds)
        ga = cc.ga

        t_td = timeit(lambda: np.asarray(per_file_weights(ga, "frontier")))
        t_bu = timeit(lambda: np.asarray(bottom_up_tables(ga)[0]))
        pick = select_direction(ga)
        emit(f"vi_c/{ds}/term_vector/top_down", t_td,
             f"files={ga.num_files}")
        emit(f"vi_c/{ds}/term_vector/bottom_up", t_bu,
             f"selector={pick};correct="
             f"{(pick == 'top_down') == (t_td <= t_bu)}")

        for method in ("frontier", "leveled", "frontier_ell"):
            t = timeit(lambda m=method: np.asarray(top_down_weights(ga, m)))
            emit(f"vi_c/{ds}/weights/{method}", t,
                 f"depth={ga.num_levels}")


if __name__ == "__main__":
    run()
