"""Open-loop load harness: saturation search + graceful-degradation proof.

``bench_queue.py`` replays a gentle closed-ish trace; this module asks the
million-user question instead: *at what offered load does the serving tier
saturate, and what happens past that point?*  The traffic is shaped like
real traffic, not like a benchmark:

* **zipf corpus popularity** — query targets are drawn rank-wise from a
  zipf(s) distribution over the registered corpora, so a handful of hot
  corpora dominate (this is also what exercises the engine's pack cache:
  the hot subsets recur, the cold tail churns);
* **bursty arrivals** — each client emits a Poisson process modulated by
  a two-phase (calm / burst) Markov chain: burst phases multiply the
  instantaneous rate, so arrivals clump the way user traffic does instead
  of spreading uniformly;
* **mixed kinds** — the six analytics and the two search kinds, weighted
  toward the cheap point lookups like production mixes are;
* **deadlines** — a configurable fraction of queries carries a deadline
  (uniform in a small window), which is what makes shedding observable.

The generator is **open-loop**: every query is submitted at its scheduled
wall-clock time whether or not earlier queries have completed — offered
load never adapts to the server, which is the only honest way to find
saturation (a closed loop self-throttles and reports its own politeness).
Per-client arrival traces are drawn in a ``multiprocessing`` pool (clients
are independent by construction, and trace synthesis is the host-side
cost here); submission itself runs one thread per client against the
shared in-process :class:`AsyncAnalyticsServer` — futures cannot cross a
process boundary, and the RPC frontend that would let true separate
client processes connect is a ROADMAP item, not this harness's job.

``run`` sweeps offered load over multipliers of a base rate, calls the
**saturation q/s** the highest goodput observed across the sweep, then
runs one deliberately overloaded pass at ``overload_factor`` (~2x) the
saturation rate and reports the degradation contract: the server sheds
expired-deadline queries (``stats.shed`` > 0 under overload) and rejects
on backpressure (``QueueFull``) but never crashes, and every query is
accounted for — completed + shed + rejected == offered.  Emitted rows
(all serialized into BENCH_batch.json, floors in docs/benchmarks.md):

* ``load/saturation_qps``       — best goodput across the sweep;
* ``load/p50_latency`` / ``load/p99_latency`` — submit-to-result at the
  highest offered load that still met ``goodput >= 0.9 * offered``;
* ``load/slo_attainment``       — fraction of deadline-carrying queries
  that completed (with a result) by their deadline, same load point;
* ``load/overload/*``           — shed / rejected / completed rates and
  p99 at the overload point;
* ``load/cache_hit_rate``       — engine pack-cache hit rate under the
  zipf skew, whole sweep;
* ``load/stage/*/p99``          — per-stage latency breakdown from the
  engine's ``repro_server_stage_seconds`` histogram (pack_build /
  compile / execute / queue_wait) over the whole sweep;
* ``load/metrics_overhead``     — median queue latency with full
  instrumentation over the registry-disabled baseline, same trace
  (``docs/observability.md``; the ≤5 % floor in ``run.py``).

The run also dumps both registries (server + process-global) to
``METRICS_snapshot.json`` — JSON snapshot plus the Prometheus text
rendering — which CI uploads as an artifact next to BENCH_batch.json.
"""

from __future__ import annotations

import json
import multiprocessing as mp
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import MetricsRegistry, global_registry
from repro.serving import (AnalyticsServer, AsyncAnalyticsServer,
                           DeadlineExceeded, Query, QueueFull)

from ._load_trace import KIND_WEIGHTS, client_trace, zipf_popularity
from .bench_queue import KINDS as QUEUE_KINDS
from .bench_queue import _make_trace, _replay, make_uniform_corpora
from .common import emit

__all__ = ["KIND_WEIGHTS", "LoadSpec", "LoadResult", "zipf_popularity",
           "make_traces", "run_open_loop", "sweep", "metrics_overhead",
           "run"]

#: The span stages whose p99 the harness reports (the full stage set the
#: server observes into ``repro_server_stage_seconds``).
STAGES = ("pack_build", "compile", "execute", "queue_wait")


@dataclass
class LoadSpec:
    """Shape of one offered-load run (everything the clients need)."""
    n_clients: int = 4
    duration_s: float = 2.0
    rate_qps: float = 100.0          # aggregate offered rate, all clients
    zipf_s: float = 1.2              # corpus-popularity skew (rank-zipf)
    deadline_frac: float = 0.5       # fraction of queries with deadlines
    deadline_lo_s: float = 0.02
    deadline_hi_s: float = 0.10
    burst_factor: float = 4.0        # rate multiplier inside a burst phase
    burst_frac: float = 0.25         # long-run fraction of time in burst
    mean_phase_s: float = 0.25       # mean calm/burst phase length
    seed: int = 0


@dataclass
class LoadResult:
    """One run's outcome, every offered query accounted for exactly once."""
    offered: int = 0                 # queries the trace scheduled
    completed: int = 0               # resolved with a result
    shed: int = 0                    # DeadlineExceeded at flush time
    rejected: int = 0                # QueueFull at submit time
    errors: int = 0                  # anything else (must stay 0)
    wall_s: float = 0.0
    latencies_s: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.float64))
    slo_met: int = 0                 # deadline queries answered in time
    slo_total: int = 0               # deadline queries offered (a rejected
    #                                  or shed deadline query is a miss)
    cache_lookups: int = 0
    cache_hits: int = 0

    @property
    def goodput_qps(self) -> float:
        return self.completed / max(self.wall_s, 1e-9)

    @property
    def offered_qps(self) -> float:
        return self.offered / max(self.wall_s, 1e-9)

    def check_accounting(self) -> None:
        total = self.completed + self.shed + self.rejected + self.errors
        if total != self.offered:
            raise AssertionError(
                f"load accounting leak: completed={self.completed} + "
                f"shed={self.shed} + rejected={self.rejected} + "
                f"errors={self.errors} != offered={self.offered}")


def make_traces(spec: LoadSpec, n_corpora: int,
                pool: Optional[mp.pool.Pool] = None) -> List[list]:
    """Per-client traces, one worker process per client when a pool is
    given (client processes are independent sources by construction)."""
    jobs = [(spec.seed * 1000 + i, spec.duration_s,
             spec.rate_qps / spec.n_clients, n_corpora, spec.zipf_s,
             spec.deadline_frac, spec.deadline_lo_s, spec.deadline_hi_s,
             spec.burst_factor, spec.burst_frac, spec.mean_phase_s)
            for i in range(spec.n_clients)]
    if pool is not None:
        return pool.map(client_trace, jobs)
    return [client_trace(j) for j in jobs]


# search terms drawn per query would defeat batching entirely; real search
# traffic repeats popular queries, so clients share a small term-set pool
# (kept small: each distinct term-count is its own compiled program shape)
_TERM_POOL: Tuple[Tuple[int, ...], ...] = ((3, 17, 42), (5, 9, 28))


def _as_query(names: Sequence[str], c: int, kind: str,
              rng: np.random.Generator) -> Query:
    if kind.startswith("search_"):
        terms = _TERM_POOL[int(rng.integers(len(_TERM_POOL)))]
        return Query(names[c], kind, terms=terms, k=3)
    return Query(names[c], kind, l=3)


def run_open_loop(aq: AsyncAnalyticsServer, names: Sequence[str],
                  traces: List[list], spec: LoadSpec) -> LoadResult:
    """Replay the traces open-loop: one submitter thread per client, each
    submitting at its schedule regardless of completions.  Never raises on
    overload — rejections and sheds are outcomes, not failures."""
    res = LoadResult()
    eng_stats = aq.stats
    hits0 = eng_stats.batch_cache_hits
    lookups0 = (eng_stats.batched_calls + eng_stats.single_calls)
    lock = threading.Lock()
    lats: List[float] = []
    slo_met = [0]
    counts = {"completed": 0, "shed": 0, "rejected": 0, "errors": 0}
    futures: List[Future] = []
    t0 = time.monotonic()

    def _done(fut: Future, submitted: float, deadline: Optional[float]):
        now = time.monotonic()
        exc = fut.exception()
        with lock:
            if exc is None:
                counts["completed"] += 1
                lats.append(now - submitted)
                if deadline is not None and now <= deadline:
                    slo_met[0] += 1
            elif isinstance(exc, DeadlineExceeded):
                counts["shed"] += 1
            else:
                counts["errors"] += 1

    def _client(trace: list, seed: int):
        rng = np.random.default_rng(seed)
        for at, c, kind, rel_dl in trace:
            target = t0 + at
            now = time.monotonic()
            if target > now:                  # open-loop: pace, don't adapt
                time.sleep(target - now)
            q = _as_query(names, c, kind, rng)
            dl = None if rel_dl is None else t0 + at + rel_dl
            submitted = time.monotonic()
            try:
                fut = aq.submit(q, deadline=dl)
            except QueueFull:
                with lock:
                    counts["rejected"] += 1
                continue
            fut.add_done_callback(
                lambda f, s=submitted, d=dl: _done(f, s, d))
            with lock:
                futures.append(fut)

    threads = [threading.Thread(target=_client, args=(tr, spec.seed + i),
                                daemon=True)
               for i, tr in enumerate(traces)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # open-loop offered everything; wait for the tail to resolve
    deadline = time.monotonic() + 60.0
    while time.monotonic() < deadline:
        with lock:
            if all(f.done() for f in futures):
                break
        time.sleep(0.002)
    aq.drain()
    # done() flips before the done-callback runs (it may run on another
    # thread); wait for the counters to cover every admitted future
    while time.monotonic() < deadline:
        with lock:
            counted = (counts["completed"] + counts["shed"]
                       + counts["errors"])
            if counted == len(futures):
                break
        time.sleep(0.001)
    res.wall_s = time.monotonic() - t0
    res.offered = sum(len(tr) for tr in traces)
    with lock:
        res.completed = counts["completed"]
        res.shed = counts["shed"]
        res.rejected = counts["rejected"]
        res.errors = counts["errors"]
        res.latencies_s = np.array(lats, np.float64)
        res.slo_met = slo_met[0]
    # SLO denominator: every deadline-carrying query the trace offered —
    # a rejected or shed deadline query is an SLO miss, not a non-event
    res.slo_total = sum(1 for tr in traces for (_, _, _, d) in tr
                        if d is not None)
    res.cache_hits = eng_stats.batch_cache_hits - hits0
    res.cache_lookups = (eng_stats.batched_calls + eng_stats.single_calls
                         - lookups0)
    res.check_accounting()
    return res


def _fresh_queue(eng: AnalyticsServer, max_pending: int
                 ) -> AsyncAnalyticsServer:
    return AsyncAnalyticsServer(eng, idle_timeout=0.004,
                                poll_interval=0.001,
                                max_pending=max_pending)


def _warm(eng: AnalyticsServer, names: Sequence[str]) -> None:
    """Compile every (kind, pack-width) program the trace can produce so
    the sweep measures serving, not XLA: flushes pack 1..max_batch
    distinct corpora, and every width is its own compiled shape (the
    corpora share one size bucket, so width is the only degree of
    freedom)."""
    widths = range(1, min(eng.max_batch, len(names)) + 1)
    for w in widths:
        sub = names[:w]
        for kind, _ in KIND_WEIGHTS:
            if kind.startswith("search_"):
                for terms in _TERM_POOL:
                    eng.run([Query(n, kind, terms=terms, k=3)
                             for n in sub])
            else:
                eng.run([Query(n, kind, l=3) for n in sub])


def sweep(eng: AnalyticsServer, names: Sequence[str], base: LoadSpec,
          multipliers: Sequence[float], max_pending: int,
          pool: Optional[mp.pool.Pool] = None
          ) -> List[Tuple[float, LoadResult]]:
    out = []
    for i, m in enumerate(multipliers):
        spec = LoadSpec(**{**base.__dict__,
                           "rate_qps": base.rate_qps * m,
                           "seed": base.seed + 7919 * i})
        traces = make_traces(spec, len(names), pool)
        with _fresh_queue(eng, max_pending) as aq:
            res = run_open_loop(aq, names, traces, spec)
        out.append((m, res))
    return out


def metrics_overhead(smoke: bool = False) -> dict:
    """Price of the observability layer on the serving hot path.

    Replays the bench_queue trace against two fresh engines on identical
    corpora — one with ``MetricsRegistry(enabled=False)`` (counters and
    gauges still record; histograms and span building are no-ops, the
    documented baseline), one fully instrumented — and reports the ratio
    of steady-state median latencies.  ``run.py check_floors`` holds the
    ratio under the documented ceiling (≤5 % in the full sweep)."""
    n_queries = 24 if smoke else 96
    gas = make_uniform_corpora(4, seed=13)
    medians = {}
    for mode in ("off", "on"):
        eng = AnalyticsServer(
            max_batch=4, registry=MetricsRegistry(enabled=(mode == "on")))
        names = []
        for i, ga in enumerate(gas):
            name = f"m{i}"
            eng.register(name, ga)
            names.append(name)
        for kind in QUEUE_KINDS:
            eng.run([Query(n, kind, l=3) for n in names])
        rng = np.random.default_rng(17)
        trace = _make_trace(rng, names, n_queries,
                            mean_gap_s=0.02 if smoke else 0.01)
        _replay(eng, trace)                     # partial-pack compiles
        _replay(eng, trace)
        lats, _, _ = _replay(eng, trace)        # steady state
        medians[mode] = float(np.median(lats))
    return {"median_off_us": medians["off"] * 1e6,
            "median_on_us": medians["on"] * 1e6,
            "ratio": medians["on"] / max(medians["off"], 1e-12)}


def run(smoke: bool = False) -> dict:
    n_corpora = 4 if smoke else 12
    n_clients = 2 if smoke else 4
    duration = 0.6 if smoke else 2.0
    base_rate = 150.0 if smoke else 300.0
    multipliers = (1.0, 4.0) if smoke else (0.5, 1.0, 2.0, 4.0)
    max_pending = 64 if smoke else 256
    overload_factor = 2.0

    gas = make_uniform_corpora(n_corpora, seed=13)
    eng = AnalyticsServer(max_batch=4)
    names = []
    for i, ga in enumerate(gas):
        name = f"z{i}"
        eng.register(name, ga)
        names.append(name)
    _warm(eng, names)

    base = LoadSpec(n_clients=n_clients, duration_s=duration,
                    rate_qps=base_rate, seed=29)
    # spawn, not fork: jax is multithreaded by the time this runs, and the
    # workers only need numpy (benchmarks/_load_trace.py is jax-free, so a
    # spawned client process starts fast)
    try:
        pool = mp.get_context("spawn").Pool(min(n_clients, 4))
    except (ValueError, OSError):           # no subprocesses: inline
        pool = None
    try:
        results = sweep(eng, names, base, multipliers, max_pending, pool)

        # saturation: the best goodput any offered load achieved; the
        # "healthy" point for latency/SLO reporting is the highest load
        # that still served >= 90% of what was offered
        saturation_qps = max(r.goodput_qps for _, r in results)
        healthy = [(m, r) for m, r in results
                   if r.goodput_qps >= 0.9 * r.offered_qps]
        h_mult, h = healthy[-1] if healthy else results[0]

        # overload: ~2x the measured saturation.  The sweep's top rung may
        # still have been below TRUE saturation (goodput tracked offered
        # the whole way up) — in that case 2x the estimate may not
        # overload either, so escalate until the server demonstrably
        # degrades (sheds or rejects); the achieved factor is reported.
        over_rate = overload_factor * saturation_qps
        for attempt in range(3):
            over_spec = LoadSpec(**{**base.__dict__,
                                    "rate_qps": over_rate,
                                    "seed": base.seed + 104729 * (attempt
                                                                  + 1)})
            traces = make_traces(over_spec, len(names), pool)
            with _fresh_queue(eng, max_pending) as aq:
                over = run_open_loop(aq, names, traces, over_spec)
            if over.shed + over.rejected > 0:
                break
            over_rate *= 2.0
    finally:
        if pool is not None:
            pool.close()
            pool.join()
    over_factor = over_rate / max(saturation_qps, 1e-9)

    cache_lookups = sum(r.cache_lookups for _, r in results)
    cache_hits = sum(r.cache_hits for _, r in results)
    cache_rate = cache_hits / max(cache_lookups, 1)

    def _pct(a: np.ndarray, q: float) -> float:
        return float(np.percentile(a, q)) if a.size else float("nan")

    overhead = metrics_overhead(smoke)

    h_slo = h.slo_met / max(h.slo_total, 1)
    emit("load/saturation_qps", 0.0, f"{saturation_qps:.0f}q/s")
    emit("load/p50_latency", _pct(h.latencies_s, 50), f"mult={h_mult}")
    emit("load/p99_latency", _pct(h.latencies_s, 99), f"mult={h_mult}")
    emit("load/slo_attainment", 0.0,
         f"{h_slo:.3f};n={h.slo_total};mult={h_mult}")
    emit("load/cache_hit_rate", 0.0,
         f"{cache_rate:.3f};lookups={cache_lookups}")
    emit("load/overload/shed_rate", 0.0,
         f"{over.shed / max(over.offered, 1):.3f};shed={over.shed}")
    emit("load/overload/rejected_rate", 0.0,
         f"{over.rejected / max(over.offered, 1):.3f}")
    emit("load/overload/p99_latency", _pct(over.latencies_s, 99),
         f"offered={over.offered_qps:.0f}q/s")

    # per-stage latency breakdown: the engine's stage histogram covers the
    # whole sweep (every flush on eng, healthy and overloaded alike)
    stage_stats = {}
    for stage in STAGES:
        child = eng.stats.stage_seconds.labels(stage)
        p99, n = child.percentile(99), child.count
        stage_stats[stage] = {"p99_us": p99 * 1e6, "count": n}
        emit(f"load/stage/{stage}/p99", p99, f"n={n}")
    emit("load/metrics_overhead", 0.0,
         f"ratio={overhead['ratio']:.3f};"
         f"on={overhead['median_on_us']:.0f}us;"
         f"off={overhead['median_off_us']:.0f}us")

    # dump both registries next to BENCH_batch.json (CI artifact)
    with open("METRICS_snapshot.json", "w") as f:
        json.dump({"snapshot": {"server": eng.registry.snapshot(),
                                "global": global_registry().snapshot()},
                   "prometheus": (eng.registry.render_prometheus()
                                  + global_registry().render_prometheus())},
                  f, indent=1)

    def _row(r: LoadResult) -> dict:
        return {"offered": r.offered, "offered_qps": r.offered_qps,
                "goodput_qps": r.goodput_qps, "completed": r.completed,
                "shed": r.shed, "rejected": r.rejected, "errors": r.errors,
                "p50_latency_us": _pct(r.latencies_s, 50) * 1e6,
                "p99_latency_us": _pct(r.latencies_s, 99) * 1e6,
                "slo_met": r.slo_met, "slo_total": r.slo_total,
                "wall_s": r.wall_s}

    return {"load": {
        "n_corpora": n_corpora,
        "n_clients": n_clients,
        "zipf_s": base.zipf_s,
        "deadline_frac": base.deadline_frac,
        "saturation_qps": saturation_qps,
        "healthy_multiplier": h_mult,
        "p50_latency_us": _pct(h.latencies_s, 50) * 1e6,
        "p99_latency_us": _pct(h.latencies_s, 99) * 1e6,
        "slo_attainment": h_slo,
        "cache_hit_rate": cache_rate,
        "stage": stage_stats,
        "metrics_overhead_ratio": overhead["ratio"],
        "metrics_overhead": overhead,
        "sweep": {str(m): _row(r) for m, r in results},
        "overload": {**_row(over),
                     "factor_vs_saturation": over_factor,
                     "shed_rate": over.shed / max(over.offered, 1),
                     "rejected_rate": over.rejected / max(over.offered, 1)},
    }}


if __name__ == "__main__":
    import sys
    run(smoke="--smoke" in sys.argv)
