"""Paper Fig. 9 analogue: the six analytics, TADOC engine vs direct.

"tadoc"  — this repo's compressed-domain analytics (grammar traversal).
"direct" — the same analytics over the *uncompressed* token stream through
           the same JAX stack (paper §VI-E compares G-TADOC against
           GPU-accelerated uncompressed analytics — same device both sides;
           here both sides run CPU-JAX).

Derived columns report the **reuse bound** = corpus tokens / grammar
symbols: the algorithmic ceiling on TADOC's win (repeated content is
touched once).  On this CPU container with scaled-down corpora, fixed JAX
dispatch overhead (~ms) dominates sub-ms kernels, so wall-clock speedups
materialize only on the high-redundancy corpus (R); the paper's regime
(GB-scale web dumps, ratios 5-13x, GPU) sits far to the right of these
sizes.  EXPERIMENTS.md §Benchmarks discusses the scaling."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (word_count, sort_words, term_vector, inverted_index,
                        ranked_inverted_index, sequence_count)
from .common import emit, get_corpus, timeit


# ---- direct (uncompressed) analytics, same JAX stack -------------------- #
import functools


@functools.partial(jax.jit, static_argnums=(2, 3))
def _d_word_count(stream, file_ids, vocab, nfiles):
    del file_ids, nfiles
    return jax.ops.segment_sum(jnp.ones_like(stream, jnp.float32), stream,
                               num_segments=vocab)


@functools.partial(jax.jit, static_argnums=(2, 3))
def _d_term_vector(stream, file_ids, vocab, nfiles):
    idx = file_ids * vocab + stream
    flat = jax.ops.segment_sum(jnp.ones_like(stream, jnp.float32), idx,
                               num_segments=nfiles * vocab)
    return flat.reshape(nfiles, vocab)


def _d_ngrams(stream, file_ids, l=3):
    # windows not crossing file boundaries; sort + segment count
    win = jnp.stack([stream[i:stream.shape[0] - l + 1 + i]
                     for i in range(l)], axis=1)
    same = file_ids[:-l + 1] == file_ids[l - 1:]
    order = jnp.lexsort(tuple(win[:, c] for c in range(l - 1, -1, -1)))
    sw = win[order]
    valid = same[order].astype(jnp.float32)
    newseg = jnp.concatenate([jnp.array([True]),
                              (sw[1:] != sw[:-1]).any(axis=1)])
    seg = jnp.cumsum(newseg) - 1
    counts = jax.ops.segment_sum(valid, seg, num_segments=sw.shape[0])
    return sw, counts


def run(datasets=("A", "B", "D", "R")) -> None:
    for ds in datasets:
        files, cc = get_corpus(ds)
        ga = cc.ga
        V = ga.vocab_size
        stream = jnp.asarray(np.concatenate(files))
        file_ids = jnp.asarray(np.concatenate(
            [np.full(len(f), i) for i, f in enumerate(files)]))
        nf = len(files)
        tokens = int(stream.shape[0])
        reuse = tokens / ga.body.shape[0]

        apps = {
            "word_count": (
                lambda: np.asarray(word_count(ga)),
                lambda: np.asarray(_d_word_count(stream, file_ids, V, nf))),
            "sort": (
                lambda: np.asarray(sort_words(ga)[1]),
                lambda: np.asarray(jnp.sort(
                    _d_word_count(stream, file_ids, V, nf))[::-1])),
            "term_vector": (
                lambda: np.asarray(term_vector(ga)),
                lambda: np.asarray(_d_term_vector(stream, file_ids, V, nf))),
            "inverted_index": (
                lambda: np.asarray(inverted_index(ga)),
                lambda: np.asarray(
                    _d_term_vector(stream, file_ids, V, nf) > 0)),
            "ranked_inverted_index": (
                lambda: np.asarray(ranked_inverted_index(ga)[0]),
                lambda: np.asarray(jnp.argsort(
                    -_d_term_vector(stream, file_ids, V, nf), axis=0))),
            "sequence_count": (
                lambda: sequence_count(ga, l=3),
                lambda: jax.block_until_ready(
                    _d_ngrams(stream, file_ids, 3))),
        }
        for app, (tadoc_fn, direct_fn) in apps.items():
            t_t = timeit(tadoc_fn)
            t_d = timeit(direct_fn)
            emit(f"fig9/{ds}/{app}/tadoc", t_t,
                 f"ratio={ga.compression_ratio():.1f}x;"
                 f"reuse_bound={reuse:.1f}x")
            emit(f"fig9/{ds}/{app}/direct", t_d,
                 f"speedup_tadoc_vs_direct={t_d / t_t:.2f}x")


if __name__ == "__main__":
    run()
